"""Data pipeline, optimizer, checkpoint manager."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, host_batch, synth_tokens
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, global_norm, lr_at)


# -- data ------------------------------------------------------------------

def test_data_deterministic_across_calls():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    a = host_batch(cfg, 11)
    b = host_batch(cfg, 11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = host_batch(cfg, 12)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_partitions_global_batch():
    base = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=1)
    full = synth_tokens(base, 5)
    parts = []
    for hid in range(4):
        cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=1,
                         n_hosts=4, host_id=hid)
        parts.append(host_batch(cfg, 5)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), full[:, :-1])


def test_data_labels_shifted():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=4)
    b = host_batch(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_learnable_structure():
    cfg = DataConfig(vocab=1000, seq_len=256, global_batch=4)
    toks = host_batch(cfg, 0)["tokens"]
    # Markov stream: conditional entropy must be far below marginal
    from collections import Counter
    pairs = Counter(zip(toks[:, :-1].ravel(), toks[:, 1:].ravel()))
    # given prev, next is nearly deterministic up to 7 noise values
    fanout = Counter(p for p, _ in pairs)
    avg_branching = np.mean([sum(1 for (a, _), _ in pairs.items() if a == p)
                             for p in list(fanout)[:20]])
    assert avg_branching <= 14


# -- optimizer ---------------------------------------------------------------

def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10,
                      schedule="constant")
    params = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
    state = adamw_init(cfg, params)
    new_params, state, stats = adamw_update(cfg, grads, state, params)
    # hand-computed AdamW step 1: m=0.1g, v=0.01g^2, mhat=g, vhat=g^2
    g = np.asarray(grads["w"])
    expect = np.asarray(params["w"]) - 1e-2 * g / (np.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect,
                               rtol=1e-5)


def test_grad_clip_and_norm():
    tree = {"a": jnp.ones((4,)) * 3.0}
    assert abs(float(global_norm(tree)) - 6.0) < 1e-5
    clipped, norm = clip_by_global_norm(tree, 1.5)
    assert abs(float(global_norm(clipped)) - 1.5) < 1e-4


def test_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      schedule="cosine", min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.array(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.array(10))) - 1.0) < 1e-5
    assert float(lr_at(cfg, jnp.array(110))) <= 0.11
    mid = float(lr_at(cfg, jnp.array(60)))
    assert 0.1 < mid < 1.0


def test_weight_decay_skips_norms_and_biases():
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.5, grad_clip=1e9,
                      warmup_steps=0, schedule="constant")
    params = {"ffn": {"gate": jnp.ones((4, 4))},
              "ln": {"scale": jnp.ones((4,))}}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(cfg, params)
    new_params, _, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(new_params["ln"]["scale"] - 1.0).max()) == 0.0
    assert float(jnp.abs(new_params["ffn"]["gate"] - 1.0).max()) > 0.0


# -- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip_retention_atomicity():
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.array(7)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        for s in (10, 20, 30):
            mgr.save(s, state)
        assert mgr.committed_steps() == [20, 30]
        restored, step = mgr.restore(target=state)
        assert step == 30
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(state["params"]["w"]))
        # uncommitted checkpoints are invisible
        os.remove(os.path.join(d, "step_000000030", "COMMIT"))
        assert mgr.latest_step() == 20


def test_checkpoint_async_save_then_restore():
    state = {"w": jnp.ones((8, 8))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3, async_save=True)
        mgr.save(1, state)
        mgr.wait()
        restored, step = mgr.restore(target=state)
        assert step == 1


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            mgr.restore(target={"w": jnp.ones((3, 3))})
