"""MoE invariants: routing mass, capacity dropping, slab layouts, grads."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import (MoEConfig, apply_moe, init_moe,
                              moe_active_param_count, moe_param_count,
                              _route)


def test_router_gates_renormalised():
    cfg = MoEConfig(dim=8, n_experts=16, top_k=4, d_ff=16)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    gates, experts, aux = _route(x, w, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert np.asarray(experts).min() >= 0
    assert np.asarray(experts).max() < 16
    # top-k indices are distinct per token
    e = np.asarray(experts)
    assert all(len(set(row)) == cfg.top_k for row in e)
    assert float(aux) > 0


def test_capacity_dropping_is_graceful():
    """With capacity_factor → 0 most tokens drop; output stays finite and
    shrinks toward the shared path (here: zero)."""
    cfg_hi = MoEConfig(dim=16, n_experts=4, top_k=2, d_ff=32,
                       capacity_factor=8.0)
    cfg_lo = MoEConfig(dim=16, n_experts=4, top_k=2, d_ff=32,
                       capacity_factor=0.05)
    p = init_moe(jax.random.PRNGKey(0), cfg_hi)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y_hi, _ = apply_moe(p, x, cfg_hi)
    y_lo, _ = apply_moe(p, x, cfg_lo)
    assert np.isfinite(np.asarray(y_lo, np.float32)).all()
    assert np.abs(np.asarray(y_lo, np.float32)).mean() < \
        np.abs(np.asarray(y_hi, np.float32)).mean()


def test_slab_geometry():
    # ep == n_shards when E >= M
    cfg = MoEConfig(dim=8, n_experts=384, top_k=8, d_ff=32, n_shards=16)
    assert (cfg.ep, cfg.tp, cfg.e_loc, cfg.f_loc) == (16, 1, 24, 32)
    # Grok case: E=8 on 16-way axis => split hidden dim
    cfg = MoEConfig(dim=8, n_experts=8, top_k=2, d_ff=32, n_shards=16)
    assert (cfg.ep, cfg.tp, cfg.e_loc, cfg.f_loc) == (8, 2, 1, 16)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    assert p["gate_slab"].shape == (16, 1, 8, 16)
    assert p["down_slab"].shape == (16, 1, 16, 8)


def test_param_counts():
    cfg = MoEConfig(dim=8, n_experts=4, top_k=2, d_ff=16,
                    shared_expert_ff=16)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    total = sum(int(a.size) for a in jax.tree.leaves(p))
    assert total == moe_param_count(cfg)
    assert moe_active_param_count(cfg) < moe_param_count(cfg)


def test_moe_grads_finite_and_router_trained():
    cfg = MoEConfig(dim=16, n_experts=8, top_k=2, d_ff=32)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))

    def loss(p):
        y, aux = apply_moe(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert np.abs(np.asarray(g["router"])).max() > 0, \
        "router must receive gradient through gates + aux loss"
