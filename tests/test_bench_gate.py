"""Benchmark regression gate tests (DESIGN.md §11): the gate must flag a
synthetically injected 2x slowdown under the default tolerance, pass
identical numbers, warn (not fail) on missing baseline entries, and the
``benchmarks.run --json`` payload must round-trip through the gate's
loader."""

import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(ROOT))

from benchmarks import gate  # noqa: E402
from benchmarks import run as bench_run  # noqa: E402

pytestmark = pytest.mark.bench


def _payload(rows):
    return {"schema": 1, "smoke": True, "only": [], "failed": [],
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows]}


BASE = _payload([
    ("scan/fwd/128", 5000.0, "row_tile=64"),
    ("scan/bwd/128", 9000.0, ""),
    ("scan/tiny", 10.0, ""),          # below the noise floor
])


def test_identical_numbers_pass():
    res = gate.compare(BASE, BASE)
    assert res.ok
    assert not res.regressions and not res.warnings
    assert res.checked == 2           # the tiny rung is floored out


def test_injected_2x_slowdown_fails():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["us_per_call"] *= 2.0
    res = gate.compare(BASE, cur)     # default tolerance 1.8
    assert not res.ok
    (name, b, c, ratio), = res.regressions
    assert name == "scan/fwd/128"
    assert ratio == pytest.approx(2.0)


def test_improvement_is_not_a_failure():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][1]["us_per_call"] /= 3.0
    res = gate.compare(BASE, cur)
    assert res.ok
    assert [r[0] for r in res.improvements] == ["scan/bwd/128"]


def test_noise_floor_suppresses_tiny_rungs():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][2]["us_per_call"] *= 2.0       # 10us -> 20us: pure noise
    assert gate.compare(BASE, cur).ok
    # but a tiny rung exploding past the floor IS a regression
    cur["rows"][2]["us_per_call"] = 5000.0
    assert not gate.compare(BASE, cur).ok


def test_missing_entries_warn_not_fail():
    cur = _payload([
        ("scan/fwd/128", 5000.0, ""),          # bwd rung retired ...
        ("scan/new_rung", 7000.0, ""),         # ... new rung landed
    ])
    res = gate.compare(BASE, cur)
    assert res.ok
    assert len(res.warnings) == 3              # bwd + tiny missing, new
    assert any("no baseline entry" in w for w in res.warnings)
    assert any("missing from current" in w for w in res.warnings)


def test_sp_overlap_rung_rows_gate():
    """The sp_scaling overlap rung (ISSUE 10) is a first-class gated
    ladder: its rows — fused-pair timing with the overlap/collective
    metadata packed in the derived column — compare like any other rung,
    a slowdown on the overlap timing is flagged, and a runtime that
    cannot run the 2-host rung (no gloo transport) only WARNS about the
    missing rows.  Also pins that ``--only sp`` resolves (the rung rides
    the uploaded smoke-bench artifact through that registry entry)."""
    assert "sp" in dict(bench_run.MODULES)

    derived = ("strategy=pair_allgather;collectives_per_pair=1;"
               "per_direction_collectives=4;overlap_efficiency=0.035;"
               "serial_us=90470.0;floor_us=6890.0;"
               "exchange_exposed_us=83580.0;exchange_hidden_us=2920.0;"
               "host_cores=8;wire_dtype=float32")
    base = _payload([
        ("sp_scaling/dev2_h64w64_us", 4000.0,
         "strategy=ppermute;collective_bytes=2048;activation_bytes=65536;"
         "ratio=0.03125;wire_dtype=float32"),
        ("sp_scaling/overlap_dev2_h64w64_us", 9000.0, derived),
        ("sp_scaling/overlap_hosts2_h64w64_us", 9500.0,
         derived + ";hosts=2"),
    ])
    assert gate.compare(base, base).ok

    cur = json.loads(json.dumps(base))
    cur["rows"][1]["us_per_call"] *= 2.0
    res = gate.compare(base, cur)
    assert [r[0] for r in res.regressions] == \
        ["sp_scaling/overlap_dev2_h64w64_us"]

    skipped = json.loads(json.dumps(base))
    skipped["rows"] = skipped["rows"][:2]      # multihost rung skipped
    res = gate.compare(base, skipped)
    assert res.ok
    assert any("overlap_hosts2" in w for w in res.warnings)


def test_tolerance_band_is_configurable():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["us_per_call"] *= 1.5
    assert gate.compare(BASE, cur).ok                       # 1.5 < 1.8
    assert not gate.compare(BASE, cur, tolerance=1.4).ok    # 1.5 > 1.4


# ---------------------------------------------------------------------------
# Dtype ordering check (DESIGN.md §12): bf16 pallas fwd must strictly
# beat f32 at every ladder resolution, with messages naming the rung.
# ---------------------------------------------------------------------------

def _dtype_rows(f32_us, bf16_us, res="128x128"):
    return [(f"dtype/f32/pallas/{res}/fwd", f32_us, ""),
            (f"dtype/bf16/pallas/{res}/fwd", bf16_us, "")]


def test_dtype_ordering_ok_when_bf16_faster():
    payload = _payload(_dtype_rows(650.0, 600.0)
                       + _dtype_rows(2600.0, 2400.0, res="256x256")
                       + [("scan/fwd/128", 5000.0, "")])
    assert gate.dtype_ordering_violations(payload) == []


def test_dtype_ordering_violation_names_rung_and_dtype():
    payload = _payload(_dtype_rows(650.0, 600.0)
                       + _dtype_rows(2000.0, 9000.0, res="256x256"))
    (v,) = gate.dtype_ordering_violations(payload)
    assert "256x256" in v and "bf16" in v and "f32" in v
    assert "9000.0us >= f32 2000.0us" in v
    # a TIE is also a violation: the order must be STRICT
    tie = _payload(_dtype_rows(500.0, 500.0))
    assert len(gate.dtype_ordering_violations(tie)) == 1


def test_dtype_ordering_skips_unpaired_rungs():
    # xla rungs and resolutions missing one side never trip the check
    payload = _payload([("dtype/f32/pallas/512x512/fwd", 100.0, ""),
                        ("dtype/bf16/xla/128x128/fwd", 9e9, ""),
                        ("dtype/f32/xla/128x128/fwd", 1.0, "")])
    assert gate.dtype_ordering_violations(payload) == []


def test_uniform_scaling_cannot_trip_ordering():
    """The injected-2x CI self-test scales every rung uniformly; a
    within-report comparison must be invariant to that."""
    payload = _payload(_dtype_rows(650.0, 600.0))
    scaled = json.loads(json.dumps(payload))
    for row in scaled["rows"]:
        row["us_per_call"] *= 2.0
    assert gate.dtype_ordering_violations(scaled) == []


def test_cli_fails_and_update_refuses_on_ordering_violation(tmp_path,
                                                           capsys):
    good = _payload(_dtype_rows(650.0, 600.0))
    bad = _payload(_dtype_rows(600.0, 16000.0))
    base = _write(tmp_path, "base.json", good)
    cur = _write(tmp_path, "bad.json", bad)
    # ratio band alone would pass (bf16 16000/600 has no baseline pair
    # mismatch here — base vs bad bf16 regresses, so gate vs base fails
    # anyway; the point is the ORDERING line names the rung + dtype)
    assert gate.main(["--baseline", base, "--current", cur,
                      "--tolerance", "1000"]) == 1
    out = capsys.readouterr().out
    assert "ORDERING" in out and "128x128" in out and "bf16" in out
    # --update must refuse to enshrine a cliff report as the baseline
    assert gate.main(["--baseline", base, "--current", cur,
                      "--update"]) == 1
    assert json.loads(pathlib.Path(base).read_text()) == good
    # and a clean report still re-baselines
    ok = _write(tmp_path, "ok.json", good)
    assert gate.main(["--baseline", base, "--current", ok,
                      "--update"]) == 0


def test_smoke_dtype_ladder_bf16_beats_f32_per_rung(monkeypatch, capsys):
    """Run the REAL smoke dtype ladder and assert bf16 pallas fwd is no
    slower than f32 at every rung it emits (the ISSUE 6 acceptance,
    checked through the same parser the gate uses)."""
    import benchmarks.common as common
    from benchmarks import dtype_ladder

    monkeypatch.setattr(common, "SMOKE", True)
    common.ROWS.clear()
    dtype_ladder.run()
    rows = [(n, us, d) for n, us, d in
            (r.split(",", 2) for r in common.ROWS)]
    payload = _payload([(n, float(us), d) for n, us, d in rows])
    assert any(r["name"].startswith("dtype/bf16/pallas/")
               for r in payload["rows"])
    violations = gate.dtype_ordering_violations(payload)
    assert violations == [], violations
    # the pallas rungs carry the resolved plan in their derived field
    for row in payload["rows"]:
        if "/pallas/" in row["name"]:
            assert "pipeline_depth=" in row["derived"], row
    depths = {row["name"]: row["derived"] for row in payload["rows"]
              if "/pallas/" in row["name"]}
    for name, derived in depths.items():
        want = "2" if "/bf16/" in name else "1"
        assert f"pipeline_depth={want}" in derived, (name, derived)


# ---------------------------------------------------------------------------
# CLI behaviour (what CI actually invokes).
# ---------------------------------------------------------------------------

def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_cli_exit_codes_and_update(tmp_path, capsys):
    base = _write(tmp_path, "base.json", BASE)
    same = _write(tmp_path, "same.json", BASE)
    assert gate.main(["--baseline", base, "--current", same]) == 0

    slow = json.loads(json.dumps(BASE))
    slow["rows"][0]["us_per_call"] *= 2.0
    cur = _write(tmp_path, "slow.json", slow)
    assert gate.main(["--baseline", base, "--current", cur]) == 1
    assert "REGRESSION" in capsys.readouterr().out

    # --update re-baselines instead of gating, then the same run passes
    assert gate.main(["--baseline", base, "--current", cur,
                      "--update"]) == 0
    assert gate.main(["--baseline", base, "--current", cur]) == 0


# ---------------------------------------------------------------------------
# run.py --json schema round-trip.
# ---------------------------------------------------------------------------

def test_json_payload_roundtrips_through_gate_loader(tmp_path):
    rows = ["scan/fwd,123.4,row_tile=64;ws=1.0",
            "scan/bwd,456.7,",
            "serve/load,89.1,ttft=1,qd=2"]     # derived may contain commas
    payload = bench_run.build_payload(rows, smoke=True, only={"fig3"},
                                      failed=["table1"])
    assert payload["schema"] == bench_run.JSON_SCHEMA == 2
    assert payload["only"] == ["fig3"]
    assert payload["failed"] == ["table1"]
    # rows built without timing stats carry stats=None (schema-2 shape)
    assert all(r["stats"] is None for r in payload["rows"])

    path = tmp_path / "report.json"
    path.write_text(json.dumps(payload))
    loaded = gate.load_report(path)
    assert loaded == json.loads(json.dumps(payload))
    assert gate.index_rows(loaded) == {"scan/fwd": 123.4, "scan/bwd": 456.7,
                                       "serve/load": 89.1}
    # derived survives intact (split on the first two commas only)
    assert loaded["rows"][2]["derived"] == "ttft=1,qd=2"


def test_gate_loader_rejects_malformed_reports(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"rows": [{"name": "x"}]}))    # no us_per_call
    with pytest.raises(ValueError):
        gate.load_report(p)
    p.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ValueError):
        gate.load_report(p)
    # garbage schema values (non-int, bool, or below the supported range)
    # are corruption, not version skew — plain ValueError, never the
    # forward-compat subclass
    for schema in ("2", None, 0, -1):
        p.write_text(json.dumps({"schema": schema, "rows": []}))
        with pytest.raises(ValueError) as ei:
            gate.load_report(p)
        assert not isinstance(ei.value, gate.UnsupportedSchemaError), schema


# ---------------------------------------------------------------------------
# Forward-compat: a report schema NEWER than the gate knows must warn and
# skip (exit 0), never crash CI — the gate binary that predates a schema
# bump cannot gate the new reports, and a wedged gate blocks every PR.
# ---------------------------------------------------------------------------

def test_loader_raises_typed_error_on_newer_schema(tmp_path):
    p = tmp_path / "future.json"
    p.write_text(json.dumps({"schema": max(gate.SUPPORTED_SCHEMAS) + 1,
                             "rows": []}))
    with pytest.raises(gate.UnsupportedSchemaError) as ei:
        gate.load_report(p)
    assert "newer than this gate supports" in str(ei.value)
    # the subclass is still a ValueError, so pre-existing callers that
    # catch ValueError keep working
    assert isinstance(ei.value, ValueError)


def test_cli_warn_skips_on_newer_current_schema(tmp_path, capsys):
    base = _write(tmp_path, "base.json", BASE)
    future = json.loads(json.dumps(BASE))
    future["schema"] = 99
    future["rows"][0]["us_per_call"] *= 100.0       # would be a regression
    cur = _write(tmp_path, "future.json", future)
    assert gate.main(["--baseline", base, "--current", cur]) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "schema 99" in out and "skipping gate" in out
    assert "REGRESSION" not in out


def test_cli_warn_skips_on_newer_baseline_schema(tmp_path, capsys):
    future = json.loads(json.dumps(BASE))
    future["schema"] = 99
    base = _write(tmp_path, "future_base.json", future)
    cur = _write(tmp_path, "cur.json", BASE)
    assert gate.main(["--baseline", base, "--current", cur]) == 0
    out = capsys.readouterr().out
    assert "WARNING" in out and "skipping gate" in out


def test_update_refuses_to_enshrine_newer_schema(tmp_path, capsys):
    """--update with an unreadable-future current report must warn-skip
    WITHOUT overwriting the baseline."""
    base = _write(tmp_path, "base.json", BASE)
    future = json.loads(json.dumps(BASE))
    future["schema"] = 99
    cur = _write(tmp_path, "future.json", future)
    assert gate.main(["--baseline", base, "--current", cur,
                      "--update"]) == 0
    assert "skipping gate" in capsys.readouterr().out
    assert json.loads(pathlib.Path(base).read_text()) == BASE


def test_duplicate_rung_names_keep_last():
    payload = _payload([("scan/fwd", 1.0, ""), ("scan/fwd", 2.0, "")])
    assert gate.index_rows(payload) == {"scan/fwd": 2.0}
