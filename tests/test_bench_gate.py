"""Benchmark regression gate tests (DESIGN.md §11): the gate must flag a
synthetically injected 2x slowdown under the default tolerance, pass
identical numbers, warn (not fail) on missing baseline entries, and the
``benchmarks.run --json`` payload must round-trip through the gate's
loader."""

import json
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(ROOT))

from benchmarks import gate  # noqa: E402
from benchmarks import run as bench_run  # noqa: E402

pytestmark = pytest.mark.bench


def _payload(rows):
    return {"schema": 1, "smoke": True, "only": [], "failed": [],
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows]}


BASE = _payload([
    ("scan/fwd/128", 5000.0, "row_tile=64"),
    ("scan/bwd/128", 9000.0, ""),
    ("scan/tiny", 10.0, ""),          # below the noise floor
])


def test_identical_numbers_pass():
    res = gate.compare(BASE, BASE)
    assert res.ok
    assert not res.regressions and not res.warnings
    assert res.checked == 2           # the tiny rung is floored out


def test_injected_2x_slowdown_fails():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["us_per_call"] *= 2.0
    res = gate.compare(BASE, cur)     # default tolerance 1.8
    assert not res.ok
    (name, b, c, ratio), = res.regressions
    assert name == "scan/fwd/128"
    assert ratio == pytest.approx(2.0)


def test_improvement_is_not_a_failure():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][1]["us_per_call"] /= 3.0
    res = gate.compare(BASE, cur)
    assert res.ok
    assert [r[0] for r in res.improvements] == ["scan/bwd/128"]


def test_noise_floor_suppresses_tiny_rungs():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][2]["us_per_call"] *= 2.0       # 10us -> 20us: pure noise
    assert gate.compare(BASE, cur).ok
    # but a tiny rung exploding past the floor IS a regression
    cur["rows"][2]["us_per_call"] = 5000.0
    assert not gate.compare(BASE, cur).ok


def test_missing_entries_warn_not_fail():
    cur = _payload([
        ("scan/fwd/128", 5000.0, ""),          # bwd rung retired ...
        ("scan/new_rung", 7000.0, ""),         # ... new rung landed
    ])
    res = gate.compare(BASE, cur)
    assert res.ok
    assert len(res.warnings) == 3              # bwd + tiny missing, new
    assert any("no baseline entry" in w for w in res.warnings)
    assert any("missing from current" in w for w in res.warnings)


def test_tolerance_band_is_configurable():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["us_per_call"] *= 1.5
    assert gate.compare(BASE, cur).ok                       # 1.5 < 1.8
    assert not gate.compare(BASE, cur, tolerance=1.4).ok    # 1.5 > 1.4


# ---------------------------------------------------------------------------
# CLI behaviour (what CI actually invokes).
# ---------------------------------------------------------------------------

def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_cli_exit_codes_and_update(tmp_path, capsys):
    base = _write(tmp_path, "base.json", BASE)
    same = _write(tmp_path, "same.json", BASE)
    assert gate.main(["--baseline", base, "--current", same]) == 0

    slow = json.loads(json.dumps(BASE))
    slow["rows"][0]["us_per_call"] *= 2.0
    cur = _write(tmp_path, "slow.json", slow)
    assert gate.main(["--baseline", base, "--current", cur]) == 1
    assert "REGRESSION" in capsys.readouterr().out

    # --update re-baselines instead of gating, then the same run passes
    assert gate.main(["--baseline", base, "--current", cur,
                      "--update"]) == 0
    assert gate.main(["--baseline", base, "--current", cur]) == 0


# ---------------------------------------------------------------------------
# run.py --json schema round-trip.
# ---------------------------------------------------------------------------

def test_json_payload_roundtrips_through_gate_loader(tmp_path):
    rows = ["scan/fwd,123.4,row_tile=64;ws=1.0",
            "scan/bwd,456.7,",
            "serve/load,89.1,ttft=1,qd=2"]     # derived may contain commas
    payload = bench_run.build_payload(rows, smoke=True, only={"fig3"},
                                      failed=["table1"])
    assert payload["schema"] == bench_run.JSON_SCHEMA == 1
    assert payload["only"] == ["fig3"]
    assert payload["failed"] == ["table1"]

    path = tmp_path / "report.json"
    path.write_text(json.dumps(payload))
    loaded = gate.load_report(path)
    assert loaded == json.loads(json.dumps(payload))
    assert gate.index_rows(loaded) == {"scan/fwd": 123.4, "scan/bwd": 456.7,
                                       "serve/load": 89.1}
    # derived survives intact (split on the first two commas only)
    assert loaded["rows"][2]["derived"] == "ttft=1,qd=2"


def test_gate_loader_rejects_malformed_reports(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"rows": [{"name": "x"}]}))    # no us_per_call
    with pytest.raises(ValueError):
        gate.load_report(p)
    p.write_text(json.dumps({"schema": 99, "rows": []}))
    with pytest.raises(ValueError):
        gate.load_report(p)
    p.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ValueError):
        gate.load_report(p)


def test_duplicate_rung_names_keep_last():
    payload = _payload([("scan/fwd", 1.0, ""), ("scan/fwd", 2.0, "")])
    assert gate.index_rows(payload) == {"scan/fwd": 2.0}
