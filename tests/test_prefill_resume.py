"""Chunked-prefill resume vs one-shot (DESIGN.md §9 / §14).

The serve contract: chaining ``gspn_seq_prefill_chunk`` over any admissible
chunking — all chunks but the last row-aligned, head and tail as ragged as
the contract allows — reproduces the one-shot mixer to 1e-5, output AND
outgoing O(W) cache.  The ScanSpec ``boundary`` leg is pure autotune-cache
policy: forcing any of the three labels through every launch in the chain
must not move a ULP of the result, pinned both through the mixer chain and
directly on ``gspn_scan``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels.ops import gspn_scan
from repro.kernels.spec import BOUNDARIES, ScanSpec

pytestmark = pytest.mark.serve

B, CP, DIM, W = 2, 4, 12, 8

# Admissible chunkings of the token stream (chunk lengths; every chunk but
# the last covers whole grid rows of width W):
CHUNKINGS = {
    "head_single_row_ragged_tail": [W, 3 * W, 2 * W + 3],
    "uneven_rows_tiny_tail": [2 * W, W, W, 5],
    "single_partial_row": [3],              # head == tail, shorter than W
    "tail_on_row_boundary": [W, 2 * W],     # cache must match EXACTLY
    "every_row_its_own_chunk": [W] * 4 + [1],
}


def _fresh_cache(w=W):
    return {"prev_row": jnp.zeros((B, CP, w)),
            "cur_row": jnp.zeros((B, CP, w)),
            "row_state": jnp.zeros((B, CP)),
            "pos": jnp.zeros((B,), jnp.int32)}


def _mixer(w=W, seed=0):
    cfg = G.GSPNSeqConfig(dim=DIM, proxy_dim=CP, row_width=w, impl="xla")
    params = G.init_gspn_seq_mixer(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _chain(params, x, cfg, chunks):
    cache = _fresh_cache(cfg.row_width)
    ys, lo = [], 0
    for t in chunks:
        y, cache = G.gspn_seq_prefill_chunk(params, x[:, lo:lo + t],
                                            cfg, cache)
        ys.append(y)
        lo += t
    return jnp.concatenate(ys, axis=1), cache


@pytest.mark.parametrize("boundary", BOUNDARIES)
@pytest.mark.parametrize("name", sorted(CHUNKINGS))
def test_chunk_chain_equals_oneshot_under_every_boundary(name, boundary,
                                                         monkeypatch):
    """Ragged head/tail resume ≡ one-shot at 1e-5 with EVERY ScanSpec
    boundary label forced through every scan launch in the chain — the
    label keys the autotune cache but must never touch numerics."""
    orig = G._scan_spec_kwargs

    def forced(cfg, mesh, **kw):
        out = orig(cfg, mesh, **kw)
        out["spec"] = out["spec"].with_(boundary=boundary)
        return out

    monkeypatch.setattr(G, "_scan_spec_kwargs", forced)

    chunks = CHUNKINGS[name]
    total = sum(chunks)
    cfg, params = _mixer()
    x = jax.random.normal(jax.random.PRNGKey(hash(name) % 1000),
                          (B, total, DIM))

    ref, ref_cache = G.apply_gspn_seq_mixer(params, x, cfg,
                                            return_cache=True)
    got, cache = _chain(params, x, cfg, chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5, err_msg=name)
    # The outgoing O(W) cache is part of the contract too — a later
    # decode step resumes from it.
    assert int(cache["pos"][0]) == total
    for leg in ("prev_row", "cur_row", "row_state"):
        np.testing.assert_allclose(np.asarray(cache[leg]),
                                   np.asarray(ref_cache[leg]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{name}/{leg}")


def test_head_chunk_as_small_as_the_contract_allows():
    """The minimal admissible HEAD chunk is one grid row (the contract
    forbids a non-final mid-row chunk); one row of state must be enough
    to seed everything downstream."""
    cfg, params = _mixer(seed=7)
    total = 5 * W + 2
    x = jax.random.normal(jax.random.PRNGKey(11), (B, total, DIM))
    ref = G.apply_gspn_seq_mixer(params, x, cfg)
    got, _ = _chain(params, x, cfg, [W, W, W, W, W, 2])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_boundary_label_is_numerically_inert_on_gspn_scan(impl):
    """Directly on the kernel entry: the three boundary labels produce
    BITWISE-identical forwards (and matching grads) — boundary is cache
    policy, not a numeric knob."""
    g, h, w = 4, 12, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (g, h, w, 3)))

    outs, grads = [], []
    for bnd in BOUNDARIES:
        sp = ScanSpec(impl=impl, boundary=bnd)
        fn = lambda *a, sp=sp: gspn_scan(*a, spec=sp)
        outs.append(np.asarray(fn(x, wl, wc, wr, lam)))
        grads.append(np.asarray(jax.grad(
            lambda *a: jnp.sum(jnp.sin(fn(*a))))(x, wl, wc, wr, lam)))
    for o, gr in zip(outs[1:], grads[1:]):
        np.testing.assert_array_equal(o, outs[0])
        np.testing.assert_array_equal(gr, grads[0])
