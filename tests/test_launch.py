"""Launcher/dry-run path on a small fake mesh (subprocess: 8 devices)."""

import json
import os
import subprocess
import sys
import tempfile
import textwrap


def test_dryrun_cell_builds_and_compiles_small_mesh():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, dataclasses
        import jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs.base import get_arch, input_specs, ShapeSpec
        from repro.models import lm as lm_mod
        from repro.parallel import sharding as shd
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.step import build_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = dataclasses.replace(get_arch("qwen2-1.5b").reduced(),
                                  n_model_shards=2)
        shape = ShapeSpec("tiny", "train", 64, 8)
        ap = jax.eval_shape(lambda k: lm_mod.init_lm(k, cfg),
                            jax.random.PRNGKey(0))
        ps = shd.param_shardings(ap, mesh)
        batch = input_specs(cfg, shape)
        bs = shd.batch_shardings(batch, mesh, ("data",))
        ocfg = AdamWConfig()
        astate = jax.eval_shape(
            lambda p: {"params": p, "opt": adamw_init(ocfg, p)}, ap)
        ssh = {"params": ps, "opt": {"m": ps, "v": ps,
               "step": NamedSharding(mesh, P())}}
        step = build_train_step(cfg, ocfg, mesh=mesh, dp_axes=("data",),
                                grad_accum=2)
        with set_mesh(mesh):
            c = jax.jit(step, in_shardings=(ssh, bs),
                        out_shardings=(ssh, None),
                        donate_argnums=(0,)).lower(astate, batch).compile()
        m = c.memory_analysis()
        assert m.temp_size_in_bytes > 0
        cost = c.cost_analysis()
        if isinstance(cost, (list, tuple)):   # one dict per device, old jax
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=560)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr}"
    assert "OK" in r.stdout


def test_production_mesh_shapes():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import (make_production_mesh, dp_axes_for,
                                       seq_axis_size)
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model")
        assert m1.devices.shape == (16, 16)
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.shape == (2, 16, 16)
        assert dp_axes_for(m2) == ("pod", "data")
        assert seq_axis_size(m2) == 1
        m3 = make_production_mesh(seq_parallel=4)
        assert m3.axis_names == ("data", "seq", "model")
        assert m3.devices.shape == (4, 4, 16)
        assert seq_axis_size(m3) == 4 and dp_axes_for(m3) == ("data",)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr}"


def test_cell_matrix_covers_assignment():
    from repro.launch.dryrun import cell_matrix
    cells = cell_matrix()
    lm_cells = [c for c in cells if c[0] == "lm"]
    # 11 archs (10 assigned + 1 beyond-paper) × 4 shapes
    assert len(lm_cells) == 44
    skips = [c for c in lm_cells if c[3] is not None]
    assert len(skips) == 8            # long_500k × full-attention archs
    assert all(c[2] == "long_500k" for c in skips)
    vision = [c for c in cells if c[0] == "vision"]
    assert len(vision) == 2


def test_roofline_analysis_reads_records():
    from repro.roofline.analysis import analyze_dir, markdown_table
    rec = {
        "arch": "qwen2-1.5b", "shape": "train_4k", "mesh": "single",
        "status": "ok", "n_devices": 256,
        "meta": {"arch": "qwen2-1.5b", "shape": "train_4k",
                 "kind": "train", "family": "dense",
                 "seq_len": 4096, "global_batch": 256},
        "flops": 1e15, "bytes_hbm": 1e13, "bytes_hbm_calibrated": 8e12,
        "collectives": {"total": 1e11, "all-reduce": 1e11, "count": 3},
    }
    with tempfile.TemporaryDirectory() as d:
        with open(os.path.join(d, "x__train_4k__single.json"), "w") as f:
            json.dump(rec, f)
        rows, skips, errors = analyze_dir(d, "single")
    assert len(rows) == 1 and not errors
    r = rows[0]
    assert r.dominant == "memory"           # 8e12/819e9 > 1e15/197e12
    assert 0 < r.useful_ratio < 10
    assert "qwen2-1.5b" in markdown_table(rows)
