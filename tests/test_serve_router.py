"""Serving tier: router placement, replica failure drain, prefix/state
reuse, and the handle API (DESIGN.md §15).

The prefix-cache contract is the §9/§14 resume contract one level up:
state stored at a chunk-aligned fold boundary and resumed through
``lm_prefill_chunk`` must reproduce the cold path exactly — pinned here
both at the mixer level (through :class:`PrefixStateCache` round-trip)
and end-to-end through two engines sharing one cache.  Router tests run
the sync tick path so placement and drain order are deterministic.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import gspn as G
from repro.models.lm import init_lm
from repro.serve import engine as engine_mod
from repro.serve.cache import PrefixStateCache
from repro.serve.engine import Request, ServeEngine, drive
from repro.serve.router import Router
from test_prefill_resume import B, DIM, W, _fresh_cache, _mixer
from test_serve_engine import _gspn_cfg

pytestmark = pytest.mark.serve


def _params(cfg):
    return init_lm(jax.random.PRNGKey(0), cfg)


def _reqs(n, plen, vocab=64, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, vocab, plen),
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# Prefix/state reuse — the §15 headline invariant.
# ---------------------------------------------------------------------------

def test_prefix_cache_roundtrip_resume_equals_oneshot():
    """Mixer level: chain a prefix to a fold boundary, round-trip the
    boundary state through PrefixStateCache (insert + descending-probe
    lookup), resume the remainder from the looked-up copy — output AND
    final O(W) cache must match the one-shot mixer to 1e-5."""
    cfg, params = _mixer(seed=3)
    total, k = 5 * W + 3, 3 * W
    x = jax.random.normal(jax.random.PRNGKey(5), (B, total, DIM))
    prompt = np.arange(total, dtype=np.int32)     # cache identity tokens

    ref, ref_cache = G.apply_gspn_seq_mixer(params, x, cfg,
                                            return_cache=True)

    # prefix chain to the boundary, then store
    cache = _fresh_cache()
    y1, cache = G.gspn_seq_prefill_chunk(params, x[:, :k], cfg, cache)
    pfx = PrefixStateCache()
    pfx.insert(prompt[:k], cache)

    # lookup probes 5W and 4W (misses) before hitting the 3W entry
    hit = pfx.lookup(prompt, chunk=W)
    assert hit is not None and hit[0] == k
    y2, end_cache = G.gspn_seq_prefill_chunk(params, x[:, k:], cfg, hit[1])

    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert int(end_cache["pos"][0]) == total
    for leg in ("prev_row", "cur_row", "row_state"):
        np.testing.assert_allclose(np.asarray(end_cache[leg]),
                                   np.asarray(ref_cache[leg]),
                                   rtol=1e-5, atol=1e-5, err_msg=leg)


def test_engine_prefix_hit_tokens_equal_cold():
    """End-to-end: a forced prefix-cache hit (second engine, shared
    cache, identical prompt) must emit exactly the cold engine's tokens,
    reporting the reused-token count on the Result."""
    cfg = _gspn_cfg()
    params = _params(cfg)
    prompt = np.random.default_rng(7).integers(0, 64, 40)
    req = lambda: Request(uid=0, prompt=prompt, max_new_tokens=6)

    def run(eng):
        h = eng.submit(req())
        eng.run()
        return h.result()

    cold = run(ServeEngine(params, cfg, batch_size=2, max_len=64,
                           prefill_chunk=16))
    pfx = PrefixStateCache()
    warmer = run(ServeEngine(params, cfg, batch_size=2, max_len=64,
                             prefill_chunk=16, prefix_cache=pfx))
    assert warmer.cached_tokens == 0 and len(pfx) > 0   # miss, then filled
    hits0 = obs.counter("serve_prefix_hits_total").value
    warm = run(ServeEngine(params, cfg, batch_size=2, max_len=64,
                           prefill_chunk=16, prefix_cache=pfx))
    # longest aligned proper prefix of a 40-token prompt at chunk 16
    assert warm.cached_tokens == 32
    assert obs.counter("serve_prefix_hits_total").value == hits0 + 1
    assert warm.tokens == cold.tokens == warmer.tokens


def test_prefix_cache_alignment_and_proper_prefix_cap():
    """``lookup`` only returns chunk-aligned offsets, capped strictly
    below the prompt length (the final chunk must produce logits)."""
    tree = {"s": jnp.zeros((1, 2))}
    pfx = PrefixStateCache()
    toks = np.arange(64, dtype=np.int32)
    for k in (16, 32, 48, 64):
        pfx.insert(toks[:k], tree)
    # full 64-token entry exists but a 64-token prompt may only reuse 48
    assert pfx.lookup(toks, chunk=16)[0] == 48
    assert pfx.lookup(toks[:33], chunk=16)[0] == 32
    assert pfx.lookup(toks[:15], chunk=16) is None      # shorter than chunk
    assert pfx.lookup(np.arange(100, 140, dtype=np.int32), 16) is None


def test_prefix_cache_lru_eviction_and_refresh():
    tree = {"s": jnp.zeros(())}
    pfx = PrefixStateCache(capacity=2)
    a, b, c = (np.full(8, i, np.int32) for i in range(3))
    pfx.insert(a, tree)
    pfx.insert(b, tree)
    pfx.insert(a, tree)                  # refresh: a becomes most-recent
    pfx.insert(c, tree)                  # evicts b, the LRU entry
    assert len(pfx) == 2
    assert pfx.lookup(np.concatenate([b, b[:1]]), 8) is None
    assert pfx.lookup(np.concatenate([a, a[:1]]), 8)[0] == 8


def test_prefix_cache_verifies_tokens_not_just_hash():
    """A poisoned entry (right key, wrong stored tokens — what a hash
    collision would look like) must degrade to a miss, never to wrong
    state."""
    pfx = PrefixStateCache()
    good = np.arange(8, dtype=np.int32)
    pfx.insert(good, {"s": jnp.ones(())})
    other = np.arange(100, 109, dtype=np.int32)
    key = pfx._key(other[:8])
    pfx._entries[key] = (good, {"s": jnp.ones(())})   # simulated collision
    assert pfx.lookup(other, 8) is None


# ---------------------------------------------------------------------------
# Handle API + legacy delivery shims.
# ---------------------------------------------------------------------------

def test_handle_lifecycle_and_legacy_results_dict():
    cfg = _gspn_cfg()
    eng = ServeEngine(_params(cfg), cfg, batch_size=2, max_len=32)
    h = eng.submit(Request(uid=9, prompt=np.arange(6), max_new_tokens=4))
    assert h.status == "queued" and not h.done
    with pytest.raises(RuntimeError, match="queued"):
        h.result()
    eng.run()
    assert h.done and h.result().uid == 9 and h.result().tokens
    assert h.result().t_finish >= h.result().t_submit > 0.0
    # hookless engines still fill the legacy results dict
    assert eng.results[9] is h.result()


def test_on_finish_shim_warns_once_and_delivers(monkeypatch):
    monkeypatch.setattr(engine_mod, "_on_finish_warned", False)
    cfg = _gspn_cfg()
    params = _params(cfg)
    got = []
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = ServeEngine(params, cfg, batch_size=2, max_len=32,
                          on_finish=got.append)
        ServeEngine(params, cfg, batch_size=2, max_len=32,
                    on_finish=lambda r: None)
        deprecations = [x for x in w
                        if issubclass(x.category, DeprecationWarning)
                        and "on_finish" in str(x.message)]
    assert len(deprecations) == 1
    h = eng.submit(Request(uid=1, prompt=np.arange(5), max_new_tokens=3))
    eng.run()
    # callback delivery still works, results dict stays empty, and the
    # handle observes the same Result object
    assert [r.uid for r in got] == [1]
    assert not eng.results and h.result() is got[0]


# ---------------------------------------------------------------------------
# Router placement policies (sync mode — deterministic).
# ---------------------------------------------------------------------------

def _router(n, cfg, params, **kw):
    engines = [ServeEngine(params, cfg, batch_size=2, max_len=64,
                           prefill_chunk=16, seed=i) for i in range(n)]
    return Router(engines, **kw)


def test_least_loaded_balances_placement():
    cfg = _gspn_cfg()
    router = _router(2, cfg, _params(cfg), policy="least_loaded")
    handles = [router.submit(r) for r in _reqs(4, plen=12)]
    placed = sorted(h.replica for h in handles)
    assert placed == [0, 0, 1, 1]        # strict alternation before ticks
    router.run()
    assert all(h.done for h in handles)


def test_ttft_policy_routes_around_queued_work():
    """With one 48-token (3-chunk) prompt parked on replica 0, the
    TTFT-predictive policy sends subsequent 1-chunk prompts to replica 1
    until its work-ahead catches up — strict least_loaded would have
    bounced back to replica 0 on the tie."""
    cfg = _gspn_cfg()
    router = _router(2, cfg, _params(cfg), policy="ttft")
    big = router.submit(Request(uid=100, prompt=np.arange(48) % 64,
                                max_new_tokens=4))
    assert big.replica == 0
    small = [router.submit(r) for r in _reqs(3, plen=8, seed=1)]
    assert [h.replica for h in small] == [1, 1, 1]
    router.run()
    assert all(h.done for h in small) and big.done


def test_ttft_slo_risk_is_counted():
    cfg = _gspn_cfg()
    params = _params(cfg)
    # make sure the per-chunk histogram has samples so the predictor
    # yields seconds (not the pure work-ahead fallback)
    warm = ServeEngine(params, cfg, batch_size=2, max_len=64,
                       prefill_chunk=16)
    warm.submit(Request(uid=0, prompt=np.arange(40) % 64, max_new_tokens=2))
    warm.run()
    assert obs.histogram("serve_prefill_chunk_seconds").count > 0

    router = _router(2, cfg, params, policy="ttft", slo_ttft=0.0)
    risk0 = obs.counter("router_slo_at_risk_total").value
    h = router.submit(Request(uid=1, prompt=np.arange(40) % 64,
                              max_new_tokens=2))
    assert obs.counter("router_slo_at_risk_total").value == risk0 + 1
    router.run()                         # at-risk admissions still serve
    assert h.done


def test_unknown_policy_rejected():
    cfg = _gspn_cfg()
    with pytest.raises(ValueError, match="unknown router policy"):
        _router(1, cfg, _params(cfg), policy="round_robin")


# ---------------------------------------------------------------------------
# Replica failure: drain to survivors under the same handles.
# ---------------------------------------------------------------------------

def test_failed_replica_drains_to_survivor_same_handles():
    cfg = _gspn_cfg()
    params = _params(cfg)

    reqs = _reqs(6, plen=24, max_new=5, seed=2)
    ref_eng = ServeEngine(params, cfg, batch_size=2, max_len=64,
                          prefill_chunk=16)
    ref = {}
    for r in reqs:
        h = ref_eng.submit(Request(uid=r.uid, prompt=r.prompt,
                                   max_new_tokens=r.max_new_tokens))
        ref_eng.run()
        ref[r.uid] = h.result().tokens
        ref_eng.reset()

    router = _router(2, cfg, params, policy="least_loaded")
    handles = [router.submit(r) for r in reqs]
    for _ in range(3):                   # admit + progress work on both
        router.tick()
    assert any(h.replica == 0 for h in handles)
    requeued = router.fail_replica(0)
    assert requeued > 0
    assert obs.gauge("router_replicas_alive").value == 1
    router.run()
    # the SAME handle objects finish, all on the survivor, and the
    # restarted requests reproduce the single-engine reference tokens
    # (greedy decode — drain restarts must not perturb outputs)
    assert all(h.done and h.replica == 1 for h in handles)
    for r in reqs:
        res = next(h.result() for h in handles if h.uid == r.uid)
        assert res.tokens == ref[r.uid], r.uid


def test_last_replica_failure_refuses_to_drop_work():
    cfg = _gspn_cfg()
    router = _router(1, cfg, _params(cfg), policy="least_loaded")
    router.submit(_reqs(1, plen=8)[0])
    with pytest.raises(RuntimeError, match="no survivors"):
        router.fail_replica(0)
    with pytest.raises(RuntimeError, match="no alive replicas"):
        router.submit(_reqs(1, plen=8, seed=3)[0])


def test_threaded_router_completes_under_drive():
    cfg = _gspn_cfg()
    router = _router(2, cfg, _params(cfg), policy="least_loaded",
                     threaded=True)
    reqs = _reqs(6, plen=12, max_new=4, seed=4)
    router.start()
    try:
        _dt, handles = drive(router, reqs, np.zeros(len(reqs)))
    finally:
        router.stop()
    assert len(handles) == 6 and all(h.done for h in handles)
    assert {h.uid for h in handles} == {r.uid for r in reqs}
