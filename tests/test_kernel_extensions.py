"""Beyond-paper kernel extensions: fused dual-direction scan (§4.3 stream
concurrency analogue) and the VMEM-aware tile tuner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels import ref as R
from repro.kernels.gspn_multidir import gspn_scan_bidir_pallas
from repro.kernels.tuning import (VMEM_BYTES, pick_row_tile,
                                  scan_working_set)

pytestmark = pytest.mark.kernels


@pytest.mark.parametrize("shape,cpw", [((4, 16, 24), 2), ((2, 8, 128), 1),
                                       ((6, 32, 16), 3)])
def test_bidir_kernel_matches_per_direction(shape, cpw):
    gd, h, w = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (gd, h, w))
    lam2 = jax.random.normal(ks[1], (2, gd, h, w))
    wl0, wc0, wr0 = G.normalize_taps(
        jax.random.normal(ks[2], (gd // cpw, h, w, 3)))
    wl1, wc1, wr1 = G.normalize_taps(
        jax.random.normal(ks[3], (gd // cpw, h, w, 3)))
    taps = {"wl": jnp.stack([wl0, wl1]), "wc": jnp.stack([wc0, wc1]),
            "wr": jnp.stack([wr0, wr1])}
    out = gspn_scan_bidir_pallas(x, taps, lam2, channels_per_weight=cpw,
                                 row_tile=4)
    ref_tb = R.gspn_scan_ref(x, wl0, wc0, wr0, lam2[0])
    ref_bt = jnp.flip(R.gspn_scan_ref(
        jnp.flip(x, 1), jnp.flip(wl1, 1), jnp.flip(wc1, 1),
        jnp.flip(wr1, 1), jnp.flip(lam2[1], 1)), 1)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref_tb),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref_bt),
                               rtol=1e-5, atol=1e-5)


def test_tile_tuner_respects_budget_and_divisibility():
    for h, w in [(4096, 1024), (1024, 512), (224, 224), (48, 64)]:
        tc = pick_row_tile(h, w, 4)
        assert h % tc.row_tile == 0
        assert tc.working_set_bytes <= VMEM_BYTES or tc.row_tile == 1
        assert tc.n_grid_steps * tc.row_tile == h


def test_tile_tuner_shrinks_with_width():
    wide = pick_row_tile(4096, 16384, 4)
    narrow = pick_row_tile(4096, 256, 4)
    assert wide.row_tile <= narrow.row_tile
    assert scan_working_set(wide.row_tile, 16384, 4) <= VMEM_BYTES
