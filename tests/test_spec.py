"""Unit pins for the ScanSpec value type (DESIGN.md §14).

The spec is load-bearing in three ways — custom_vjp nondiff argument
(hashability), autotune cache key (canonical serialization), and test
enumerator (grid shape) — so its invariants are pinned directly rather
than inferred from the integration suites.
"""

import dataclasses

import jax.numpy as jnp
import pytest

from repro.kernels import autotune
from repro.kernels.spec import (BOUNDARIES, DIRECTIONS, IMPLS, ScanSpec,
                                canonical_key, enumerate_specs)

pytestmark = pytest.mark.kernels


def test_defaults_and_derived_views():
    sp = ScanSpec()
    assert sp.direction == "fwd" and sp.impl == "auto"
    assert sp.boundary == "one_shot" and sp.interpret
    assert not sp.channel_shared and sp.channel_mode == "per_channel"
    assert sp.stream_bytes == 4
    assert ScanSpec(channels_per_weight=4).channel_mode == "shared"
    assert ScanSpec(stream_dtype="bfloat16").stream_bytes == 2


def test_frozen_and_hashable():
    sp = ScanSpec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        sp.impl = "pallas"
    # Equal specs collapse to one dict/cache slot.
    assert {sp: 1, ScanSpec(): 2} == {ScanSpec(): 2}
    assert hash(ScanSpec(stream_dtype="float32")) == \
        hash(ScanSpec(stream_dtype=jnp.float32))


def test_dtype_spellings_normalise():
    """Any dtype spelling collapses to the canonical numpy name, so the
    cache key never splits on spelling."""
    for spelling in ("float32", jnp.float32, "f4", "<f4"):
        assert ScanSpec(stream_dtype=spelling).stream_dtype == "float32"
    assert ScanSpec(carry_dtype=jnp.bfloat16).carry_dtype == "bfloat16"


@pytest.mark.parametrize("bad", [
    dict(direction="diagonal"),
    dict(impl="cuda"),
    dict(boundary="wraparound"),
    dict(channels_per_weight=0),
    dict(channels_per_weight="4"),
    dict(row_tile=0),
    dict(row_tile=2.0),
    dict(pipeline_depth=3),
    dict(stream_dtype="notadtype"),
    dict(carry_dtype=object()),
])
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        ScanSpec(**bad)


def test_with_revalidates():
    sp = ScanSpec()
    assert sp.with_(impl="pallas").impl == "pallas"
    assert sp.with_(impl="pallas") is not sp
    with pytest.raises(ValueError):
        sp.with_(direction="sideways")


def test_adjoint():
    assert ScanSpec(direction="fwd").adjoint().direction == "bwd"
    pa = ScanSpec(direction="pair_fwd", carry_dtype="bfloat16").adjoint()
    assert pa.direction == "pair_bwd"
    assert pa.carry_dtype == "float32"          # adjoint carry is f32
    for d in ("bwd", "pair_bwd", "quad"):
        with pytest.raises(ValueError):
            ScanSpec(direction=d).adjoint()


def test_canonical_and_spec_id():
    sp = ScanSpec(direction="fwd", impl="pallas", channels_per_weight=3,
                  stream_dtype="bfloat16", carry_dtype="float32",
                  row_tile=8, pipeline_depth=2, boundary="chunk_resume")
    assert sp.canonical() == canonical_key(
        "fwd", "pallas", "bfloat16", "float32", True, "chunk_resume")
    assert sp.canonical() == \
        "fwd|pallas|bfloat16|carry-float32|cs1|bnd-chunk_resume"
    assert sp.spec_id() == sp.canonical() + "|cpw3|t8|d2|interp"
    # tile/depth/interpret are launch mechanics, not cache policy.
    assert sp.with_(row_tile=None, pipeline_depth=None).canonical() == \
        sp.canonical()


def test_scan_key_encoding_ends_with_spec_canonical():
    """The tentpole contract: the schema-3 autotune cache key IS the
    device/shape legs + the spec's canonical serialization."""
    sp = ScanSpec(direction="pair_fwd", impl="multidir",
                  channels_per_weight=2, stream_dtype="bfloat16",
                  boundary="sp_block_local")
    key = autotune.ScanKey("cpu-interp", 64, 32, 8, sp.direction, sp.impl,
                           sp.stream_dtype, sp.carry_dtype,
                           sp.channel_shared, sp.boundary)
    assert key.encode().endswith(sp.canonical())
    assert key.encode() == "cpu-interp|h64|w32|c8|" + sp.canonical()


def test_enumerate_specs_shape():
    specs = enumerate_specs()
    assert len(specs) == 44 and len(set(specs)) == 44
    # Dispatch matrix: fwd→pallas/xla, pair_fwd→multidir/xla, quad→multidir.
    by_dir = {}
    for s in specs:
        by_dir.setdefault(s.direction, set()).add(s.impl)
    assert by_dir == {"fwd": {"pallas", "xla"},
                      "pair_fwd": {"multidir", "xla"},
                      "quad": {"multidir"}}
    # Boundary/cpw axes expand the grid multiplicatively.
    assert len(enumerate_specs(boundaries=BOUNDARIES)) == 3 * 44
    assert len(enumerate_specs(cpws=(1,))) == 22
    # Everything emitted is admissible by construction.
    for s in specs:
        assert s.direction in DIRECTIONS and s.impl in IMPLS
        assert s.boundary in BOUNDARIES
