"""Multi-device behaviour (8 fake CPU devices in subprocesses via the
``run_sub`` conftest fixture, so the rest of the suite keeps a single
device): MoE shard_map equivalence, pipeline parallel, int8-EF compressed
all-reduce (incl. the all-zero-shard guard), fault-tolerant + elastic
trainer, sharded-vs-single-device train-step numerics."""

import pytest

pytestmark = pytest.mark.distributed


def test_moe_shard_map_matches_local(run_sub):
    run_sub("""
        from repro.models.moe import MoEConfig, init_moe, apply_moe
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg4 = MoEConfig(dim=16, n_experts=8, top_k=2, d_ff=32, n_shards=4,
                         capacity_factor=8.0)
        cfg1 = MoEConfig(dim=16, n_experts=8, top_k=2, d_ff=32, n_shards=1,
                         capacity_factor=8.0)
        p4 = init_moe(jax.random.PRNGKey(0), cfg4)
        g = jnp.concatenate([p4["gate_slab"][m] for m in range(4)], 0)[None]
        u = jnp.concatenate([p4["up_slab"][m] for m in range(4)], 0)[None]
        d = jnp.concatenate([p4["down_slab"][m] for m in range(4)], 0)[None]
        p1 = {"router": p4["router"], "gate_slab": g, "up_slab": u,
              "down_slab": d}
        y_ref, _ = apply_moe(p1, x, cfg1)
        with set_mesh(mesh):
            y4, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg4, mesh=mesh,
                                                   dp_axes=("data",)))(p4, x)
        np.testing.assert_allclose(np.array(y4, np.float32),
                                   np.array(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
    """)


def test_moe_tp_split_experts(run_sub):
    run_sub("""
        from repro.models.moe import MoEConfig, init_moe, apply_moe
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg_tp = MoEConfig(dim=16, n_experts=2, top_k=1, d_ff=32,
                           n_shards=4, capacity_factor=4.0)
        ptp = init_moe(jax.random.PRNGKey(2), cfg_tp)
        gt = jnp.stack([jnp.concatenate([ptp["gate_slab"][2*e+t, 0]
                        for t in range(2)], -1) for e in range(2)])[None]
        ut = jnp.stack([jnp.concatenate([ptp["up_slab"][2*e+t, 0]
                        for t in range(2)], -1) for e in range(2)])[None]
        dt = jnp.stack([jnp.concatenate([ptp["down_slab"][2*e+t, 0]
                        for t in range(2)], 0) for e in range(2)])[None]
        cfg1 = MoEConfig(dim=16, n_experts=2, top_k=1, d_ff=64, n_shards=1,
                         capacity_factor=4.0)
        p1 = {"router": ptp["router"], "gate_slab": gt, "up_slab": ut,
              "down_slab": dt}
        y_ref, _ = apply_moe(p1, x, cfg1)
        with set_mesh(mesh):
            y, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg_tp, mesh=mesh,
                                                  dp_axes=("data",)))(ptp, x)
        np.testing.assert_allclose(np.array(y, np.float32),
                                   np.array(y_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
    """)


def test_pipeline_matches_sequential(run_sub):
    run_sub("""
        from repro.parallel.pipeline import pipeline_apply
        pmesh = make_mesh((4,), ("pipe",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 2, 16))
        with set_mesh(pmesh):
            y = pipeline_apply(pmesh, "pipe",
                               lambda w, x: jnp.tanh(x @ w["w"]),
                               {"w": ws}, x, n_micro=6)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.array(y), np.array(ref),
                                   rtol=1e-5, atol=1e-5)
    """)


def test_compressed_allreduce_and_error_feedback(run_sub):
    run_sub("""
        from repro.parallel.collectives import compressed_allreduce
        cmesh = make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(2), (8, 32, 16))
        e = jnp.zeros((8, 32, 16))
        exact = g.mean(axis=0)
        with set_mesh(cmesh):
            fn = jax.jit(compressed_allreduce(cmesh, "pod"))
            gh, ee = fn(g, e)
            err1 = float(jnp.abs(gh - exact).max() / jnp.abs(exact).max())
            acc = jnp.zeros_like(exact)
            for _ in range(20):
                gh, ee = fn(g, ee)
                acc = acc + gh
            errT = float(jnp.abs(acc / 20 - exact).max()
                         / jnp.abs(exact).max())
        assert err1 < 0.15, err1
        assert errT < err1 / 5, (err1, errT)
    """)


def test_compressed_allreduce_all_zero_shards(run_sub):
    """Regression: an all-zero gradient (every shard) must dequantise to
    exact finite zeros — the shared-scale path used to lean on a 1e-12
    floor whose reciprocal amplifies by ~1e14 (collectives._compress_one
    guard).  Also checks the mixed case (one zero shard among live ones)
    and that error feedback stays zero, not denormal garbage."""
    run_sub("""
        from repro.parallel.collectives import compressed_allreduce
        cmesh = make_mesh((8,), ("pod",))
        fn = jax.jit(compressed_allreduce(cmesh, "pod"))
        z = jnp.zeros((8, 16, 8))
        gh, ee = fn(z, jnp.zeros_like(z))
        assert np.isfinite(np.array(gh)).all()
        np.testing.assert_array_equal(np.array(gh), 0.0)
        np.testing.assert_array_equal(np.array(ee), 0.0)

        g = jnp.zeros((8, 16, 8)).at[1:].set(
            jax.random.normal(jax.random.PRNGKey(0), (7, 16, 8)))
        gh, ee = fn(g, jnp.zeros_like(g))
        exact = g.mean(axis=0)
        assert np.isfinite(np.array(gh)).all()
        err = float(jnp.abs(gh - exact).max() / jnp.abs(exact).max())
        assert err < 0.15, err
    """)


def test_trainer_fault_tolerance_and_elastic(run_sub):
    run_sub("""
        import tempfile, logging
        logging.disable(logging.WARNING)
        from repro.models.lm import LMConfig
        from repro.optim.adamw import AdamWConfig
        from repro.data.pipeline import DataConfig
        from repro.train.trainer import (Trainer, ElasticTrainer,
                                         TrainerConfig)
        cfg = LMConfig(name="d", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                       unit=(("attn", 2),), n_units=1, remat="none")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
        dcfg = DataConfig(vocab=256, seq_len=32, global_batch=8)
        mesh = make_mesh((4, 2), ("data", "model"))
        fails = {7, 13}
        def injector(step):
            if step in fails:
                fails.discard(step)
                raise RuntimeError("injected")
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, ocfg, dcfg,
                         TrainerConfig(ckpt_dir=d, ckpt_every=5,
                                       log_every=1000),
                         mesh=mesh, failure_injector=injector)
            hist = tr.run(20)
        assert tr.recoveries == 2 and tr.step == 20
        assert hist[-1] < hist[0], (hist[0], hist[-1])

        polls = [jax.devices(), jax.devices()[:4], jax.devices()[:4]]
        def monitor():
            return polls[0] if len(polls) == 1 else polls.pop(0)
        def builder(devs):
            return make_mesh((len(devs)//2, 2), ("data", "model"),
                             devices=devs)
        with tempfile.TemporaryDirectory() as d:
            tr = ElasticTrainer(cfg, ocfg, dcfg,
                                TrainerConfig(ckpt_dir=d, ckpt_every=5,
                                              log_every=1000),
                                mesh=mesh, device_monitor=monitor,
                                mesh_builder=builder)
            tr.run(20, remesh_every=8)
        assert tr.step == 20 and tr.mesh.devices.size == 4
    """)


def test_sharded_train_step_matches_single_device(run_sub):
    run_sub("""
        import functools
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.lm import LMConfig, init_lm
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.step import build_train_step
        from repro.parallel import sharding as shd
        cfg = LMConfig(name="d", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                       unit=(("attn", 2),), n_units=1, remat="none")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
        params = init_lm(jax.random.PRNGKey(0), cfg)
        state = {"params": params, "opt": adamw_init(ocfg, params)}
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
        batch = {"tokens": toks, "labels": toks}
        s_ref, m_ref = build_train_step(cfg, ocfg)(state, batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        ps = shd.param_shardings(params, mesh)
        ssh = {"params": ps, "opt": {"m": ps, "v": ps,
               "step": NamedSharding(mesh, P())}}
        bs = shd.batch_shardings(batch, mesh, ("data",))
        with set_mesh(mesh):
            step = jax.jit(build_train_step(cfg, ocfg, mesh=mesh,
                                            dp_axes=("data",)),
                           in_shardings=(ssh, bs),
                           out_shardings=(ssh, None))
            s_sh, m_sh = step(state, batch)
        assert abs(float(m_sh["loss"]) - float(m_ref["loss"])) < 2e-2
        for a, b in zip(jax.tree.leaves(s_sh["params"]),
                        jax.tree.leaves(s_ref["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=3e-2, atol=3e-3)
    """)
