"""Observability subsystem tests (DESIGN.md §13): tracing semantics
(no-op fast path, ring bound, thread interleaving), Chrome trace-event
schema, metrics-registry edge cases (inclusive bucket bounds, Prometheus
export), the serve-engine instrumentation contract, the autotune plan
funnel, schema-2 benchmark stats, the report CLI — and the pin that
keeps disabled tracing under 2% of a decode step."""

import json
import pathlib
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels import autotune
from repro.kernels.spec import ScanSpec
from repro.obs import report

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(ROOT))

pytestmark = pytest.mark.obs


@pytest.fixture(autouse=True)
def _obs_reset():
    """Process-global state (trace buffer, registry, resolved-plan map)
    starts and ends clean for every test."""
    saved_plans = dict(autotune._RESOLVED)
    obs.disable()
    obs.clear()
    obs.REGISTRY.reset()
    autotune._RESOLVED.clear()
    yield
    obs.disable()
    obs.clear()
    obs.REGISTRY.reset()
    autotune._RESOLVED.clear()
    autotune._RESOLVED.update(saved_plans)


# ---------------------------------------------------------------------------
# Tracing core.
# ---------------------------------------------------------------------------

def test_disabled_trace_is_a_shared_noop_singleton():
    assert obs.trace("a") is obs.trace("b", x=1) is obs.NOOP_SPAN
    with obs.trace("a", x=1) as sp:
        sp.set(y=2)                     # annotating a noop is legal
    obs.event("e", x=1)
    obs.async_begin("request", 1)
    obs.async_end("request", 1)
    assert obs.records() == []          # nothing touched the buffer
    obs.enable()
    assert obs.trace("a") is not obs.NOOP_SPAN


def test_span_records_duration_and_late_attrs():
    obs.enable()
    with obs.trace("phase", size=3) as sp:
        sp.set(plan="fwd:t64-d1")
    (rec,) = obs.spans("phase")
    assert rec.ph == "X" and rec.dur >= 0
    assert rec.args == {"size": 3, "plan": "fwd:t64-d1"}
    assert obs.spans("other") == []


def test_ring_buffer_bounds_memory_keeping_newest():
    obs.enable(ring=8)
    for i in range(20):
        obs.event("e", i=i)
    recs = obs.records()
    assert len(recs) == 8
    assert [r.args["i"] for r in recs] == list(range(12, 20))


def test_threaded_spans_interleave_and_nest_per_thread():
    obs.enable()
    n_threads, n_iters = 6, 25

    def work(i):
        for j in range(n_iters):
            with obs.trace("outer", worker=i):
                with obs.trace("inner", worker=i, j=j):
                    pass

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    outer, inner = obs.spans("outer"), obs.spans("inner")
    assert len(outer) == len(inner) == n_threads * n_iters
    # nesting is reconstructed from (tid, ts, dur) intervals: every inner
    # span must lie inside an outer interval recorded by ITS OWN thread
    by_tid = {}
    for r in outer:
        by_tid.setdefault(r.tid, []).append((r.ts, r.ts + r.dur))
    for r in inner:
        assert any(a <= r.ts and r.ts + r.dur <= b
                   for a, b in by_tid[r.tid]), "inner escaped its outer"


def test_chrome_trace_event_schema(tmp_path):
    obs.enable()
    with obs.trace("serve.decode_step", batch=2):
        pass
    obs.event("request.queued", uid=7)
    obs.async_begin("request", 7, prompt_tokens=3)
    obs.async_end("request", 7, finish_reason="eos")
    payload = json.loads(json.dumps(obs.chrome_trace()))  # serialisable
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    assert [e["ph"] for e in evs] == ["X", "i", "b", "e"]
    for e in evs:
        assert {"ph", "name", "pid", "tid", "ts", "cat"} <= set(e)
        assert isinstance(e["ts"], float) and e["ts"] >= 0.0  # µs from epoch
    x, i, b, e = evs
    assert "dur" in x and x["dur"] >= 0.0 and x["args"]["batch"] == 2
    assert i["args"]["uid"] == 7 and "dur" not in i
    for ev in (b, e):                   # async pairs: string id, own cat
        assert ev["id"] == "7" and ev["cat"] == "request"
    # the saved artifact is what the report CLI (and Perfetto) consume
    path = obs.save_chrome_trace(tmp_path / "t.json")
    assert json.loads(pathlib.Path(path).read_text()) == payload


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------

def test_histogram_inclusive_upper_bounds_underflow_overflow():
    h = obs.Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5,      # below edges[0]: first bucket doubles as underflow
              1.0,      # EXACT boundary: stays in its edge's bucket
              1.5, 2.0,  # bucket 1 (2.0 inclusive)
              4.0,      # bucket 2
              4.0001):  # past the last edge: +Inf overflow
        h.observe(v)
    assert h.counts == [2, 2, 1, 1]
    assert h.count == 6 and h.sum == pytest.approx(13.0001)
    assert h.min == 0.5 and h.max == 4.0001
    assert h.quantile(0.5) == 2.0       # cumulative crosses rank in bucket 1
    assert h.quantile(1.0) == 4.0001    # overflow reports max observed
    with pytest.raises(ValueError):
        obs.Histogram("bad", buckets=(2.0, 1.0))   # not increasing
    with pytest.raises(ValueError):
        obs.Histogram("bad", buckets=())


def test_registry_typing_and_reset():
    obs.counter("reqs_total").inc(2)
    assert obs.counter("reqs_total").value == 2   # get-or-create: same obj
    with pytest.raises(TypeError):
        obs.gauge("reqs_total")                   # name/type clash
    with pytest.raises(ValueError):
        obs.counter("reqs_total").inc(-1)         # counters never decrease
    obs.REGISTRY.reset()
    assert obs.counter("reqs_total").value == 0   # accessors re-create


def test_prometheus_export_is_cumulative_with_inf_sum_count():
    h = obs.histogram("lat_seconds", buckets=(0.001, 0.01, 0.1))
    for v in (0.0005, 0.001, 0.05, 99.0):
        h.observe(v)
    obs.counter("reqs_total", "served requests").inc(2)
    obs.gauge("depth").set(3)
    text = obs.prometheus()
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="0.001"} 2' in text   # underflow + boundary
    assert 'lat_seconds_bucket{le="0.01"} 2' in text    # cumulative
    assert 'lat_seconds_bucket{le="0.1"} 3' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_sum" in text and "lat_seconds_count 4" in text
    assert "# HELP reqs_total served requests" in text
    assert "reqs_total 2" in text and "depth 3.0" in text
    snap = obs.snapshot()
    assert snap["counters"]["reqs_total"] == 2
    assert snap["histograms"]["lat_seconds"]["counts"] == [2, 0, 1, 1]


def test_save_metrics_writes_json_or_prom_by_suffix(tmp_path):
    obs.counter("c_total").inc()
    p_json = obs.save_metrics(tmp_path / "m.json")
    assert json.loads(pathlib.Path(p_json).read_text())["counters"] == \
        {"c_total": 1}
    p_prom = obs.save_metrics(tmp_path / "m.prom")
    assert "c_total 1" in pathlib.Path(p_prom).read_text()


# ---------------------------------------------------------------------------
# Autotune plan funnel (the decode-step span annotation).
# ---------------------------------------------------------------------------

def test_plan_resolutions_are_recorded_once_and_summarised():
    obs.enable()
    plan = autotune.plan_for_spec(
        ScanSpec(direction="fwd", interpret=True), 64, 64, c=8)
    evs = [r for r in obs.records() if r.name == "kernel.plan"]
    assert len(evs) == 1 and evs[0].ph == "i"
    assert evs[0].args["row_tile"] == plan.row_tile
    assert evs[0].args["source"] in ("cache", "heuristic")
    autotune.plan_for_spec(ScanSpec(direction="fwd", interpret=True),
                           64, 64, c=8)
    assert len([r for r in obs.records()
                if r.name == "kernel.plan"]) == 1    # same key: no re-emit
    s = autotune.plans_summary()
    assert "h64|w64|c8|fwd" in s
    assert f"t{plan.row_tile}-d{plan.pipeline_depth}" in s


# ---------------------------------------------------------------------------
# Serve-engine instrumentation (the ISSUE acceptance shape).
# ---------------------------------------------------------------------------

def _gspn_cfg():
    from repro.models.lm import LMConfig
    return LMConfig(
        name="g", family="gspn", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, prelude=(("gspn", 1),),
        unit=(("attn", 1),), n_units=1, gspn_proxy_dim=4, gspn_row_width=8,
        remat="none", compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def engine():
    from repro.models.lm import init_lm
    from repro.serve.engine import ServeEngine
    cfg = _gspn_cfg()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, batch_size=2, max_len=64,
                       prefill_chunk=8)


def test_engine_emits_request_to_kernel_spans(engine):
    from repro.serve.engine import Request
    engine.reset()
    obs.enable()
    engine.submit(Request(uid=0, prompt=np.arange(24) % 64,
                          max_new_tokens=4))   # 24 > chunk 8: 3 chunks
    engine.submit(Request(uid=1, prompt=np.arange(6) % 64, max_new_tokens=3))
    engine.submit(Request(uid=2, prompt=np.arange(6) % 64, max_new_tokens=3))
    res = engine.run()
    assert sorted(res) == [0, 1, 2]

    recs = obs.records()
    begins = [r for r in recs if r.ph == "b" and r.name == "request"]
    ends = [r for r in recs if r.ph == "e" and r.name == "request"]
    assert {r.aid for r in begins} == {0, 1, 2} == {r.aid for r in ends}
    for e in ends:
        assert e.args["finish_reason"] in ("eos", "length")
        b = next(r for r in begins if r.aid == e.aid)
        assert b.ts <= e.ts             # lifecycle ordering

    chunks = obs.spans("serve.prefill_chunk")
    assert [c.args["index"] for c in chunks] == [0, 1, 2]
    assert all(c.args["uid"] == 0 for c in chunks)
    steps = obs.spans("serve.decode_step")
    assert steps, "no decode-step spans recorded"
    assert all("plan" in s.args and "batch" in s.args for s in steps)

    m = engine.metrics                  # compat view + derived mean
    assert m["decode_steps"] == len(steps)
    assert m["prefill_chunks"] == 3
    assert m["queue_depth_max"] >= 1    # uid 2 had to wait for a slot
    assert m["queue_depth_mean"] >= 0.0
    snap = obs.snapshot()               # same counters, global registry
    assert snap["counters"]["serve_requests_submitted_total"] == 3
    assert snap["counters"]["serve_requests_finished_total"] == 3
    assert snap["counters"]["serve_decode_steps_total"] == len(steps)
    assert snap["histograms"]["serve_ttft_seconds"]["count"] == 3


def test_queue_depth_not_counted_on_admission_tick(engine):
    """The satellite fix: depth is sampled AFTER _admit(), so a request
    admitted the tick it arrived never inflates the mean (the old
    pre-admit sample double-counted every retire-and-replace tick)."""
    from repro.serve.engine import Request
    engine.reset()
    engine.submit(Request(uid=0, prompt=np.arange(6) % 64, max_new_tokens=3))
    engine.run()
    m = engine.metrics
    assert m["depth_samples"] == m["ticks"] > 0
    assert m["queue_depth_max"] == 0    # never actually waited a tick out
    assert m["queue_depth_mean"] == 0.0


def test_disabled_tracing_overhead_under_2pct_of_decode_step(engine):
    """The DESIGN.md §13 pin: with tracing off, the per-call cost of the
    instrumentation (flag check + shared singleton) times a generous
    calls-per-step budget stays under 2% of a measured decode step."""
    from repro.serve.engine import Request
    engine.reset()
    assert not obs.enabled()
    engine.submit(Request(uid=0, prompt=np.arange(6) % 64,
                          max_new_tokens=24))
    engine.tick()                       # admit + compile the decode path
    step_times = []
    while engine.slot_req[0] is not None and len(step_times) < 16:
        t0 = obs.monotonic()
        engine.tick()
        step_times.append(obs.monotonic() - t0)
    engine.run()
    engine.reset()
    step_times.sort()
    step_s = step_times[len(step_times) // 2]

    n = 10000                           # best-of-5: intrinsic cost, not
    best = float("inf")                 # scheduler noise
    for _ in range(5):
        t0 = obs.monotonic()
        for _ in range(n):
            with obs.trace("x", a=1, b=2):
                pass
            obs.event("y", z=3)
        best = min(best, obs.monotonic() - t0)
    per_call = best / (2 * n)
    calls_per_step = 16                 # actual instrumented calls/tick ~6
    assert per_call * calls_per_step < 0.02 * step_s, (
        f"disabled-tracing overhead {per_call * calls_per_step * 1e6:.2f}us "
        f"vs 2% of decode step {0.02 * step_s * 1e6:.2f}us")


# ---------------------------------------------------------------------------
# Benchmark schema 2 (time_fn stats) + gate read-compat.
# ---------------------------------------------------------------------------

def test_time_fn_stats_flow_into_schema2_payload(monkeypatch):
    import benchmarks.common as common
    from benchmarks import run as bench_run
    monkeypatch.setattr(common, "ROWS", [])
    monkeypatch.setattr(common, "ROW_STATS", [])
    monkeypatch.setattr(common, "LAST_STATS", None)
    common.time_fn(lambda: jnp.arange(8), iters=5, warmup=0)
    st = common.LAST_STATS
    assert st["iters"] == 5
    assert st["p10_us"] <= st["p50_us"] <= st["p90_us"]
    common.emit("obs/timed", 1.0, "d=1")
    common.emit("obs/derived", 2.0)     # no fresh time_fn: stats is None
    assert common.LAST_STATS is None    # emit consumed it
    payload = bench_run.build_payload(common.ROWS, smoke=True,
                                      row_stats=common.ROW_STATS)
    assert payload["schema"] == 2
    assert payload["rows"][0]["stats"]["iters"] == 5
    assert payload["rows"][1]["stats"] is None


def test_gate_reads_schema_1_and_2(tmp_path):
    from benchmarks import gate
    for payload in (
            {"schema": 1, "rows": [{"name": "a", "us_per_call": 1.0,
                                    "derived": ""}]},
            {"schema": 2, "rows": [{"name": "a", "us_per_call": 1.0,
                                    "derived": "", "stats": None}]}):
        p = tmp_path / "r.json"
        p.write_text(json.dumps(payload))
        assert gate.index_rows(gate.load_report(p)) == {"a": 1.0}


# ---------------------------------------------------------------------------
# Report CLI.
# ---------------------------------------------------------------------------

def test_report_cli_summarises_trace_and_metrics(tmp_path, capsys):
    obs.enable()
    with obs.trace("kernel.launch", kernel="gspn_pair_fwd"):
        pass
    obs.event("kernel.plan")
    obs.async_begin("request", 1)
    obs.async_end("request", 1)
    trace_path = obs.save_chrome_trace(tmp_path / "t.json")
    obs.counter("c_total").inc(3)
    obs.histogram("h_seconds").observe(0.004)
    metrics_path = obs.save_metrics(tmp_path / "m.json")

    assert report.main([trace_path]) == 0
    out = capsys.readouterr().out
    assert "1 spans, 2 async, 1 instant" in out
    assert "kernel.launch" in out
    assert report.main([metrics_path]) == 0
    out = capsys.readouterr().out
    assert "c_total" in out and "h_seconds" in out and "p90" in out

    bad = tmp_path / "bad.json"
    bad.write_text('{"neither": 1}')
    assert report.main([str(bad)]) == 1
    assert report.main([str(tmp_path / "missing.json")]) == 1
