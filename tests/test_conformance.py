"""Randomized oracle-conformance grid (DESIGN.md §11).

~40 seeded samples over (H, W, C, dtype, direction, channel_shared, impl)
must match the pure-jnp oracle (``kernels/ref.py``) in forward AND grad
within per-dtype tolerances.  A second sweep runs every row tile the
autotuner's candidate enumerator can emit for the sampled shapes —
tuned cache entries are drawn from the same enumerator, so a green grid
proves any cache entry is numerically safe before it ever reaches a
launch site.
"""

import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels import autotune
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan_pair

pytestmark = pytest.mark.kernels

HS = [4, 8, 12, 16, 24, 32]
WS = [4, 8, 16, 24, 32]
CS = [1, 2, 4, 6]
DTYPES = ["float32", "bfloat16"]
SINGLE_DIRS = ["tb", "bt", "lr", "rl"]
N_CONFIGS = 40

# Per-dtype (rtol, atol): the kernels accumulate in f32 whatever the
# stream dtype, so bf16 error is bounded by operand quantisation plus one
# output rounding per row (taps are row-stochastic => non-expansive).
TOL = {
    "float32": {"fwd": (1e-5, 1e-5), "grad": (1e-4, 1e-5)},
    "bfloat16": {"fwd": (7.5e-2, 7.5e-2), "grad": (1.5e-1, 1.5e-1)},
}


@dataclasses.dataclass(frozen=True)
class Conf:
    h: int
    w: int
    c: int
    dtype: str
    direction: str            # tb | bt | lr | rl | pair (vertical pair)
    channel_shared: bool
    impl: str                 # pallas | multidir | xla
    pipeline_depth: int = 1   # 1 | 2 for the Pallas impls (DESIGN.md §12)

    def id(self) -> str:
        return (f"h{self.h}w{self.w}c{self.c}-{self.direction}-"
                f"{self.impl}-{self.dtype}-cs{int(self.channel_shared)}"
                f"-d{self.pipeline_depth}")


def _sample_configs(n: int = N_CONFIGS, seed: int = 0) -> list:
    rng = random.Random(seed)
    cfgs, seen = [], set()
    while len(cfgs) < n:
        direction = rng.choice(SINGLE_DIRS + ["pair", "pair"])
        impl = rng.choice(["multidir", "xla"] if direction == "pair"
                          else ["pallas", "pallas", "xla"])
        depth = 1 if impl == "xla" else rng.choice([1, 2])
        cfg = Conf(rng.choice(HS), rng.choice(WS), rng.choice(CS),
                   rng.choice(DTYPES), direction,
                   rng.choice([True, False]), impl, depth)
        if cfg not in seen:
            seen.add(cfg)
            cfgs.append(cfg)
    return cfgs


CONFIGS = _sample_configs()


def _operands(cfg: Conf, seed: int, n_dirs: int = 1):
    """x/lam (C, H, W), softmaxed taps (n_dirs*, Gw, H, W), dy cotangent."""
    gw = 1 if cfg.channel_shared else cfg.c
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (cfg.c, cfg.h, cfg.w)).astype(dt)
    lam = jax.nn.sigmoid(
        jax.random.normal(ks[1], (cfg.c, cfg.h, cfg.w))).astype(dt)
    shape = (n_dirs, gw, cfg.h, cfg.w, 3) if n_dirs > 1 \
        else (gw, cfg.h, cfg.w, 3)
    taps = jax.nn.softmax(jax.random.normal(ks[2], shape), axis=-1)
    wl, wc, wr = (taps[..., i].astype(dt) for i in range(3))
    dy = jax.random.normal(ks[3], (cfg.c, cfg.h, cfg.w))
    return x, wl, wc, wr, lam, dy


def _oracle_single(x, wl, wc, wr, lam, direction):
    """ref.py scan in f32 on the oriented operands, un-oriented back."""
    can = lambda a: G._to_canonical(a.astype(jnp.float32), direction)
    h = R.gspn_scan_ref(can(x), can(wl), can(wc), can(wr), can(lam))
    return G._from_canonical(h, direction)


def _oracle_pair(x, wl2, wc2, wr2, lam2):
    f32 = lambda a: a.astype(jnp.float32)
    fwd = R.gspn_scan_ref(f32(x), f32(wl2[0]), f32(wc2[0]), f32(wr2[0]),
                          f32(lam2[0]))
    rev = R.gspn_scan_ref(f32(x), f32(wl2[1]), f32(wc2[1]), f32(wr2[1]),
                          f32(lam2[1]), reverse=True)
    return jnp.stack([fwd, rev])


def _check(a, b, which, dtype):
    rtol, atol = TOL[dtype][which]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=which)


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.id())
def test_oracle_conformance_fwd_and_grad(cfg):
    seed = CONFIGS.index(cfg)
    if cfg.direction == "pair":
        x, wl2, wc2, wr2, lam_s, dy = _operands(cfg, seed, n_dirs=2)
        lam2 = jnp.stack([lam_s, lam_s])
        dy2 = jnp.stack([dy, -dy])

        def impl_fn(x, wl2, wc2, wr2, lam2):
            return gspn_scan_pair(x, wl2, wc2, wr2, lam2, impl=cfg.impl,
                                  pipeline_depth=cfg.pipeline_depth)

        _check(impl_fn(x, wl2, wc2, wr2, lam2),
               _oracle_pair(x, wl2, wc2, wr2, lam2), "fwd", cfg.dtype)

        def loss_impl(*a):
            return jnp.sum(impl_fn(*a).astype(jnp.float32) * dy2)

        def loss_ref(*a):
            return jnp.sum(_oracle_pair(*a) * dy2)

        args = (x, wl2, wc2, wr2, lam2)
    else:
        x, wl, wc, wr, lam, dy = _operands(cfg, seed)

        def impl_fn(x, wl, wc, wr, lam):
            return G.directional_scan(x, wl, wc, wr, lam, cfg.direction,
                                      impl=cfg.impl,
                                      pipeline_depth=cfg.pipeline_depth)

        _check(impl_fn(x, wl, wc, wr, lam),
               _oracle_single(x, wl, wc, wr, lam, cfg.direction),
               "fwd", cfg.dtype)

        def loss_impl(*a):
            return jnp.sum(impl_fn(*a).astype(jnp.float32) * dy)

        def loss_ref(*a):
            return jnp.sum(_oracle_single(*a, cfg.direction) * dy)

        args = (x, wl, wc, wr, lam)

    g_impl = jax.grad(loss_impl, argnums=tuple(range(5)))(*args)
    g_ref = jax.grad(loss_ref, argnums=tuple(range(5)))(*args)
    for gi, gr in zip(g_impl, g_ref):
        _check(gi, gr, "grad", cfg.dtype)


# ---------------------------------------------------------------------------
# Every config the tuner can emit: the cache only ever stores row tiles
# from enumerate_candidates, so sweeping the enumerator's output over the
# sampled shapes proves any cache entry is safe (DESIGN.md §11).
# ---------------------------------------------------------------------------

TUNER_CFGS = [c for c in CONFIGS if c.impl in ("pallas", "multidir")][:12]


def _scan_geometry(cfg: Conf):
    """(scan_len, lane_w): horizontal directions scan over W."""
    if cfg.direction in ("lr", "rl"):
        return cfg.w, cfg.h
    return cfg.h, cfg.w


@pytest.mark.parametrize("cfg", TUNER_CFGS, ids=lambda c: c.id())
def test_every_tuner_candidate_matches_oracle(cfg):
    seed = 1000 + TUNER_CFGS.index(cfg)
    scan_len, lane_w = _scan_geometry(cfg)
    direction = "pair_fwd" if cfg.direction == "pair" else "fwd"
    key = autotune.ScanKey(
        autotune.device_kind(True), scan_len, lane_w, cfg.c, direction,
        cfg.impl, cfg.dtype, "float32", cfg.channel_shared)
    cands = autotune.enumerate_candidates(key)
    assert cands, key
    plans = sorted({(c.row_tile, c.pipeline_depth) for c in cands})
    tiles = sorted({t for t, _ in plans})
    # The heuristic's choice is always in the candidate set — a measured
    # winner can therefore never be slower than the heuristic beyond
    # timing noise (the tuner times the heuristic tile too).
    assert autotune.heuristic_row_tile(key) in tiles
    # Depth 2 is enumerated exactly for narrow streams (admission policy).
    assert (2 in {d for _, d in plans}) == (key.stream_bytes < 4)

    if cfg.direction == "pair":
        x, wl2, wc2, wr2, lam_s, _ = _operands(cfg, seed, n_dirs=2)
        lam2 = jnp.stack([lam_s, lam_s])
        want = _oracle_pair(x, wl2, wc2, wr2, lam2)
        for t, d in plans:
            got = gspn_scan_pair(x, wl2, wc2, wr2, lam2, impl=cfg.impl,
                                 row_tile=t, pipeline_depth=d)
            _check(got, want, "fwd", cfg.dtype)
    else:
        x, wl, wc, wr, lam, _ = _operands(cfg, seed)
        want = _oracle_single(x, wl, wc, wr, lam, cfg.direction)
        for t, d in plans:
            got = G.directional_scan(x, wl, wc, wr, lam, cfg.direction,
                                     impl=cfg.impl, row_tile=t,
                                     pipeline_depth=d)
            _check(got, want, "fwd", cfg.dtype)


# ---------------------------------------------------------------------------
# Pipeline-depth bit agreement (DESIGN.md §12).
#
# Depth 1 (the revolving-buffer per-plane kernels) and depth 2 (the staged
# plane-blocked pipeline) execute the SAME f32 operation sequence per
# element — staging only changes where casts and copies happen, never the
# arithmetic.  In interpret mode that makes the two depths bit-identical,
# and this grid pins it: forward AND grad, all four directions, the fused
# pair, the quad launch, bf16/f32 streams, bf16/f32 carries.
# ---------------------------------------------------------------------------

DEPTH_DIRS = SINGLE_DIRS + ["pair", "quad"]


@pytest.mark.parametrize("carry_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("direction", DEPTH_DIRS)
def test_pipeline_depth_bit_agreement(direction, dtype, carry_dtype):
    cfg = Conf(16, 16, 4, dtype, direction if direction != "quad" else "tb",
               True, "pallas")
    seed = 77 + DEPTH_DIRS.index(direction)

    def bitwise(a, b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    if direction == "quad":
        # Forward-only single-launch path; exercised directly.
        from repro.kernels import gspn_multidir as MK
        x, wl4, wc4, wr4, lam_s, _ = _operands(cfg, seed, n_dirs=4)
        lam4 = jnp.stack([lam_s] * 4)
        outs = [MK.gspn_scan_quad_pallas(
                    x, {"wl": wl4, "wc": wc4, "wr": wr4}, lam4,
                    channels_per_weight=cfg.c, row_tile=8,
                    carry_dtype=carry_dtype, pipeline_depth=d)
                for d in (1, 2)]
        bitwise(*outs)
        return

    if direction == "pair":
        x, wl2, wc2, wr2, lam_s, dy = _operands(cfg, seed, n_dirs=2)
        lam2 = jnp.stack([lam_s, lam_s])
        dy2 = jnp.stack([dy, -dy])

        def run(depth, *a):
            return gspn_scan_pair(*a, impl="multidir", row_tile=8,
                                  carry_dtype=carry_dtype,
                                  pipeline_depth=depth)

        args = (x, wl2, wc2, wr2, lam2)
        cot = dy2
    else:
        x, wl, wc, wr, lam, dy = _operands(cfg, seed)

        def run(depth, *a):
            return G.directional_scan(*a, cfg.direction, impl="pallas",
                                      row_tile=8, carry_dtype=carry_dtype,
                                      pipeline_depth=depth)

        args = (x, wl, wc, wr, lam)
        cot = dy

    bitwise(run(1, *args), run(2, *args))
    grads = [jax.grad(
                 lambda *a, _d=d: jnp.sum(run(_d, *a).astype(jnp.float32)
                                          * cot),
                 argnums=tuple(range(5)))(*args)
             for d in (1, 2)]
    for g1, g2 in zip(*grads):
        bitwise(g1, g2)
