"""Spec-space oracle conformance (DESIGN.md §11, §14).

The grid is no longer hand-sampled: :func:`repro.kernels.spec
.enumerate_specs` is the single source of truth for the admissible
launch-policy space, and EVERY spec it emits runs forward AND grad
against the pure-jnp oracle (``kernels/ref.py``) within per-dtype
tolerances.  A new propagation variant therefore becomes a spec plus an
automatic conformance entry — adding a kernel fork without teaching the
enumerator about it cannot pass review silently.

Two grid sizes (``GSPN_SPEC_GRID`` env):

* ``pr`` (default) — the full 44-spec grid, one cycled spatial
  orientation per fwd spec, one base shape per direction family; runs in
  the blocking PR matrix.
* ``full`` — every orientation × an extended shape set per spec; the
  nightly-style ``spec-grid`` CI lane.

On top of the enumerated grid, seeded property-based sampling covers the
expensive cross-cutting invariants: pair/quad fusion ≡ per-direction
composition, chunked prefill ≡ one-shot, and depth-1 ≡ depth-2 bitwise.
A tuner sweep still runs every row tile the candidate enumerator can
emit, proving any cache entry numerically safe before it reaches a
launch site.
"""

import os
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels import autotune
from repro.kernels import gspn_multidir as MK
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan_pair
from repro.kernels.spec import ScanSpec, enumerate_specs

pytestmark = pytest.mark.kernels

GRID_MODE = os.environ.get("GSPN_SPEC_GRID", "pr")   # pr | full
SINGLE_DIRS = ["tb", "bt", "lr", "rl"]

# Per-dtype (rtol, atol): the kernels accumulate in f32 whatever the
# stream dtype, so bf16 error is bounded by operand quantisation plus one
# output rounding per row (taps are row-stochastic => non-expansive).
TOL = {
    "float32": {"fwd": (1e-5, 1e-5), "grad": (1e-4, 1e-5)},
    "bfloat16": {"fwd": (7.5e-2, 7.5e-2), "grad": (1.5e-1, 1.5e-1)},
}

# Shapes per direction family.  The quad launch requires square grids.
BASE_SHAPES = {"fwd": (12, 8), "pair_fwd": (12, 8), "quad": (12, 12)}
FULL_EXTRA_SHAPES = {
    "fwd": [(16, 24), (24, 16), (8, 32)],
    "pair_fwd": [(16, 24), (24, 16), (8, 32)],
    "quad": [(16, 16), (8, 8)],
}

SPECS = enumerate_specs()


def _cases():
    """(spec, orientation, h, w) — the enumerated sweep.

    ``pr`` runs every spec once (orientation cycled across fwd specs so
    the four spatial directions all stay covered); ``full`` crosses each
    spec with every orientation and the extended shape set.
    """
    cases = []
    for i, sp in enumerate(SPECS):
        fam = sp.direction
        shapes = [BASE_SHAPES[fam]]
        if GRID_MODE == "full":
            shapes += FULL_EXTRA_SHAPES[fam]
        if fam == "fwd":
            oris = SINGLE_DIRS if GRID_MODE == "full" \
                else [SINGLE_DIRS[i % 4]]
        else:
            oris = [None]
        for ori in oris:
            for h, w in shapes:
                cases.append((sp, ori, h, w))
    return cases


CASES = _cases()


def _case_id(case):
    sp, ori, h, w = case
    return f"{sp.spec_id()}-{ori or sp.direction}-h{h}w{w}".replace("|", "_")


def _operands(h, w, c, gw, dtype, seed, n_dirs: int = 1):
    """x/lam (C, H, W), softmaxed taps (n_dirs*, Gw, H, W), dy cotangent."""
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (c, h, w)).astype(dt)
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (c, h, w))).astype(dt)
    shape = (n_dirs, gw, h, w, 3) if n_dirs > 1 else (gw, h, w, 3)
    taps = jax.nn.softmax(jax.random.normal(ks[2], shape), axis=-1)
    wl, wc, wr = (taps[..., i].astype(dt) for i in range(3))
    dy = jax.random.normal(ks[3], (c, h, w))
    return x, wl, wc, wr, lam, dy


def _oracle_single(x, wl, wc, wr, lam, direction):
    """ref.py scan in f32 on the oriented operands, un-oriented back."""
    can = lambda a: G._to_canonical(a.astype(jnp.float32), direction)
    h = R.gspn_scan_ref(can(x), can(wl), can(wc), can(wr), can(lam))
    return G._from_canonical(h, direction)


def _oracle_pair(x, wl2, wc2, wr2, lam2):
    f32 = lambda a: a.astype(jnp.float32)
    fwd = R.gspn_scan_ref(f32(x), f32(wl2[0]), f32(wc2[0]), f32(wr2[0]),
                          f32(lam2[0]))
    rev = R.gspn_scan_ref(f32(x), f32(wl2[1]), f32(wc2[1]), f32(wr2[1]),
                          f32(lam2[1]), reverse=True)
    return jnp.stack([fwd, rev])


def _oracle_quad(x, wl4, wc4, wr4, lam4):
    """Quad-launch semantics: entries 0/1 stream x, entries 2/3 its
    transpose (taps arrive pre-transposed); odd entries scan reversed."""
    f32 = lambda a: a.astype(jnp.float32)
    xt = jnp.swapaxes(f32(x), -1, -2)
    outs = []
    for d in range(4):
        outs.append(R.gspn_scan_ref(
            f32(x) if d < 2 else xt, f32(wl4[d]), f32(wc4[d]),
            f32(wr4[d]), f32(lam4[d]), reverse=(d % 2 == 1)))
    return jnp.stack(outs)


def _check(a, b, which, dtype):
    rtol, atol = TOL[dtype][which]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=which)


@pytest.mark.parametrize("case", CASES, ids=_case_id)
def test_spec_grid_conformance(case):
    """Every spec the enumerator emits matches the oracle, fwd + grad.

    The spec travels intact: each call path receives the enumerated
    ScanSpec itself (refined only in the shape-derived legs), so the grid
    exercises the exact objects the autotune cache is keyed on.
    """
    sp, ori, h, w = case
    seed = CASES.index(case)
    c = sp.channels_per_weight * 2
    gw = c // sp.channels_per_weight

    if sp.direction == "fwd":
        x, wl, wc, wr, lam, dy = _operands(h, w, c, gw, sp.stream_dtype,
                                           seed)

        def impl_fn(*a):
            return G.directional_scan(*a, ori, spec=sp)

        want = _oracle_single(x, wl, wc, wr, lam, ori)
        args = (x, wl, wc, wr, lam)
        cot = dy
    elif sp.direction == "pair_fwd":
        x, wl2, wc2, wr2, lam_s, dy = _operands(h, w, c, gw,
                                                sp.stream_dtype, seed,
                                                n_dirs=2)
        lam2 = jnp.stack([lam_s, -lam_s])

        def impl_fn(*a):
            return gspn_scan_pair(*a, spec=sp)

        want = _oracle_pair(x, wl2, wc2, wr2, lam2)
        args = (x, wl2, wc2, wr2, lam2)
        cot = jnp.stack([dy, -dy])
    else:   # quad — forward-only single-launch path
        x, wl4, wc4, wr4, lam_s, _ = _operands(h, w, c, gw,
                                               sp.stream_dtype, seed,
                                               n_dirs=4)
        lam4 = jnp.stack([lam_s, -lam_s, 2 * lam_s, lam_s])
        got = MK.gspn_scan_quad_pallas(
            x, {"wl": wl4, "wc": wc4, "wr": wr4}, lam4, spec=sp)
        _check(got, _oracle_quad(x, wl4, wc4, wr4, lam4), "fwd",
               sp.stream_dtype)
        return

    _check(impl_fn(*args), want, "fwd", sp.stream_dtype)

    def loss_impl(*a):
        return jnp.sum(impl_fn(*a).astype(jnp.float32) * cot)

    if sp.direction == "fwd":
        def loss_ref(*a):
            return jnp.sum(_oracle_single(*a, ori) * cot)
    else:
        def loss_ref(*a):
            return jnp.sum(_oracle_pair(*a) * cot)

    g_impl = jax.grad(loss_impl, argnums=tuple(range(5)))(*args)
    g_ref = jax.grad(loss_ref, argnums=tuple(range(5)))(*args)
    for gi, gr in zip(g_impl, g_ref):
        _check(gi, gr, "grad", sp.stream_dtype)


def test_enumerated_grid_is_the_whole_admissible_space():
    """Structural pins on the enumerator itself: the grid stays exactly
    the dispatch matrix × dtype policy × channel modes — a silently
    shrunken grid would hollow out the sweep above."""
    assert len(SPECS) == len(set(SPECS))             # hashable + distinct
    dirs = {s.direction for s in SPECS}
    assert dirs == {"fwd", "pair_fwd", "quad"}
    assert {s.channels_per_weight for s in SPECS} == {1, 3}
    assert {s.stream_dtype for s in SPECS} == {"float32", "bfloat16"}
    for s in SPECS:
        if s.impl == "xla":
            assert s.pipeline_depth is None and s.carry_dtype == "float32"
        else:
            assert s.pipeline_depth in (1, 2)
            if s.stream_dtype == "float32":
                assert s.carry_dtype == "float32"
    # bf16 fused entries carry both policies; every fused entry appears
    # at both depths.
    fused = [s for s in SPECS if s.impl in ("pallas", "multidir")]
    assert {s.carry_dtype for s in fused
            if s.stream_dtype == "bfloat16"} == {"float32", "bfloat16"}
    assert all(s.with_(pipeline_depth=3 - s.pipeline_depth) in set(SPECS)
               for s in fused)


# ---------------------------------------------------------------------------
# Seeded property-based sampling: the expensive cross-cutting invariants
# (fusion ≡ composition, chunked prefill ≡ one-shot).  Each sample draws
# a random geometry/policy from a fixed seed, so the sampled subspace
# grows over reruns of the full lane without bloating the PR matrix.
# ---------------------------------------------------------------------------

N_PROPERTY_SAMPLES = 3 if GRID_MODE == "pr" else 8


def _sample_rng(seed):
    return random.Random(0xC0FFEE + seed)


@pytest.mark.parametrize("sample", range(N_PROPERTY_SAMPLES))
def test_property_pair_fusion_equals_composition(sample):
    """The fused opposite pair ≡ two independent directional scans, fwd
    and grad — the invariant that lets dispatch fuse without asking."""
    rng = _sample_rng(sample)
    h = rng.choice([8, 12, 16, 24])
    w = rng.choice([8, 16, 24])
    cpw = rng.choice([1, 2, 4])
    dtype = rng.choice(["float32", "bfloat16"])
    c = cpw * 2
    x, wl2, wc2, wr2, lam_s, dy = _operands(h, w, c, c // cpw, dtype,
                                            200 + sample, n_dirs=2)
    lam2 = jnp.stack([lam_s, -lam_s])
    dy2 = jnp.stack([dy, -dy])
    sp = ScanSpec(impl="multidir", channels_per_weight=cpw)

    def fused(*a):
        return gspn_scan_pair(*a, spec=sp)

    def composed(x, wl2, wc2, wr2, lam2):
        one = ScanSpec(impl="pallas", channels_per_weight=cpw)
        tb = G.directional_scan(x, wl2[0], wc2[0], wr2[0], lam2[0], "tb",
                                spec=one)
        bt = G.directional_scan(x, wl2[1], wc2[1], wr2[1], lam2[1], "bt",
                                spec=one)
        return jnp.stack([tb, bt])

    args = (x, wl2, wc2, wr2, lam2)
    _check(fused(*args), composed(*args), "fwd", dtype)
    gf = jax.grad(lambda *a: jnp.sum(fused(*a).astype(jnp.float32) * dy2),
                  argnums=tuple(range(5)))(*args)
    gc = jax.grad(lambda *a: jnp.sum(composed(*a).astype(jnp.float32)
                                     * dy2),
                  argnums=tuple(range(5)))(*args)
    for a, b in zip(gf, gc):
        _check(a, b, "grad", dtype)


@pytest.mark.parametrize("sample", range(N_PROPERTY_SAMPLES))
def test_property_quad_fusion_equals_composition(sample):
    """The single-launch quad ≡ four per-direction reference scans."""
    rng = _sample_rng(100 + sample)
    n = rng.choice([8, 12, 16])
    cpw = rng.choice([1, 2])
    dtype = rng.choice(["float32", "bfloat16"])
    c = cpw * 2
    x, wl4, wc4, wr4, lam_s, _ = _operands(n, n, c, c // cpw, dtype,
                                           300 + sample, n_dirs=4)
    lam4 = jnp.stack([lam_s, -lam_s, 2 * lam_s, lam_s])
    sp = ScanSpec(direction="quad", impl="multidir",
                  channels_per_weight=cpw)
    got = MK.gspn_scan_quad_pallas(x, {"wl": wl4, "wc": wc4, "wr": wr4},
                                   lam4, spec=sp)
    _check(got, _oracle_quad(x, wl4, wc4, wr4, lam4), "fwd", dtype)


@pytest.mark.parametrize("sample", range(N_PROPERTY_SAMPLES))
def test_property_chunked_prefill_equals_oneshot(sample):
    """Chaining row-aligned prefill chunks (ragged tail allowed) over a
    sampled split ≡ the one-shot mixer at 1e-5 — the §9 serve contract."""
    rng = _sample_rng(200 + sample)
    w = rng.choice([4, 8])
    n_rows = rng.randint(4, 8)
    tail = rng.randint(1, w)            # ragged final chunk
    total = (n_rows - 1) * w + tail
    scfg = G.GSPNSeqConfig(dim=12, proxy_dim=4, row_width=w, impl="xla")
    p = G.init_gspn_seq_mixer(jax.random.PRNGKey(400 + sample), scfg)
    x = jax.random.normal(jax.random.PRNGKey(500 + sample), (2, total, 12))
    ref = G.apply_gspn_seq_mixer(p, x, scfg)

    # Random row-aligned split points, ragged tail.
    rows = sorted(rng.sample(range(1, n_rows), rng.randint(1, 3)))
    bounds = [0] + [r * w for r in rows] + [total]
    cache = {"prev_row": jnp.zeros((2, 4, w)),
             "cur_row": jnp.zeros((2, 4, w)),
             "row_state": jnp.zeros((2, 4)),
             "pos": jnp.zeros((2,), jnp.int32)}
    ys = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        y, cache = G.gspn_seq_prefill_chunk(p, x[:, lo:hi], scfg, cache)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(ref),
        rtol=1e-5, atol=1e-5, err_msg=str(bounds))


# ---------------------------------------------------------------------------
# Every config the tuner can emit: the cache only ever stores row tiles
# from enumerate_candidates, so sweeping the enumerator's output over the
# fused specs proves any cache entry is safe (DESIGN.md §11).
# ---------------------------------------------------------------------------

# One probe per (direction, stream, cpw) at the policy carry — depth and
# tile are plan OUTPUTS here, so the depth/carry spec axes would only
# duplicate sweeps.
TUNER_SPECS = [s for s in SPECS
               if s.impl in ("pallas", "multidir")
               and s.pipeline_depth == 1 and s.carry_dtype == "float32"]


def _tuner_id(sp):
    return sp.spec_id().replace("|", "_")


@pytest.mark.parametrize("sp", TUNER_SPECS, ids=_tuner_id)
def test_every_tuner_candidate_matches_oracle(sp):
    seed = 1000 + TUNER_SPECS.index(sp)
    h, w = (16, 16) if sp.direction == "quad" else (16, 8)
    c = sp.channels_per_weight * 2
    gw = c // sp.channels_per_weight
    probe = sp.with_(row_tile=None, pipeline_depth=None)
    key = autotune.ScanKey(
        autotune.device_kind(True), h, w, c, probe.direction, probe.impl,
        probe.stream_dtype, probe.carry_dtype, probe.channel_shared,
        probe.boundary)
    cands = autotune.enumerate_candidates(key)
    assert cands, key
    plans = sorted({(cand.row_tile, cand.pipeline_depth)
                    for cand in cands})
    tiles = sorted({t for t, _ in plans})
    # The heuristic's choice is always in the candidate set — a measured
    # winner can therefore never be slower than the heuristic beyond
    # timing noise (the tuner times the heuristic tile too).
    assert autotune.heuristic_row_tile(key) in tiles
    # Depth 2 is enumerated exactly for narrow streams (admission policy).
    assert (2 in {d for _, d in plans}) == (key.stream_bytes < 4)

    if sp.direction == "pair_fwd":
        x, wl2, wc2, wr2, lam_s, _ = _operands(h, w, c, gw,
                                               sp.stream_dtype, seed,
                                               n_dirs=2)
        lam2 = jnp.stack([lam_s, lam_s])
        want = _oracle_pair(x, wl2, wc2, wr2, lam2)
        for t, d in plans:
            got = gspn_scan_pair(x, wl2, wc2, wr2, lam2,
                                 spec=probe.with_(row_tile=t,
                                                  pipeline_depth=d))
            _check(got, want, "fwd", sp.stream_dtype)
    elif sp.direction == "quad":
        x, wl4, wc4, wr4, lam_s, _ = _operands(h, w, c, gw,
                                               sp.stream_dtype, seed,
                                               n_dirs=4)
        lam4 = jnp.stack([lam_s] * 4)
        want = _oracle_quad(x, wl4, wc4, wr4, lam4)
        for t, d in plans:
            got = MK.gspn_scan_quad_pallas(
                x, {"wl": wl4, "wc": wc4, "wr": wr4}, lam4,
                spec=probe.with_(row_tile=t, pipeline_depth=d))
            _check(got, want, "fwd", sp.stream_dtype)
    else:
        x, wl, wc, wr, lam, _ = _operands(h, w, c, gw, sp.stream_dtype,
                                          seed)
        want = _oracle_single(x, wl, wc, wr, lam, "tb")
        for t, d in plans:
            got = G.directional_scan(
                x, wl, wc, wr, lam, "tb",
                spec=probe.with_(row_tile=t, pipeline_depth=d))
            _check(got, want, "fwd", sp.stream_dtype)


# ---------------------------------------------------------------------------
# Pipeline-depth bit agreement (DESIGN.md §12).
#
# Depth 1 (the revolving-buffer per-plane kernels) and depth 2 (the staged
# plane-blocked pipeline) execute the SAME f32 operation sequence per
# element — staging only changes where casts and copies happen, never the
# arithmetic.  In interpret mode that makes the two depths bit-identical,
# and this grid pins it: forward AND grad, all four directions, the fused
# pair, the quad launch, bf16/f32 streams, bf16/f32 carries.
# ---------------------------------------------------------------------------

DEPTH_DIRS = SINGLE_DIRS + ["pair", "quad"]
DTYPES = ["float32", "bfloat16"]


@pytest.mark.parametrize("carry_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("direction", DEPTH_DIRS)
def test_pipeline_depth_bit_agreement(direction, dtype, carry_dtype):
    seed = 77 + DEPTH_DIRS.index(direction)
    h = w = 16
    c, gw = 4, 1

    def bitwise(a, b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    def spec_at(depth, **kw):
        return ScanSpec(channels_per_weight=c, row_tile=8,
                        carry_dtype=carry_dtype, pipeline_depth=depth,
                        **kw)

    if direction == "quad":
        # Forward-only single-launch path; exercised directly.
        x, wl4, wc4, wr4, lam_s, _ = _operands(h, w, c, gw, dtype, seed,
                                               n_dirs=4)
        lam4 = jnp.stack([lam_s] * 4)
        outs = [MK.gspn_scan_quad_pallas(
                    x, {"wl": wl4, "wc": wc4, "wr": wr4}, lam4,
                    spec=spec_at(d, impl="multidir"))
                for d in (1, 2)]
        bitwise(*outs)
        return

    if direction == "pair":
        x, wl2, wc2, wr2, lam_s, dy = _operands(h, w, c, gw, dtype, seed,
                                                n_dirs=2)
        lam2 = jnp.stack([lam_s, lam_s])

        def run(depth, *a):
            return gspn_scan_pair(*a, spec=spec_at(depth, impl="multidir"))

        args = (x, wl2, wc2, wr2, lam2)
        cot = jnp.stack([dy, -dy])
    else:
        x, wl, wc, wr, lam, dy = _operands(h, w, c, gw, dtype, seed)

        def run(depth, *a):
            return G.directional_scan(*a, direction,
                                      spec=spec_at(depth, impl="pallas"))

        args = (x, wl, wc, wr, lam)
        cot = dy

    bitwise(run(1, *args), run(2, *args))
    grads = [jax.grad(
                 lambda *a, _d=d: jnp.sum(run(_d, *a).astype(jnp.float32)
                                          * cot),
                 argnums=tuple(range(5)))(*args)
             for d in (1, 2)]
    for g1, g2 in zip(*grads):
        bitwise(g1, g2)
