"""Mixed-precision numerics gates (DESIGN.md §10).

Pins the documented error budget: bf16 streamed operands with f32 carries
track the f32 oracle within 1e-2 relative L2 error — forward and
gradients, across all four scan directions, compact-channel mode, the
fused pair op, chunked GSPN prefill, and the sp boundary exchange —
plus the serve-side state-pool narrowing and the train-side f32-master /
dynamic-loss-scale policy.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan

TOL = 1e-2     # the §10 documented bf16-vs-f32 bound (relative L2)


def rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)


def _dir_inputs(b, cp, h, w, seed=0):
    """Direction-stacked inputs in ORIGINAL orientation (f32)."""
    g = b * cp
    nd = len(G.DIRECTIONS)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (nd, g, h, w)))
    logits = jax.random.normal(ks[2], (nd, b, h, w, 3))
    taps = [G._normalize_taps_oriented(logits[i], d, "softmax")
            for i, d in enumerate(G.DIRECTIONS)]
    wl, wc, wr = (jnp.stack([t[k] for t in taps]) for k in range(3))
    return x, wl, wc, wr, lam


def _cast(tree, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), tree)


# ---------------------------------------------------------------------------
# Kernel-level: forward + grads, all four directions, compact mode.
# ---------------------------------------------------------------------------

@pytest.mark.kernels
@pytest.mark.parametrize("impl", ["xla", "multidir"])
@pytest.mark.parametrize("cpw", [1, 3])
def test_bf16_forward_all_directions(impl, cpw):
    """bf16 streams ≤ 1e-2 off the f32 oracle, per direction, through the
    fused multi-direction dispatch (pair fusion included)."""
    args32 = _dir_inputs(2, cpw, 16, 12)
    ref = G.directional_scan(*args32, G.DIRECTIONS, impl="xla")
    out = G.directional_scan(*_cast(args32, jnp.bfloat16), G.DIRECTIONS,
                             impl=impl)
    assert out.dtype == jnp.bfloat16
    for i, d in enumerate(G.DIRECTIONS):
        assert rel_err(out[i], ref[i]) < TOL, d


@pytest.mark.kernels
@pytest.mark.parametrize("impl", ["xla", "multidir"])
def test_bf16_grads_all_directions(impl):
    """Gradients through the custom-vjp adjoint: bf16 within 1e-2 of f32
    for every tensor argument."""
    args32 = _dir_inputs(2, 2, 16, 12, seed=3)

    def loss(fn_impl, dtype):
        def f(*a):
            a = _cast(a, dtype)
            h = G.directional_scan(*a, G.DIRECTIONS, impl=fn_impl)
            return jnp.sum(jnp.sin(h.astype(jnp.float32)))
        return f

    g_ref = jax.grad(loss("xla", jnp.float32), argnums=(0, 4))(*args32)
    g_bf = jax.grad(loss(impl, jnp.bfloat16), argnums=(0, 4))(*args32)
    for a, b in zip(g_bf, g_ref):
        assert rel_err(a, b) < TOL


@pytest.mark.kernels
def test_bf16_carry_dtype_knob():
    """The carry_dtype leg is threadable end-to-end; a bf16 carry stays
    within a looser bound (it exists for experiments, not the policy)."""
    x, wl, wc, wr, lam = _dir_inputs(1, 2, 16, 12)[0:5]
    ref = R.gspn_scan_ref(x, wl[0], wc[0], wr[0], lam[0])
    b = jnp.bfloat16
    out = gspn_scan(x.astype(b), wl[0].astype(b), wc[0].astype(b),
                    wr[0].astype(b), lam[0].astype(b), impl="pallas",
                    carry_dtype="bfloat16")
    assert rel_err(out, ref) < 5e-2


# ---------------------------------------------------------------------------
# Module-level: attention module, seq mixer, chunked prefill.
# ---------------------------------------------------------------------------

@pytest.mark.kernels
@pytest.mark.parametrize("channel_shared", [True, False])
def test_bf16_attention_module(channel_shared):
    cfg = G.GSPNAttentionConfig(dim=16, proxy_dim=4,
                                channel_shared=channel_shared)
    p = G.init_gspn_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 16))
    ref = G.apply_gspn_attention(p, x, cfg)
    cfg_b = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16)
    out = G.apply_gspn_attention(p, x, cfg_b)
    assert out.dtype == x.dtype
    assert rel_err(out, ref) < TOL


@pytest.mark.serve
def test_bf16_chunked_prefill_matches_f32_oneshot():
    """Chaining bf16 prefill chunks stays within the §10 bound of the f32
    one-shot mixer — the cross-chunk boundary rounding included — and the
    f32 chunked path stays EXACT (1e-5), so narrowing is opt-in."""
    scfg = G.GSPNSeqConfig(dim=16, proxy_dim=4, row_width=8, impl="xla")
    p = G.init_gspn_seq_mixer(jax.random.PRNGKey(0), scfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 40, 16))
    ref = G.apply_gspn_seq_mixer(p, x, scfg)

    def chunked(cfg):
        cache = {"prev_row": jnp.zeros((2, 4, 8)),
                 "cur_row": jnp.zeros((2, 4, 8)),
                 "row_state": jnp.zeros((2, 4)),
                 "pos": jnp.zeros((2,), jnp.int32)}
        ys = []
        for lo, hi in ((0, 16), (16, 32), (32, 40)):   # ragged tail
            y, cache = G.gspn_seq_prefill_chunk(p, x[:, lo:hi], cfg, cache)
            ys.append(y)
        return jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(chunked(scfg)), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    scfg_b = dataclasses.replace(scfg, compute_dtype=jnp.bfloat16)
    assert rel_err(chunked(scfg_b), ref) < TOL


# ---------------------------------------------------------------------------
# sp path: bf16 boundary exchange (8 fake CPU devices).
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_sp_bf16_boundary_exchange(run_sub):
    """Both exchange strategies with bf16 wire payloads stay within the
    §10 bound of the f32 single-device oracle, forward and gradient."""
    run_sub("""
        from repro.parallel.gspn_sp import gspn_scan_sp
        from repro.kernels import ref as R
        from repro.core import gspn as G

        mesh = make_mesh((8,), ("seq",))
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (4, 33, 16))
        lam = jax.random.normal(ks[1], (4, 33, 16))
        wl, wc, wr = G.normalize_taps(
            jax.random.normal(ks[2], (2, 33, 16, 3)))
        ref = R.gspn_scan_ref(x, wl, wc, wr, lam)

        def rel(a, b):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            return np.linalg.norm(a - b) / np.linalg.norm(b)

        for strat in ("ppermute", "allgather"):
            out = jax.jit(lambda *a: gspn_scan_sp(
                *a, mesh=mesh, strategy=strat,
                boundary_dtype=jnp.bfloat16))(x, wl, wc, wr, lam)
            assert rel(out, ref) < 1e-2, (strat, rel(out, ref))

        g_ref = jax.grad(lambda x: jnp.sum(jnp.sin(
            R.gspn_scan_ref(x, wl, wc, wr, lam))))(x)
        g_sp = jax.jit(jax.grad(lambda x: jnp.sum(jnp.sin(
            gspn_scan_sp(x, wl, wc, wr, lam, mesh=mesh,
                         boundary_dtype=jnp.bfloat16)))))(x)
        assert rel(g_sp, g_ref) < 1e-2, rel(g_sp, g_ref)
    """, timeout=560)


# ---------------------------------------------------------------------------
# Serve: state pool narrowing.
# ---------------------------------------------------------------------------

def _serve_cfg():
    from repro.models.lm import LMConfig
    return LMConfig(
        name="mp-serve", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
        gspn_proxy_dim=2, gspn_row_width=8, remat="none",
        compute_dtype=jnp.float32)


@pytest.mark.serve
def test_state_pool_bf16_halves_bytes_and_survives_ticks():
    """bf16 pool ≥1.9× smaller than f32; float leaves stay bf16 across
    commit + decode updates (the pool must not widen after tick one)."""
    from repro.models.lm import init_lm
    from repro.serve.cache import StateCachePool
    from repro.serve.engine import Request, ServeEngine

    cfg = _serve_cfg()
    pool32 = StateCachePool(cfg, 2, 64, state_dtype=jnp.float32)
    pool16 = StateCachePool(cfg, 2, 64, state_dtype=jnp.bfloat16)
    assert pool32.nbytes / pool16.nbytes >= 1.9

    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_size=2, max_len=64,
                      prefill_chunk=8, state_dtype=jnp.bfloat16)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.arange(4 + 8 * i) % 64,
                           max_new_tokens=4))
    res = eng.run()
    assert len(res) == 3
    assert all(len(r.tokens) == 4 for r in res.values())
    for leaf in jax.tree.leaves(eng.pool.caches):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.bfloat16, leaf.dtype


@pytest.mark.serve
def test_state_pool_first_token_invariant_to_state_dtype():
    """The first sampled token comes from the (f32-computed) prefill
    logits before any narrowed state is read back, so it must be
    identical under bf16 state."""
    from repro.models.lm import init_lm
    from repro.serve.engine import Request, ServeEngine

    cfg = _serve_cfg()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    firsts = {}
    for sd in (None, jnp.bfloat16):
        eng = ServeEngine(params, cfg, batch_size=2, max_len=64,
                          state_dtype=sd)
        eng.submit(Request(uid=0, prompt=np.arange(12) % 64,
                           max_new_tokens=2))
        firsts[sd] = eng.run()[0].tokens[0]
    assert firsts[None] == firsts[jnp.bfloat16]


# ---------------------------------------------------------------------------
# Train: f32 master copy + dynamic loss scaling.
# ---------------------------------------------------------------------------

def _train_fixture(ls):
    from repro.configs.base import with_precision
    from repro.models.lm import LMConfig, init_lm
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import build_train_step, loss_scale_init
    from repro.optim.adamw import adamw_init

    cfg = LMConfig(name="mp-train", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                   unit=(("gspn", 1),), n_units=1, gspn_proxy_dim=2,
                   gspn_row_width=4, remat="none")
    cfg = with_precision(cfg, "bf16")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    step = build_train_step(cfg, AdamWConfig(), master_weights=True,
                            loss_scaling=ls)
    state = {"params": params,
             "opt": adamw_init(AdamWConfig(), params),
             "master": jax.tree.map(lambda p: p.astype(jnp.float32),
                                    params),
             "loss_scale": loss_scale_init(ls)}
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32) + 3,
             "labels": jnp.ones((2, 16), jnp.int32)}
    return step, state, batch


def test_master_copy_update_and_scale_growth():
    from repro.train.step import LossScaleConfig
    ls = LossScaleConfig(init_scale=2.0 ** 10, growth_interval=2)
    step, state, batch = _train_fixture(ls)
    s1, m1 = jax.jit(step)(state, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m1["grads_finite"]) == 1.0
    assert jax.tree.leaves(s1["master"])[0].dtype == jnp.float32
    assert jax.tree.leaves(s1["params"])[0].dtype == jnp.bfloat16
    # working copy is the master rounded to bf16
    for p, mast in zip(jax.tree.leaves(s1["params"]),
                       jax.tree.leaves(s1["master"])):
        np.testing.assert_array_equal(
            np.asarray(p), np.asarray(mast.astype(jnp.bfloat16)))
    assert int(s1["loss_scale"]["good_steps"]) == 1
    s2, _ = jax.jit(step)(s1, batch)
    # growth_interval=2 consecutive finite steps → scale doubles
    assert float(s2["loss_scale"]["scale"]) == 2.0 ** 11


def test_loss_scale_overflow_skips_update_and_backs_off():
    from repro.train.step import LossScaleConfig
    # 2^127 is finite in f32 but scale·loss overflows → inf grads →
    # the step must be skipped and the scale halved.
    ls = LossScaleConfig(init_scale=2.0 ** 127)
    step, state, batch = _train_fixture(ls)
    s1, m1 = jax.jit(step)(state, batch)
    assert float(m1["grads_finite"]) == 0.0
    for new, old in zip(jax.tree.leaves(s1["master"]),
                        jax.tree.leaves(state["master"])):
        np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
    assert float(s1["loss_scale"]["scale"]) == 2.0 ** 126
    assert int(s1["loss_scale"]["good_steps"]) == 0


def test_loss_scale_transition_unit():
    from repro.train.step import (LossScaleConfig, loss_scale_init,
                                  loss_scale_update, tree_all_finite)
    ls = LossScaleConfig(init_scale=4.0, growth_interval=3, min_scale=1.0)
    s = loss_scale_init(ls)
    s = loss_scale_update(ls, s, jnp.asarray(False))
    assert float(s["scale"]) == 2.0 and int(s["good_steps"]) == 0
    s = loss_scale_update(ls, s, jnp.asarray(False))
    s = loss_scale_update(ls, s, jnp.asarray(False))
    assert float(s["scale"]) == 1.0          # clamped at min_scale
    for _ in range(3):
        s = loss_scale_update(ls, s, jnp.asarray(True))
    assert float(s["scale"]) == 2.0          # grew after the interval
    assert not bool(tree_all_finite({"a": jnp.array([1.0, np.inf])}))
    assert bool(tree_all_finite({"a": jnp.array([1.0, 2.0])}))
