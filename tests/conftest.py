"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see ONE
CPU device; multi-device tests spawn subprocesses with their own flags."""

import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "kernels: interpret-mode Pallas kernel validation "
        "(cheap PR gate: pytest -m kernels)")


@pytest.fixture(scope="session", autouse=True)
def _single_device_guard():
    assert len(jax.devices()) == 1, (
        "tests must run on a single device; the dry-run sets its own flags")
    yield


@pytest.fixture
def rng():
    return np.random.default_rng(0)
