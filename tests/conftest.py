"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see ONE
CPU device; multi-device tests go through the ``run_sub`` fixture, which
spawns subprocesses with their own flags (the device count must be forced
BEFORE jax import, so it cannot be done in-process)."""

import ast
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

# Prepended to every ``run_sub`` body: 8 fake CPU devices + the compat
# mesh helpers (jax.sharding.AxisType / jax.set_mesh moved across jax
# releases; repro.compat papers over both).
SUB_PRELUDE = textwrap.dedent("""
    import os
    # APPENDED so it wins: on duplicated XLA flags the LAST occurrence
    # applies, and the inherited env may already force a device count
    # (importing repro.launch.dryrun in the pytest parent sets 512).
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh, set_mesh, shard_map
""")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "kernels: interpret-mode Pallas kernel validation "
        "(cheap PR gate: pytest -m kernels)")
    config.addinivalue_line(
        "markers",
        "distributed: multi-device behaviour on 8 forced host-platform CPU "
        "devices in subprocesses — no TPUs needed (pytest -m distributed)")
    config.addinivalue_line(
        "markers",
        "serve: continuous-batching engine / chunked-prefill / cache-pool "
        "tests on tiny configs (pytest -m serve)")
    config.addinivalue_line(
        "markers",
        "bench: benchmark --json schema and perf-regression-gate tests "
        "(pytest -m bench)")
    config.addinivalue_line(
        "markers",
        "obs: tracing/metrics subsystem + instrumentation contracts, "
        "including the disabled-overhead pin (pytest -m obs)")


@pytest.fixture(scope="session", autouse=True)
def _single_device_guard():
    assert len(jax.devices()) == 1, (
        "tests must run on a single device; the dry-run sets its own flags")
    yield


@pytest.fixture(scope="session")
def run_sub():
    """Run a python test body on 8 fake CPU devices in a subprocess.

    Subprocess-or-skip: a one-time probe checks that this interpreter can
    spawn subprocesses AND that the host-platform device-count flag takes
    effect (it does not on real TPU backends); otherwise every dependent
    test skips instead of failing on CI hardware without TPUs.
    """
    try:
        probe = subprocess.run(
            [sys.executable, "-c",
             SUB_PRELUDE + "assert jax.device_count() == 8"],
            capture_output=True, text=True, timeout=240)
        ok, why = probe.returncode == 0, probe.stderr.strip()[-400:]
    except (OSError, subprocess.SubprocessError) as exc:  # no subprocesses
        ok, why = False, repr(exc)
    if not ok:
        pytest.skip(f"8-device host-platform subprocess unavailable: {why}")

    def run(body: str, timeout: int = 560):
        dedented = textwrap.dedent(body)
        # Guard against the silent-no-op failure mode: when a shared
        # setup string is indented shallower than the test body, dedent
        # strips only the common prefix and the body's statements end up
        # NESTED inside the last setup def — syntactically valid, never
        # executed, subprocess exits 0.  A real body always has at least
        # one top-level statement that is not an import or a definition.
        tree = ast.parse(dedented)
        assert any(not isinstance(n, (ast.Import, ast.ImportFrom,
                                      ast.FunctionDef, ast.ClassDef))
                   for n in tree.body), (
            "run_sub body has no top-level executable statements — "
            "shared setup string indented shallower than the body?")
        script = SUB_PRELUDE + dedented
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=timeout,
                           env=None)
        assert r.returncode == 0, \
            f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        return r.stdout

    return run


@pytest.fixture
def rng():
    return np.random.default_rng(0)
