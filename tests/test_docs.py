"""Documentation invariants: every `DESIGN.md §N` citation in the code
resolves to a real section heading (the contract DESIGN.md's preamble
promises the re-anchoring loop)."""

import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_design_citations_resolve():
    sections = set(re.findall(r"^## §(\d+)", (ROOT / "DESIGN.md")
                              .read_text(), flags=re.M))
    assert sections, "DESIGN.md has no §-numbered sections"
    bad = []
    skip_dirs = {".git", ".venv", "venv", "build", "dist", "node_modules",
                 "__pycache__", ".claude"}
    for path in ROOT.rglob("*.py"):
        if skip_dirs & set(path.parts):
            continue
        for n in re.findall(r"DESIGN\.md §(\d+)", path.read_text()):
            if n not in sections:
                bad.append((str(path.relative_to(ROOT)), f"§{n}"))
    for name in ("README.md", "ROADMAP.md", "CHANGES.md"):
        for n in re.findall(r"DESIGN\.md §(\d+)", (ROOT / name).read_text()):
            if n not in sections:
                bad.append((name, f"§{n}"))
    assert not bad, f"unresolved DESIGN.md citations: {bad}"


def test_design_s13_documents_observability():
    """§13 is the observability contract: the section must exist and
    name the pieces the instrumented layers rely on, so a future rewrite
    cannot silently drop the documented semantics."""
    text = (ROOT / "DESIGN.md").read_text()
    m = re.search(r"^## §13 .*$", text, flags=re.M)
    assert m, "DESIGN.md is missing §13 (observability)"
    body = text[m.end():]
    nxt = re.search(r"^## §\d+", body, flags=re.M)
    section = body[:nxt.start()] if nxt else body
    for needle in ("obs.trace", "Chrome trace", "Prometheus",
                   "--trace-out", "--metrics-out", "plans_summary",
                   "queue_depth_mean", "named_scope"):
        assert needle in section, f"DESIGN.md §13 no longer mentions " \
                                  f"{needle!r}"


def test_design_s15_documents_serving_tier():
    """§15 is the serving-tier contract: router policies, prefix/state
    reuse, and the consolidated plan/handle/flag surfaces must stay
    named so a rewrite cannot silently drop the documented semantics."""
    text = (ROOT / "DESIGN.md").read_text()
    m = re.search(r"^## §15 .*$", text, flags=re.M)
    assert m, "DESIGN.md is missing §15 (serving tier)"
    body = text[m.end():]
    nxt = re.search(r"^## §\d+", body, flags=re.M)
    section = body[:nxt.start()] if nxt else body
    for needle in ("least_loaded", "ttft", "serve_prefill_chunk_seconds",
                   "router_slo_at_risk_total", "PrefixStateCache",
                   "chunk_resume", "RequestHandle", "plan_for_spec",
                   "fail_replica", "--replicas", "--prefix-cache",
                   "launch/args.py", "cached_tokens"):
        assert needle in section, f"DESIGN.md §15 no longer mentions " \
                                  f"{needle!r}"
