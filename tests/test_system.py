"""End-to-end behaviour: losses fall on the synthetic stream for a small
model of each interesting family; serving consumes a trained checkpoint."""

import functools
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, host_batch
from repro.models.lm import LMConfig, init_lm, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _train(cfg, steps=30, lr=2e-3, seed=0):
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=seed)
    ocfg = AdamWConfig(lr=lr, warmup_steps=3, total_steps=steps * 2)
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    state = adamw_init(ocfg, params)
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: lm_loss(p, cfg, b)[0]))
    upd = jax.jit(functools.partial(adamw_update, ocfg))
    losses = []
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in host_batch(dcfg, step).items()}
        loss, g = grad_fn(params, b)
        params, state, _ = upd(g, state, params)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("kind,extra", [
    ("attn", {}),
    ("gspn", {"gspn_proxy_dim": 4, "gspn_row_width": 8}),
    ("mlstm", {}),
    ("mamba", {"ssm_head_dim": 16}),
])
def test_losses_fall(kind, extra):
    cfg = LMConfig(name=f"sys-{kind}", family="dense", n_layers=2,
                   d_model=64, n_heads=4, n_kv_heads=2 if kind == "attn"
                   else 4, d_ff=128, vocab=256,
                   unit=((kind, 2),), n_units=1, remat="none", **extra)
    losses = _train(cfg)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.25, f"{kind}: {first:.3f} -> {last:.3f}"


def test_train_then_serve_roundtrip():
    """Train briefly, checkpoint, restore, serve — the full lifecycle."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.serve.engine import Request, ServeEngine

    cfg = LMConfig(name="lifecycle", family="dense", n_layers=2, d_model=48,
                   n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
                   unit=(("attn", 2),), n_units=1, remat="none")
    dcfg = DataConfig(vocab=128, seq_len=24, global_batch=4)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = adamw_init(ocfg, params)
    grad_fn = jax.jit(jax.value_and_grad(lambda p, b: lm_loss(p, cfg, b)[0]))
    upd = jax.jit(functools.partial(adamw_update, ocfg))
    for step in range(10):
        b = {k: jnp.asarray(v) for k, v in host_batch(dcfg, step).items()}
        _, g = grad_fn(params, b)
        params, state, _ = upd(g, state, params)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(10, {"params": params})
        restored, _ = mgr.restore(target={"params": params})

    eng = ServeEngine(restored["params"], cfg, batch_size=2, max_len=48)
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3]), max_new_tokens=4))
    res = eng.run()
    assert len(res[0].tokens) == 4


def test_grad_accum_matches_full_batch():
    """K-microbatch accumulation == single-batch gradients (same math)."""
    from repro.train.step import build_train_step

    cfg = LMConfig(name="ga", family="dense", n_layers=2, d_model=48,
                   n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
                   unit=(("attn", 2),), n_units=1, remat="none")
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": adamw_init(ocfg, params)}
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 24), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    s1, m1 = build_train_step(cfg, ocfg)(state, batch)
    s4, m4 = build_train_step(cfg, ocfg, grad_accum=4)(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=3e-3)
