"""Fused multi-direction dispatch: equivalence of the pair-fused path
against the per-direction reference (all four directions, compact channel
mode, non-square grids), gradients through the pair custom_vjp, the
dispatch-count guarantee (≤2 pallas_calls for a 4-direction pass), and the
single-launch quad kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.core import gspn as G
from repro.core.gspn import _from_canonical, _to_canonical
from repro.kernels import gspn_multidir as MK
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan_pair

pytestmark = pytest.mark.kernels

DIRECTIONS = G.DIRECTIONS


def _make_dir_inputs(gd, h, w, gw, seed=0):
    """x/lam plus per-direction taps in ORIGINAL orientation (the
    directional_scan multi convention)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (gd, h, w))
    lam = jax.random.normal(ks[1], (len(DIRECTIONS), gd, h, w))
    logits = jax.random.normal(ks[2], (len(DIRECTIONS), gw, h, w, 3))
    wls, wcs, wrs = [], [], []
    for d_idx, d in enumerate(DIRECTIONS):
        wl, wc, wr = G._normalize_taps_oriented(logits[d_idx], d, "softmax")
        wls.append(wl)
        wcs.append(wc)
        wrs.append(wr)
    return x, jnp.stack(wls), jnp.stack(wcs), jnp.stack(wrs), lam, logits


def _ref_direction(x, wl, wc, wr, lam, d):
    """Per-direction oracle: orient, lax.scan reference, orient back."""
    h = R.gspn_scan_ref(
        _to_canonical(x, d), _to_canonical(wl, d), _to_canonical(wc, d),
        _to_canonical(wr, d), _to_canonical(lam, d))
    return _from_canonical(h, d)


@pytest.mark.parametrize("shape,cpw", [((2, 16, 16), 1),    # square
                                       ((4, 8, 24), 2),     # non-square, compact
                                       ((6, 32, 16), 3)])   # H > W, compact
@pytest.mark.parametrize("impl", ["xla", "multidir"])
def test_multi_directional_scan_matches_per_direction(shape, cpw, impl):
    gd, h, w = shape
    x, wl, wc, wr, lam, _ = _make_dir_inputs(gd, h, w, gd // cpw)
    out = G.directional_scan(x, wl, wc, wr, lam, DIRECTIONS, impl=impl)
    assert out.shape == (len(DIRECTIONS), gd, h, w)
    for d_idx, d in enumerate(DIRECTIONS):
        ref = _ref_direction(x, wl[d_idx], wc[d_idx], wr[d_idx],
                             lam[d_idx], d)
        np.testing.assert_allclose(np.asarray(out[d_idx]), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"direction {d}")


@pytest.mark.parametrize("impl", ["xla", "multidir"])
def test_multi_directional_scan_gradients(impl):
    gd, h, w, cpw = 4, 8, 12, 2
    x, _, _, _, lam, logits = _make_dir_inputs(gd, h, w, gd // cpw, seed=3)

    def loss(x, logits, lam, impl):
        wls, wcs, wrs = [], [], []
        for d_idx, d in enumerate(DIRECTIONS):
            a, b_, c = G._normalize_taps_oriented(logits[d_idx], d, "softmax")
            wls.append(a)
            wcs.append(b_)
            wrs.append(c)
        out = G.directional_scan(x, jnp.stack(wls), jnp.stack(wcs),
                                 jnp.stack(wrs), lam, DIRECTIONS, impl=impl)
        return jnp.sum(jnp.sin(out))

    def loss_ref(x, logits, lam):
        acc = 0.0
        for d_idx, d in enumerate(DIRECTIONS):
            a, b_, c = G._normalize_taps_oriented(logits[d_idx], d, "softmax")
            acc = acc + jnp.sum(jnp.sin(
                _ref_direction(x, a, b_, c, lam[d_idx], d)))
        return acc

    g_got = jax.grad(loss, argnums=(0, 1, 2))(x, logits, lam, impl)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, logits, lam)
    for a, b in zip(g_got, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_four_direction_pass_issues_at_most_two_pallas_calls(monkeypatch):
    calls = []
    real = pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    gd, h, w = 2, 8, 16
    x, wl, wc, wr, lam, _ = _make_dir_inputs(gd, h, w, gd)
    out = G.directional_scan(x, wl, wc, wr, lam, DIRECTIONS, impl="multidir")
    jax.block_until_ready(out)
    assert len(calls) == 2, f"expected 2 fused dispatches, saw {calls}"


def test_pair_op_chunked_matches_blockdiag():
    gd, h, w, chunk = 4, 16, 20, 4
    x, wl, wc, wr, lam, _ = _make_dir_inputs(gd, h, w, 2, seed=5)
    out = gspn_scan_pair(x, wl[:2], wc[:2], wr[:2], lam[:2],
                         chunk=chunk, impl="multidir")
    ref_tb = R.gspn_scan_chunked_ref(x, wl[0], wc[0], wr[0], lam[0], chunk)
    ref_bt = jnp.flip(R.gspn_scan_chunked_ref(
        jnp.flip(x, 1), jnp.flip(wl[1], 1), jnp.flip(wc[1], 1),
        jnp.flip(wr[1], 1), jnp.flip(lam[1], 1), chunk), 1)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref_tb),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref_bt),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cpw", [1, 2])
def test_quad_single_launch_matches_per_direction(cpw):
    gd, n = 2 * cpw, 16
    x, wl, wc, wr, lam, _ = _make_dir_inputs(gd, n, n, gd // cpw, seed=7)
    T = lambda a: jnp.swapaxes(a, -1, -2)
    # quad convention: entries 2/3 (lr/rl) in transposed geometry.
    taps4 = {
        "wl": jnp.stack([wl[0], wl[1], T(wl[2]), T(wl[3])]),
        "wc": jnp.stack([wc[0], wc[1], T(wc[2]), T(wc[3])]),
        "wr": jnp.stack([wr[0], wr[1], T(wr[2]), T(wr[3])]),
    }
    lam4 = jnp.stack([lam[0], lam[1], T(lam[2]), T(lam[3])])
    out = MK.gspn_scan_quad_pallas(x, taps4, lam4, channels_per_weight=cpw,
                                   row_tile=4)
    for d_idx, d in enumerate(DIRECTIONS):
        got = out[d_idx] if d_idx < 2 else T(out[d_idx])
        ref = _ref_direction(x, wl[d_idx], wc[d_idx], wr[d_idx],
                             lam[d_idx], d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"direction {d}")


def test_attention_multidir_equals_xla_including_grads():
    """impl='multidir' end-to-end through the attention module."""
    img = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 24, 32))
    cfgs = {impl: G.GSPNAttentionConfig(dim=32, proxy_dim=4, impl=impl)
            for impl in ("multidir", "xla")}
    params = G.init_gspn_attention(jax.random.PRNGKey(1), cfgs["xla"])
    ys, gs = {}, {}
    for impl, cfg in cfgs.items():
        ys[impl] = G.apply_gspn_attention(params, img, cfg)
        gs[impl] = jax.grad(lambda p: jnp.sum(jnp.sin(
            G.apply_gspn_attention(p, img, cfg))))(params)
    np.testing.assert_allclose(np.asarray(ys["multidir"]),
                               np.asarray(ys["xla"]), rtol=2e-5, atol=2e-5)
    for k in gs["xla"]:
        np.testing.assert_allclose(np.asarray(gs["multidir"][k]),
                                   np.asarray(gs["xla"][k]),
                                   rtol=1e-4, atol=1e-5)
