"""Spatial sequence parallelism (parallel/gspn_sp.py, DESIGN.md §8).

Runs on 8 forced host-platform CPU devices via the ``run_sub`` conftest
fixture.  Proves:

* numerical equivalence of ``impl="sp"`` vs the single-device fused path
  to 1e-5 (f32) — forward AND gradients — across all four directions,
  compact channel mode, and non-divisible block sizes;
* both exchange strategies (ppermute chain / all-gather prefix fold);
* the collective count: ≤ 1 logical boundary exchange per scan direction
  (a K-1-hop ppermute chain of boundary columns counts as one), and no
  full-activation collective anywhere in the forward scan;
* model-layer wiring (vision attention block and LM folded-sequence
  mixer run sharded and match their single-device outputs);
* the graceful single-device fallback (no mesh ⇒ plain fused scan).
"""

import pathlib
import sys

import pytest

pytestmark = pytest.mark.distributed

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(ROOT))

# Shared by the equivalence bodies: direction-stacked inputs in ORIGINAL
# orientation (taps generated per oriented geometry, like the attention
# module does), plus a scalarising loss for gradient comparison.
# MUST be indented to the same depth as the run_sub bodies (8 spaces):
# textwrap.dedent runs over the concatenation, and a shallower setup
# would leave the body nested inside the last def here — a silent no-op
# (conftest.run_sub now rejects such bodies structurally).
_SETUP = """
        from repro.core import gspn as G

        def inputs(b, cp, h, w, seed=0):
            g = b * cp
            nd = len(G.DIRECTIONS)
            ks = jax.random.split(jax.random.PRNGKey(seed), 3)
            x = jax.random.normal(ks[0], (g, h, w))
            lam = jax.nn.sigmoid(jax.random.normal(ks[1], (nd, g, h, w)))
            logits = jax.random.normal(ks[2], (nd, b, h, w, 3))
            taps = [G._normalize_taps_oriented(logits[i], d, "softmax")
                    for i, d in enumerate(G.DIRECTIONS)]
            wl, wc, wr = (jnp.stack([t[k] for t in taps]) for k in range(3))
            return x, wl, wc, wr, lam

        def loss(fn):
            return lambda *a: jnp.sum(jnp.sin(fn(*a)))

        def check_tree(got, want, rtol, atol):
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=rtol, atol=atol)
"""


def test_sp_matches_single_device_all_directions(run_sub):
    """All four directions at once through directional_scan: forward and
    all five gradients, compact channel mode (cpw=3), scan lengths that do
    NOT divide the 8-way mesh (H=21 vertical, W=12 horizontal).  "auto"
    resolves the opposite-direction pairs to the fused single-collective
    exchange; the explicit strategies exercise the per-direction
    fallback knob — all three must match the single-device oracle."""
    run_sub(_SETUP + """
        mesh = make_mesh((8,), ("seq",))
        x, wl, wc, wr, lam = inputs(2, 3, 21, 12)

        ref_fn = lambda *a: G.directional_scan(*a, G.DIRECTIONS, impl="xla")
        ref = ref_fn(x, wl, wc, wr, lam)
        g_ref = jax.grad(loss(ref_fn), argnums=(0, 1, 2, 3, 4))(
            x, wl, wc, wr, lam)

        for strategy in ("auto", "ppermute", "allgather"):
            sp_fn = lambda *a: G.directional_scan(
                *a, G.DIRECTIONS, impl="sp", mesh=mesh,
                sp_strategy=strategy)
            out = jax.jit(sp_fn)(x, wl, wc, wr, lam)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)
            g_sp = jax.jit(jax.grad(loss(sp_fn), argnums=(0, 1, 2, 3, 4)))(
                x, wl, wc, wr, lam)
            check_tree(g_sp, g_ref, 1e-4, 1e-5)
    """, timeout=560)


def test_sp_non_compact_and_divisible_blocks(run_sub):
    """Per-channel taps (cpw=1) and an evenly dividing scan length, single
    direction each way (tb + rl), against BOTH the XLA oracle and the
    fused Pallas kernel (interpret)."""
    run_sub(_SETUP + """
        from repro.kernels.ops import gspn_scan
        mesh = make_mesh((8,), ("seq",))
        g, h, w = 4, 24, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        x = jax.random.normal(ks[0], (g, h, w))
        lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
        wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (g, h, w, 3)))

        for d in ("tb", "rl"):
            args = (x, wl, wc, wr, lam)
            ref_fn = lambda *a: G.directional_scan(*a, d, impl="xla")
            pal_fn = lambda *a: G.directional_scan(*a, d, impl="pallas")
            sp_fn = lambda *a: G.directional_scan(*a, d, impl="sp",
                                                  mesh=mesh)
            ref = ref_fn(*args)
            np.testing.assert_allclose(np.asarray(pal_fn(*args)),
                                       np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(jax.jit(sp_fn)(*args)),
                                       np.asarray(ref),
                                       rtol=1e-5, atol=1e-5)
            g_sp = jax.jit(jax.grad(loss(sp_fn), argnums=(0, 1, 2, 3, 4)))(
                *args)
            g_ref = jax.grad(loss(ref_fn), argnums=(0, 1, 2, 3, 4))(*args)
            check_tree(g_sp, g_ref, 1e-4, 1e-5)
    """, timeout=560)


def test_sp_backward_compact_nondivisible_grid_edges(run_sub):
    """The cell the suite used to skip: sp BACKWARD under compact channel
    mode (cpw=3) with block sizes that do NOT divide the 8-way mesh, probed
    at BOTH grid edges — a loss reading only the first or only the last
    row.  Edge rows are where block-local padding and the boundary carry
    injection meet: row 0 of block 0 has no incoming carry, the last row
    lives in a partially-padded block (h=19 → h_blk=3, last block is pure
    padding; h=9 → h_blk=2, three trailing blocks are pure padding), so a
    cotangent concentrated there must flow back through the exchange chain
    without picking up padded-lane garbage.  Both strategies, all five
    gradients, against the reference scan."""
    run_sub(_SETUP + """
        from repro.kernels.ref import gspn_scan_ref
        from repro.parallel.gspn_sp import gspn_scan_sp

        mesh = make_mesh((8,), ("seq",))
        gw, cpw, w = 2, 3, 8
        g = gw * cpw
        for h in (19, 9):
            ks = jax.random.split(jax.random.PRNGKey(h), 3)
            x = jax.random.normal(ks[0], (g, h, w))
            lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
            wl, wc, wr = G.normalize_taps(
                jax.random.normal(ks[2], (gw, h, w, 3)))
            args = (x, wl, wc, wr, lam)
            for row in (0, h - 1):
                edge = lambda fn, row=row: (
                    lambda *a: jnp.sum(jnp.sin(fn(*a)[:, row])))
                g_ref = jax.grad(edge(gspn_scan_ref),
                                 argnums=(0, 1, 2, 3, 4))(*args)
                for strategy in ("ppermute", "allgather"):
                    sp_fn = lambda *a, s=strategy: gspn_scan_sp(
                        *a, mesh=mesh, strategy=s)
                    g_sp = jax.jit(jax.grad(edge(sp_fn),
                                            argnums=(0, 1, 2, 3, 4)))(*args)
                    check_tree(g_sp, g_ref, 1e-4, 1e-5)
    """, timeout=560)


def test_sp_collective_counts(run_sub):
    """Pins the communication contract of one scan direction: at most ONE
    logical boundary exchange — either ≤ K-1 chained ppermutes whose
    payload is exactly the (G, W) boundary column, or 2 all-gathers (the
    (G_w, W, W) transfer operator + the boundary column).  No other
    collective kind, and never a full (G, H_blk, W) activation payload."""
    run_sub("""
        from repro.core.gspn import normalize_taps
        from repro.parallel.gspn_sp import gspn_scan_sp

        def collectives(fn, *args):
            found = []
            def walk(jaxpr):
                for eqn in jaxpr.eqns:
                    nm = eqn.primitive.name
                    if ("all_gather" in nm or "psum" in nm
                            or nm in ("ppermute", "all_to_all", "pgather")):
                        found.append(
                            (nm, [tuple(v.aval.shape) for v in eqn.invars]))
                    for v in eqn.params.values():
                        vs = v if isinstance(v, (list, tuple)) else [v]
                        for j in vs:
                            if hasattr(j, "jaxpr"):
                                walk(j.jaxpr)
                            elif hasattr(j, "eqns"):
                                walk(j)
            walk(jax.make_jaxpr(fn)(*args).jaxpr)
            return found

        mesh = make_mesh((8, ), ("seq",))
        g_dim, gw, h, w = 6, 2, 24, 16
        hb = h // 8
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (g_dim, h, w))
        lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g_dim, h, w)))
        wl, wc, wr = normalize_taps(jax.random.normal(ks[2], (gw, h, w, 3)))

        cs = collectives(lambda *a: gspn_scan_sp(*a, mesh=mesh,
                                                 strategy="ppermute"),
                         x, wl, wc, wr, lam)
        kinds = {nm for nm, _ in cs}
        assert kinds == {"ppermute"}, cs
        assert len(cs) <= 7, cs                      # one K-1-hop chain
        for nm, shapes in cs:                        # boundary columns only
            assert shapes == [(g_dim, w)], cs

        cs = collectives(lambda *a: gspn_scan_sp(*a, mesh=mesh,
                                                 strategy="allgather"),
                         x, wl, wc, wr, lam)
        kinds = {nm for nm, _ in cs}
        assert all("all_gather" in k for k in kinds), cs
        assert len(cs) == 2, cs                      # operator + boundary
        payloads = sorted(s for _, ss in cs for s in ss)
        assert payloads == [(gw, w, w), (g_dim, w)] or \
               payloads == sorted([(gw, w, w), (g_dim, w)]), cs
        for _, shapes in cs:                         # never an activation
            assert (g_dim, hb, w) not in shapes and (g_dim, h, w) not in shapes
    """)


def test_sp_hybrid_data_seq_mesh(run_sub):
    """On a ("data", "seq") mesh the G dim stays data-sharded inside the
    scan's shard_map (no activation gather to replicate G) and the only
    collective is still the seq boundary exchange."""
    run_sub(_SETUP + """
        from repro.parallel.gspn_sp import gspn_scan_sp
        mesh = make_mesh((2, 4), ("data", "seq"))
        g, gw, h, w = 8, 4, 21, 16          # cpw=2, both divide data=2
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        x = jax.random.normal(ks[0], (g, h, w))
        lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
        wl, wc, wr = G.normalize_taps(
            jax.random.normal(ks[2], (gw, h, w, 3)))

        from repro.kernels.ref import gspn_scan_ref
        ref_fn = lambda *a: gspn_scan_ref(*a)
        sp_fn = lambda *a: gspn_scan_sp(*a, mesh=mesh)
        ref = ref_fn(x, wl, wc, wr, lam)
        out = jax.jit(sp_fn)(x, wl, wc, wr, lam)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        g_sp = jax.jit(jax.grad(loss(sp_fn), argnums=(0, 1, 2, 3, 4)))(
            x, wl, wc, wr, lam)
        g_ref = jax.grad(loss(ref_fn), argnums=(0, 1, 2, 3, 4))(
            x, wl, wc, wr, lam)
        check_tree(g_sp, g_ref, 1e-4, 1e-5)

        # G is never gathered to replicate: no collective moves a full
        # activation payload — only boundary columns and the transfer
        # operator cross devices.  (An output-sharding pin is impossible
        # here: h=21 cannot lay out on the 4-way seq axis at all, so jit
        # is free to replicate the reassembled output.)
        def collective_payloads(fn, *args):
            found = []
            def walk(jaxpr):
                for eqn in jaxpr.eqns:
                    nm = eqn.primitive.name
                    if ("all_gather" in nm or "psum" in nm
                            or nm in ("ppermute", "all_to_all", "pgather")):
                        found.extend(tuple(v.aval.shape)
                                     for v in eqn.invars)
                    for v in eqn.params.values():
                        vs = v if isinstance(v, (list, tuple)) else [v]
                        for j in vs:
                            if hasattr(j, "jaxpr"):
                                walk(j.jaxpr)
                            elif hasattr(j, "eqns"):
                                walk(j)
            walk(jax.make_jaxpr(fn)(*args).jaxpr)
            return found

        h_blk = -(-h // 4)
        payloads = collective_payloads(sp_fn, x, wl, wc, wr, lam)
        assert payloads, "expected at least the boundary exchange"
        for shp in payloads:
            assert shp not in ((g, h_blk, w), (g, h, w)), payloads
    """, timeout=560)


def test_sp_model_layer_wiring(run_sub):
    """The vision attention block and the LM folded-sequence mixer run
    sharded (impl="sp" + mesh) and match their single-device outputs."""
    run_sub("""
        import dataclasses
        from repro.core import gspn as G

        mesh = make_mesh((8,), ("seq",))
        # Vision attention module: 14x14 grid (non-divisible by 8).
        cfg = G.GSPNAttentionConfig(dim=16, proxy_dim=2, impl="xla")
        params = G.init_gspn_attention(jax.random.PRNGKey(0), cfg)
        xv = jax.random.normal(jax.random.PRNGKey(1), (2, 14, 14, 16))
        ref = G.apply_gspn_attention(params, xv, cfg)
        cfg_sp = dataclasses.replace(cfg, impl="sp")
        out = jax.jit(lambda p, x: G.apply_gspn_attention(
            p, x, cfg_sp, mesh=mesh))(params, xv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

        # LM mixer: L=100 folds to a 13x8 grid; both passes shard.
        scfg = G.GSPNSeqConfig(dim=16, proxy_dim=2, row_width=8, impl="xla")
        sp = G.init_gspn_seq_mixer(jax.random.PRNGKey(2), scfg)
        xt = jax.random.normal(jax.random.PRNGKey(3), (2, 100, 16))
        ref = G.apply_gspn_seq_mixer(sp, xt, scfg)
        scfg_sp = dataclasses.replace(scfg, impl="sp")
        out = jax.jit(lambda p, x: G.apply_gspn_seq_mixer(
            p, x, scfg_sp, mesh=mesh))(sp, xt)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

        # Full vision backbone end-to-end with the Ctx mesh threading.
        from repro.models.vision import (GSPNVisionConfig, init_vision,
                                         apply_vision)
        from repro.models.lm import Ctx
        vcfg = GSPNVisionConfig(name="t", img_size=16, n_classes=4,
                                dims=(8, 12), depths=(1, 1), proxy_dim=2,
                                impl="xla")
        vp = init_vision(jax.random.PRNGKey(4), vcfg)
        img = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 16, 3))
        ref = apply_vision(vp, img, vcfg)
        vcfg_sp = dataclasses.replace(vcfg, impl="sp")
        ctx = Ctx(mesh=mesh)
        out = jax.jit(lambda p, x: apply_vision(p, x, vcfg_sp,
                                                ctx=ctx))(vp, img)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
    """, timeout=560)


def test_sp_sharded_activation_specs(run_sub):
    """parallel/sharding.py scan-dim helpers place activations on the seq
    axis (and degrade to replication when the mesh lacks it)."""
    run_sub("""
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import (scan_dim_spec,
                                             sp_activation_shardings)
        from repro.launch.mesh import make_sp_mesh, make_mesh_for_devices

        assert scan_dim_spec(3) == P("data", "seq", None)
        assert scan_dim_spec(4, 1, dp_axes=("data",)) == \\
            P("data", "seq", None, None)

        smesh = make_sp_mesh()
        x = jnp.zeros((4, 16, 8))
        sh = sp_activation_shardings(x, smesh)
        assert sh.spec == P(None, "seq", None), sh.spec

        dmesh = make_mesh_for_devices(jax.devices(), model_parallel=2,
                                      seq_parallel=2)
        assert dmesh.axis_names == ("data", "seq", "model")
        sh = sp_activation_shardings(x, dmesh)
        assert sh.spec == P("data", "seq", None), sh.spec
        # no seq axis on the mesh -> dp only
        dp = make_mesh_for_devices(jax.devices(), model_parallel=2)
        sh = sp_activation_shardings(x, dp)
        assert sh.spec == P("data", None, None), sh.spec
    """)


def test_sp_single_device_fallback():
    """Without a mesh (or with a 1-wide seq axis) impl="sp" must silently
    take the plain fused path — in-process, one device."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import gspn as G
    from repro.kernels.ops import gspn_scan

    g, h, w = 3, 9, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (g, h, w, 3)))
    ref = gspn_scan(x, wl, wc, wr, lam, impl="xla")
    out = gspn_scan(x, wl, wc, wr, lam, impl="sp")       # no mesh anywhere
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # chunked requests route to the (already parallel) chunked fused path
    out = gspn_scan(x, wl, wc, wr, lam, impl="sp", chunk=3)
    ref = gspn_scan(x, wl, wc, wr, lam, impl="xla", chunk=3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# Shared by the fused-pair bodies: direction-stacked pair inputs in the
# ops.gspn_scan_pair layout (one stream, per-slot taps/lam; slot 0 scans
# top→bottom, slot 1 bottom→top) plus the slot-wise reference.
_PAIR_SETUP = _SETUP + """
        from repro.kernels.ref import gspn_scan_ref
        from repro.parallel.gspn_sp import (collectives_in_jaxpr,
                                            gspn_scan_sp_pair)

        def pair_inputs(gw, cpw, h, w, seed=0):
            g = gw * cpw
            ks = jax.random.split(jax.random.PRNGKey(seed), 4)
            x = jax.random.normal(ks[0], (g, h, w))
            lam2 = jax.nn.sigmoid(jax.random.normal(ks[1], (2, g, h, w)))
            wl2, wc2, wr2 = (
                jnp.stack(t) for t in zip(
                    G.normalize_taps(jax.random.normal(ks[2],
                                                       (gw, h, w, 3))),
                    G.normalize_taps(jax.random.normal(ks[3],
                                                       (gw, h, w, 3)))))
            return x, wl2, wc2, wr2, lam2

        def pair_ref(x, wl2, wc2, wr2, lam2):
            return jnp.stack([
                gspn_scan_ref(x, wl2[0], wc2[0], wr2[0], lam2[0]),
                gspn_scan_ref(x, wl2[1], wc2[1], wr2[1], lam2[1],
                              reverse=True)])
"""


def test_sp_fused_pair_single_collective(run_sub):
    """The tentpole's communication contract (ISSUE 10 acceptance): the
    fused opposite-direction pair emits exactly ONE boundary collective —
    a single all-gather of the stacked compact (T, b) states plus the
    3 piggybacked adjoint edge weight rows, payload (2, G_w·W+G+3·G_w, W)
    — down from 2 per-direction exchanges; zero ppermutes; the gradient
    adds exactly one more fused exchange (its backward pair).  And the
    fused path matches both the per-direction fallback and the slot-wise
    reference to 1e-5 fwd / 1e-4 grad on compact (cpw=3) non-divisible
    (h=21 on 8 blocks) shapes."""
    run_sub(_PAIR_SETUP + """
        mesh = make_mesh((8,), ("seq",))
        gw, cpw, w, h = 2, 3, 8, 21
        g = gw * cpw
        args = pair_inputs(gw, cpw, h, w)

        fused = lambda *a: gspn_scan_sp_pair(*a, mesh=mesh)
        per_dir = lambda *a: gspn_scan_sp_pair(*a, mesh=mesh,
                                               strategy="allgather")

        # --- jaxpr pin: ONE collective forward (2 -> 1 per pair) ---
        cs = collectives_in_jaxpr(fused, *args)
        assert len(cs) == 1, cs
        nm, shape, dtype = cs[0]
        assert "all_gather" in nm and dtype == "float32", cs
        assert shape == (2, gw * w + g + 3 * gw, w), cs
        # the per-direction fallback pays 2 all-gathers per direction
        pcs = collectives_in_jaxpr(per_dir, *args)
        assert len(pcs) == 4 and all("all_gather" in c[0] for c in pcs), pcs

        # --- gradient: 2 fused exchanges total (fwd + mirrored bwd),
        # still zero ppermutes.  psum counts are NOT pinned here: they
        # are shard_map transpose artifacts of the block-sharded
        # cotangents, present identically in the per-direction path.
        gfn = lambda f: jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))),
                                 argnums=(0, 1, 2, 3, 4))
        gcs = collectives_in_jaxpr(gfn(fused), *args)
        ags = [c for c in gcs if "all_gather" in c[0]]
        assert len(ags) == 2, gcs
        assert not [c for c in gcs if c[0] == "ppermute"], gcs

        # --- equivalence: fused vs reference and vs fallback ---
        ref = pair_ref(*args)
        out = jax.jit(fused)(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(jax.jit(per_dir)(*args)),
                                   np.asarray(ref), rtol=1e-5, atol=1e-5)
        g_f = jax.jit(gfn(fused))(*args)
        g_r = gfn(pair_ref)(*args)
        check_tree(g_f, g_r, 1e-4, 1e-5)

        # divisible blocks + per-channel taps through the same pin
        args = pair_inputs(4, 1, 24, 8, seed=1)
        cs = collectives_in_jaxpr(fused, *args)
        assert len(cs) == 1 and "all_gather" in cs[0][0], cs
        np.testing.assert_allclose(np.asarray(jax.jit(fused)(*args)),
                                   np.asarray(pair_ref(*args)),
                                   rtol=1e-5, atol=1e-5)
        check_tree(jax.jit(gfn(fused))(*args), gfn(pair_ref)(*args),
                   1e-4, 1e-5)
    """, timeout=560)


def test_sp_pair_exchange_modes(run_sub):
    """The overlap rung's measurement knob: "serial" only inserts an
    optimization barrier (gather must land before the local scan), so it
    must be numerically IDENTICAL to production "overlap"; "skip" elides
    the collective entirely (the timing floor) and must be WRONG across
    blocks — and emit zero collectives."""
    run_sub(_PAIR_SETUP + """
        mesh = make_mesh((8,), ("seq",))
        args = pair_inputs(2, 2, 24, 8)
        ref = pair_ref(*args)

        outs = {m: jax.jit(lambda *a, m=m: gspn_scan_sp_pair(
                    *a, mesh=mesh, exchange_mode=m))(*args)
                for m in ("overlap", "serial", "skip")}
        np.testing.assert_allclose(np.asarray(outs["overlap"]),
                                   np.asarray(ref), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(outs["serial"]),
                                      np.asarray(outs["overlap"]))
        assert not np.allclose(np.asarray(outs["skip"]), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
        cs = collectives_in_jaxpr(
            lambda *a: gspn_scan_sp_pair(*a, mesh=mesh,
                                         exchange_mode="skip"), *args)
        assert cs == [], cs

        import pytest
        with pytest.raises(ValueError):
            gspn_scan_sp_pair(*args, mesh=mesh, exchange_mode="eager")
    """, timeout=560)


def test_sp_strategy_resolution_drift_pin():
    """ISSUE 10 satellite: benchmarks/sp_scaling.py must measure the
    strategy production resolves.  strategy_for delegates to
    SPConfig.resolved_strategy — this pin fails if anyone ever
    re-introduces a local copy of the auto rule and lets it drift."""
    from benchmarks.sp_scaling import strategy_for

    from repro.parallel.gspn_sp import PPERMUTE_MAX_BLOCKS, SPConfig

    for n in range(1, 17):
        for pair in (False, True):
            assert strategy_for(n, pair=pair) == \
                SPConfig(n_blocks=n).resolved_strategy(pair=pair), n
        # the auto rule itself, pinned concretely
        assert strategy_for(n) == (
            "ppermute" if n <= PPERMUTE_MAX_BLOCKS else "allgather"), n
        assert strategy_for(n, pair=True) == "pair_allgather", n

    # explicit strategies are honoured; the pair-only strategy degrades
    # to its single-direction form and vice versa
    assert SPConfig(n_blocks=8,
                    strategy="ppermute").resolved_strategy() == "ppermute"
    assert SPConfig(n_blocks=2,
                    strategy="allgather").resolved_strategy() == "allgather"
    assert SPConfig(n_blocks=8, strategy="pair_allgather") \
        .resolved_strategy() == "allgather"
    assert SPConfig(n_blocks=8, strategy="allgather") \
        .resolved_strategy(pair=True) == "allgather"
    assert SPConfig(n_blocks=8, strategy="pair_allgather") \
        .resolved_strategy(pair=True) == "pair_allgather"


def test_sp_collective_byte_accounting(run_sub):
    """ISSUE 10 satellite: the analytic ``collective_bytes`` model in the
    sp_scaling ladder must equal the bytes of the collectives ACTUALLY
    emitted in the jaxpr — per-op payload for ppermute hops, K× the
    gathered shard for all-gathers — for both strategies × both wire
    dtypes × fused-pair vs per-direction.  Every boundary payload must
    cross the wire in the configured boundary_dtype."""
    run_sub(_PAIR_SETUP + f"""
        import sys
        sys.path.insert(0, {str(ROOT)!r})
    """ + """
        from benchmarks.sp_scaling import collective_bytes
        from repro.parallel.gspn_sp import gspn_scan_sp

        k = 8
        mesh = make_mesh((k,), ("seq",))
        gw, cpw, w, h = 2, 3, 8, 24
        g = gw * cpw
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (g, h, w))
        lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
        wl, wc, wr = G.normalize_taps(
            jax.random.normal(ks[2], (gw, h, w, 3)))
        pargs = pair_inputs(gw, cpw, h, w)

        def emitted_bytes(wire, fn, *args):
            total = 0
            for nm, shape, dtype in collectives_in_jaxpr(fn, *args):
                assert dtype == wire, (nm, shape, dtype)
                n = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
                total += k * n if "all_gather" in nm else n
            return total

        for wire, wb in (("float32", 4), ("bfloat16", 2)):
            # single direction, both per-direction strategies
            for strategy in ("ppermute", "allgather"):
                got = emitted_bytes(
                    wire, lambda *a, s=strategy: gspn_scan_sp(
                        *a, mesh=mesh, strategy=s, boundary_dtype=wire),
                    x, wl, wc, wr, lam)
                assert got == collective_bytes(k, gw, g, w, strategy, wb), \
                    (wire, strategy, got)
            # fused pair: ONE collective carrying the whole model
            got = emitted_bytes(
                wire, lambda *a: gspn_scan_sp_pair(
                    *a, mesh=mesh, boundary_dtype=wire), *pargs)
            assert got == collective_bytes(k, gw, g, w,
                                           "pair_allgather", wb), \
                (wire, got)
            # per-direction fallback pays the single-direction model TWICE
            for strategy in ("ppermute", "allgather"):
                got = emitted_bytes(
                    wire, lambda *a, s=strategy: gspn_scan_sp_pair(
                        *a, mesh=mesh, strategy=s, boundary_dtype=wire),
                    *pargs)
                assert got == 2 * collective_bytes(k, gw, g, w,
                                                   strategy, wb), \
                    (wire, strategy, got)
    """, timeout=560)


def test_sp_bf16_wire_chain_vs_allgather(run_sub):
    """Pins the bf16-wire divergence bound of both exchange strategies
    against the f32 reference.  The masked-send chain quantizes only the
    consumed boundary path (K-1 column round trips, but every
    ``_apply_transfer`` matvec uses the LOCAL f32 operator), while the
    all-gather quantizes each payload once but ships the (W, W) transfer
    operators themselves over the bf16 wire — so the two land in the
    same error band, and neither may drift an order of magnitude from
    the other.  f32 wire must stay exact for both."""
    run_sub(_SETUP + """
        from repro.kernels.ref import gspn_scan_ref
        from repro.parallel.gspn_sp import gspn_scan_sp

        mesh = make_mesh((8,), ("seq",))
        gw, cpw, w, h = 2, 3, 8, 24
        g = gw * cpw
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (g, h, w))
        lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w)))
        wl, wc, wr = G.normalize_taps(
            jax.random.normal(ks[2], (gw, h, w, 3)))
        args = (x, wl, wc, wr, lam)
        ref = np.asarray(gspn_scan_ref(*args))

        def err(strategy, wire):
            out = jax.jit(lambda *a: gspn_scan_sp(
                *a, mesh=mesh, strategy=strategy,
                boundary_dtype=wire))(*args)
            return float(np.max(np.abs(np.asarray(out) - ref)))

        # f32 wire: both strategies exact to scan tolerance
        assert err("ppermute", "float32") < 1e-5
        assert err("allgather", "float32") < 1e-5

        # bf16 wire: real but bounded quantization, same band for both
        e_ag = err("allgather", "bfloat16")
        e_ch = err("ppermute", "bfloat16")
        assert 1e-6 < e_ag < 0.03, e_ag
        assert 1e-6 < e_ch < 0.03, e_ch
        assert e_ch < 10 * e_ag and e_ag < 10 * e_ch, (e_ag, e_ch)
    """, timeout=560)
