"""GSPN-2 vision backbone (the paper's own model)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gspn2_vision import (GSPN2_B, GSPN2_S, GSPN2_T,
                                        reduced_vision)
from repro.models.vision import (apply_vision, init_vision, vision_loss,
                                 vision_macs)


def test_reduced_forward_and_grad():
    cfg = reduced_vision()
    p = init_vision(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.img_size, cfg.img_size, 3))
    logits = apply_vision(p, x, cfg)
    assert logits.shape == (2, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()
    g = jax.grad(lambda pp: vision_loss(
        pp, cfg, {"images": x, "labels": jnp.array([1, 2])})[0])(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_param_counts_match_paper_table2():
    """Paper Table 2: GSPN-2 T/S/B = 24M/50M/89M params."""
    import numpy as np
    for cfg, target, tol in [(GSPN2_T, 24e6, 0.1), (GSPN2_S, 50e6, 0.1),
                             (GSPN2_B, 89e6, 0.1)]:
        shapes = jax.eval_shape(lambda k, c=cfg: init_vision(k, c),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(shapes))
        assert abs(n - target) / target < tol, (cfg.name, n)


def test_macs_match_paper_table2():
    """Paper Table 2: 4.2G / 9.2G / 14.2G MACs at 224² (±25%)."""
    for cfg, target in [(GSPN2_T, 4.2e9), (GSPN2_S, 9.2e9),
                        (GSPN2_B, 14.2e9)]:
        m = vision_macs(cfg)
        assert abs(m - target) / target < 0.25, (cfg.name, m / 1e9)


def test_gspn1_mode_has_more_scan_params():
    """GSPN-1 per-channel mode keeps separate propagation weights — the
    compact GSPN-2 mode must be strictly smaller at equal dims."""
    from repro.core.gspn import (GSPNAttentionConfig,
                                 gspn_attention_param_count)
    c2 = GSPNAttentionConfig(dim=256, proxy_dim=8, channel_shared=True)
    c1 = GSPNAttentionConfig(dim=256, proxy_dim=8, channel_shared=False)
    assert gspn_attention_param_count(c2) < gspn_attention_param_count(c1)
