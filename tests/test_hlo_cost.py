"""Trip-corrected HLO cost model: exact on known programs."""

import jax
import jax.numpy as jnp

from repro.roofline.hlo_cost import analyze
from repro.roofline.hlo import collective_bytes


def _scan_matmul(trips=7, m=64, k=128, n=128):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return f, x, w, 2 * m * k * n * trips


def test_forward_flops_exact():
    f, x, w, expect = _scan_matmul()
    c = jax.jit(f).lower(x, w).compile()
    res = analyze(c.as_text())
    assert 0.99 < res["flops"] / expect < 1.01
    assert res["while_trips"] and list(res["while_trips"].values()) == [7]


def test_grad_flops_3x():
    f, x, w, expect = _scan_matmul()
    g = jax.jit(jax.grad(lambda x, w: jnp.sum(f(x, w)), argnums=1))
    res = analyze(g.lower(x, w).compile().as_text())
    assert 0.9 < res["flops"] / (3 * expect) < 1.2


def test_remat_flops_4x():
    trips, m, k, n = 7, 64, 128, 128

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=trips)
        return y

    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    g = jax.jit(jax.grad(lambda x, w: jnp.sum(f(x, w)), argnums=1))
    res = analyze(g.lower(x, w).compile().as_text())
    expect = 2 * m * k * n * trips
    assert 3.8 < res["flops"] / expect < 4.3


def test_bytes_scale_with_trips():
    f7 = _scan_matmul(trips=7)
    f14 = _scan_matmul(trips=14)
    b7 = analyze(jax.jit(f7[0]).lower(f7[1], f7[2]).compile().as_text())
    b14 = analyze(jax.jit(f14[0]).lower(f14[1], f14[2]).compile().as_text())
    ratio = b14["bytes"] / b7["bytes"]
    assert 1.6 < ratio < 2.2, ratio


def test_collective_parser_on_psum():

    def f(x):
        return jax.lax.psum(x, "i")

    fn = jax.pmap(f, axis_name="i")
    x = jnp.ones((1, 128, 128))
    c = fn.lower(x).compile()
    txt = c.as_text() if isinstance(c.as_text(), str) else c.as_text()[0]
    coll = collective_bytes(txt)
    assert coll["total"] >= 128 * 128 * 4  # one all-reduce, 2x multiplier
    assert coll["count"] >= 1
