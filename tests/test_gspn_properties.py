"""Property-based tests (hypothesis) for GSPN-2 invariants.

Skipped wholesale when hypothesis isn't installed in the container —
these are extra assurance on top of the deterministic suites, not tier-1
gating."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.core import gspn as G
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan

jax.config.update("jax_platform_name", "cpu")

dims = st.tuples(st.integers(1, 4),            # G
                 st.integers(2, 12),           # H
                 st.integers(2, 24))           # W


@settings(max_examples=15, deadline=None)
@given(dims, st.integers(0, 2 ** 31 - 1))
def test_row_stochastic_taps_sum_to_one(shape, seed):
    g, h, w = shape
    logits = jax.random.normal(jax.random.PRNGKey(seed), (g, h, w, 3)) * 3
    wl, wc, wr = G.normalize_taps(logits)
    np.testing.assert_allclose(np.asarray(wl + wc + wr), 1.0, atol=1e-5)
    # boundary taps masked
    assert np.all(np.asarray(wl)[..., 0] == 0)
    assert np.all(np.asarray(wr)[..., -1] == 0)


@settings(max_examples=15, deadline=None)
@given(dims, st.integers(0, 2 ** 31 - 1))
def test_stability_non_expansion(shape, seed):
    """Stability–Context condition: with row-stochastic w and zero input,
    ||h_i||_inf never exceeds ||h_0||_inf (non-expansive propagation)."""
    g, h, w = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = jax.random.normal(ks[0], (g, h, w, 3)) * 3
    wl, wc, wr = G.normalize_taps(logits)
    h0 = jax.random.normal(ks[1], (g, w))
    x = jnp.zeros((g, h, w))
    lam = jnp.zeros((g, h, w))
    out = R.gspn_scan_ref(x, wl, wc, wr, lam, h0=h0)
    max0 = np.abs(np.asarray(h0)).max()
    assert np.abs(np.asarray(out)).max() <= max0 + 1e-5


@settings(max_examples=15, deadline=None)
@given(dims, st.integers(0, 2 ** 31 - 1))
def test_mass_conservation_column_sums(shape, seed):
    """A row-stochastic tridiagonal matvec preserves the total mass of a
    CONSTANT vector: w @ 1 = 1 (rows sum to one)."""
    g, h, w = shape
    logits = jax.random.normal(jax.random.PRNGKey(seed), (g, h, w, 3))
    wl, wc, wr = G.normalize_taps(logits)
    ones = jnp.ones((g, w))
    out = R.step_row(ones, jnp.zeros((g, w)), wl[:, 0], wc[:, 0], wr[:, 0],
                     jnp.zeros((g, w)))
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 16),
       st.integers(0, 2 ** 31 - 1))
def test_chunk_equals_full_when_chunk_is_h(g, nch, w, seed):
    h = nch * 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.random.normal(ks[1], (g, h, w))
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (g, h, w, 3)))
    a = gspn_scan(x, wl, wc, wr, lam, chunk=h, impl="xla")
    b = gspn_scan(x, wl, wc, wr, lam, impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(2, 8), st.integers(2, 16),
       st.integers(0, 2 ** 31 - 1))
def test_direction_flip_consistency(g, h, w, seed):
    """un-flip(T->B scan of flipped inputs) == reverse (B->T) scan of the
    originals — the identity the directional dispatch relies on."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.random.normal(ks[1], (g, h, w))
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (g, h, w, 3)))
    via_flip = jnp.flip(R.gspn_scan_ref(
        jnp.flip(x, 1), jnp.flip(wl, 1), jnp.flip(wc, 1), jnp.flip(wr, 1),
        jnp.flip(lam, 1)), 1)
    via_reverse = R.gspn_scan_ref(x, wl, wc, wr, lam, reverse=True)
    np.testing.assert_allclose(np.asarray(via_flip),
                               np.asarray(via_reverse), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_linearity_in_input(h, seed):
    """The scan is linear in x for fixed taps/λ: f(a·x1 + b·x2) =
    a·f(x1) + b·f(x2)."""
    g, w = 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x1 = jax.random.normal(ks[0], (g, h, w))
    x2 = jax.random.normal(ks[1], (g, h, w))
    lam = jax.random.normal(ks[2], (g, h, w))
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[3], (g, h, w, 3)))

    def f(x):
        return R.gspn_scan_ref(x, wl, wc, wr, lam)

    lhs = f(2.5 * x1 - 1.5 * x2)
    rhs = 2.5 * f(x1) - 1.5 * f(x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 40), st.integers(0, 2 ** 31 - 1))
def test_seq_mixer_causality(l, seed):
    """Changing suffix tokens never changes earlier outputs."""
    cfg = G.GSPNSeqConfig(dim=16, proxy_dim=4, row_width=8)
    params = G.init_gspn_seq_mixer(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (1, l, 16))
    cut = max(1, l // 2)
    x2 = x.at[0, cut:].set(jax.random.normal(ks[1], (l - cut, 16)))
    y1 = G.apply_gspn_seq_mixer(params, x, cfg)
    y2 = G.apply_gspn_seq_mixer(params, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[0, :cut]),
                               np.asarray(y2[0, :cut]),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(2, 6), st.integers(2, 12),
       st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_proxy_identity_roundtrip(b, h, w, cp, seed):
    """With identity down/up projections, zero taps toward propagation and
    λ ≡ 1, the attention module reduces to a per-direction gating of x —
    checks the proxy-compression plumbing preserves shape/content flow."""
    dim = cp
    cfg = G.GSPNAttentionConfig(dim=dim, proxy_dim=cp,
                                directions=("tb",))
    params = G.init_gspn_attention(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, h, w, dim))
    y = G.apply_gspn_attention(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
