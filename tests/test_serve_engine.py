"""Continuous-batching engine: chunked-prefill equivalence, scheduler edge
cases, and the paged state-cache pool (DESIGN.md §9 invariants).

All configs are tiny (d_model 32, vocab 64) so the whole module stays
cheap inside the tier-1 ``pytest -q`` gate; ``pytest -m serve`` selects
just this surface.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (LMConfig, init_lm, init_lm_cache,
                             lm_decode_step, lm_prefill, lm_prefill_chunk,
                             prefill_chunk_alignment,
                             supports_chunked_prefill)
from repro.serve.cache import StateCachePool
from repro.serve.engine import Request, ServeEngine

pytestmark = pytest.mark.serve


def _gspn_cfg(**kw):
    """gspn prelude + attn unit: exercises both chunked-prefill paths and
    both cache batch-axis layouts."""
    base = dict(name="g", family="gspn", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=64,
                prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
                gspn_proxy_dim=4, gspn_row_width=8, remat="none",
                compute_dtype=jnp.float32)
    base.update(kw)
    return LMConfig(**base)


def _tree_close(a, b, atol):
    for ka, kb in zip(sorted(a), sorted(b)):
        assert ka == kb
        jax.tree.map(
            lambda x, y: np.testing.assert_allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32),
                atol=atol, rtol=0), a[ka], b[kb])


# ---------------------------------------------------------------------------
# Chunked prefill == one-shot prefill (the §9 headline invariant).
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_one_shot():
    """Logits AND every cache leaf must agree to 1e-5 when the prompt is
    consumed in chunks (incl. a ragged tail) vs in one shot."""
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    max_len, chunk = 64, 16
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, 43), jnp.int32)[None]

    logits1, caches1, _ = lm_prefill(p, cfg, prompt, max_len)

    caches = init_lm_cache(cfg, 1, max_len)
    outs = []
    for off in range(0, prompt.shape[1], chunk):
        lg, caches = lm_prefill_chunk(p, cfg, prompt[:, off:off + chunk],
                                      caches, off)
        outs.append(lg)
    logits2 = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               atol=1e-5, rtol=0)
    _tree_close(caches1, caches, 1e-5)

    # and decode continues identically from either cache
    tok = jnp.argmax(logits1[:, -1:], -1).astype(jnp.int32)
    l1, _ = lm_decode_step(p, cfg, tok, caches1)
    l2, _ = lm_decode_step(p, cfg, tok, caches)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-5, rtol=0)


def test_engine_chunked_equals_one_shot_tokens():
    """Greedy engine output is invariant to the prefill chunking."""
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, n) for n in (40, 7, 24)]

    def run(chunk):
        eng = ServeEngine(p, cfg, batch_size=2, max_len=96,
                          prefill_chunk=chunk)
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr, max_new_tokens=5))
        res = eng.run()
        return {u: res[u].tokens for u in res}, eng

    one_shot, _ = run(0)
    chunked, eng = run(16)
    assert one_shot == chunked
    # the 40- and 24-token prompts actually went through the chunked path
    assert eng.metrics["prefill_chunks"] >= 3 + 2


def test_chunk_support_matrix():
    assert supports_chunked_prefill(_gspn_cfg())
    assert prefill_chunk_alignment(_gspn_cfg()) == 8
    # mamba has no incremental prefill; row_width=0 defeats a fixed fold
    assert not supports_chunked_prefill(_gspn_cfg(unit=(("mamba", 1),)))
    assert not supports_chunked_prefill(_gspn_cfg(gspn_row_width=0))
    eng = ServeEngine(init_lm(jax.random.PRNGKey(0), _gspn_cfg()),
                      _gspn_cfg(), batch_size=1, max_len=64,
                      prefill_chunk=13)
    assert eng.prefill_chunk == 8          # snapped down to the fold width
    with pytest.raises(ValueError):        # oversized prompts rejected at
        eng.submit(Request(uid=0,          # submit, not silently clamped
                           prompt=np.arange(65) % 64, max_new_tokens=1))
    with pytest.raises(ValueError):        # prompt + generated must fit too
        eng.submit(Request(uid=0,
                           prompt=np.arange(60) % 64, max_new_tokens=10))


# ---------------------------------------------------------------------------
# Scheduler edge cases.
# ---------------------------------------------------------------------------

def test_admission_under_full_batch():
    """More requests than slots: the pool backpressures, everything still
    completes, and concurrency never exceeds the slot count."""
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(p, cfg, batch_size=2, max_len=96, prefill_chunk=16)
    for i in range(5):
        # one prompt length => one jit variant; the edge case under test
        # is admission order, not shapes
        eng.submit(Request(uid=i, prompt=(np.arange(12) + i) % 64,
                           max_new_tokens=4))
    res = eng.run()
    assert sorted(res) == list(range(5))
    assert eng.metrics["queue_depth_max"] >= 3   # requests actually waited
    assert eng.pool.n_free == 2                  # all slots returned
    assert eng.pool.n_used == 0


def test_sjf_admits_shortest_prompt_first():
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)

    def order(sched):
        eng = ServeEngine(p, cfg, batch_size=1, max_len=96,
                          prefill_chunk=16, scheduler=sched)
        for i, n in enumerate([40, 6, 24]):
            eng.submit(Request(uid=i, prompt=np.arange(n) % 64,
                               max_new_tokens=3))
        eng.run()
        return list(eng.metrics["admission_order"])

    assert order("fcfs") == [0, 1, 2]
    assert order("sjf") == [1, 2, 0]


def test_retirement_eos_vs_max_tokens():
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(12) % 64

    ref = ServeEngine(p, cfg, batch_size=1, max_len=96, prefill_chunk=16)
    ref.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    eos = ref.run()[0].tokens[2]      # 3rd generated token as synthetic EOS

    eng = ServeEngine(p, cfg, batch_size=1, max_len=96, prefill_chunk=16,
                      eos_id=eos)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    eng.submit(Request(uid=1, prompt=prompt[:5], max_new_tokens=2))
    res = eng.run()
    assert res[0].finish_reason == "eos"
    assert res[0].tokens[-1] == eos and len(res[0].tokens) <= 3
    assert res[1].finish_reason == "length"
    assert len(res[1].tokens) == 2


def test_request_metrics_and_streaming():
    """One engine run pins both the per-request metrics fields and the
    streaming callback (every token, in generation order)."""
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    seen = {}
    eng = ServeEngine(p, cfg, batch_size=2, max_len=96, prefill_chunk=16,
                      stream=lambda uid, tok: seen.setdefault(uid, [])
                      .append(tok))
    for i in range(3):
        eng.submit(Request(uid=i, prompt=np.arange(20) % 64,
                           max_new_tokens=4))
    res = eng.run()
    assert {u: r.tokens for u, r in res.items()} == seen
    for r in res.values():
        assert r.ttft > 0.0
        assert r.queue_delay >= 0.0
        assert r.prefill_chunks == 2          # 20 tokens / 16-chunk
        assert len(r.itl) == len(r.tokens) - 1
        assert r.finish_reason == "length"

    # with on_finish set, results are delivered, not retained (engine
    # state stays bounded for long-running servers); reuses the jits
    eng.reset()
    delivered = []
    eng.on_finish = delivered.append
    eng.submit(Request(uid=7, prompt=np.arange(20) % 64, max_new_tokens=4))
    assert eng.run() == {}
    assert [r.uid for r in delivered] == [7]
    assert delivered[0].tokens == res[0].tokens    # same prompt, same model


# ---------------------------------------------------------------------------
# State-cache pool.
# ---------------------------------------------------------------------------

def test_cache_pool_alloc_free_reuse():
    cfg = _gspn_cfg()
    pool = StateCachePool(cfg, 2, 32)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1}
    assert pool.alloc() is None               # exhaustion, not an exception
    pool.free(a)
    assert pool.n_free == 1
    assert pool.alloc() == a                  # LIFO reuse of the freed page
    pool.free(b)
    with pytest.raises(ValueError):
        pool.free(b)                          # double-free is a bug


def test_cache_pool_commit_writes_only_its_slot():
    cfg = _gspn_cfg()
    pool = StateCachePool(cfg, 4, 32)
    pool.caches = jax.tree.map(lambda a: jnp.full_like(a, 7), pool.caches)
    new = jax.tree.map(lambda a: jnp.full_like(a, -3),
                       init_lm_cache(cfg, 1, 32))
    slot = pool.alloc()
    pool.commit(slot, new)
    prelude_keys = {f"s{si}_{kind}" for si, (w, kind, n)
                    in enumerate(cfg.stages()) if w == "prelude"}
    for key, sub in pool.caches.items():
        axis = 1 if key in prelude_keys else 2
        for leaf in jax.tree.leaves(sub):
            got = np.moveaxis(np.asarray(leaf, np.float32), axis, 0)
            np.testing.assert_array_equal(got[slot], -3.0)
            others = [s for s in range(4) if s != slot]
            np.testing.assert_array_equal(got[others], 7.0)


def test_cache_pool_reuse_after_free_is_clean():
    """A request decoded in a reused slot must match a fresh engine —
    chunked prefill must fully overwrite the previous occupant's page."""
    cfg = _gspn_cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(23) % 64

    fresh = ServeEngine(p, cfg, batch_size=1, max_len=96, prefill_chunk=16)
    fresh.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    expect = fresh.run()[0].tokens

    eng = ServeEngine(p, cfg, batch_size=1, max_len=96, prefill_chunk=16)
    eng.submit(Request(uid=0, prompt=np.arange(40) % 64, max_new_tokens=9))
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=5))
    assert eng.run()[1].tokens == expect
