"""Autotuner unit tests (DESIGN.md §11): cache round-trip and layering,
graceful fallback to the static heuristic, deterministic measurement under
an injected timer, VMEM-budget candidate admission (including the PR-4
bf16-carry byte accounting), the precision-policy routing of the static
picker, and the schema-3 spec-canonical keying with schema-2 read-compat
(DESIGN.md §14)."""

import json

import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs.base import PRECISIONS, resolve_precision
from repro.kernels import autotune as A
from repro.kernels import tuning
from repro.kernels.spec import ScanSpec

pytestmark = pytest.mark.kernels


def _key(**kw):
    base = dict(device="testdev", h=64, w=32, c=4, direction="fwd",
                impl="pallas", dtype="float32", carry_dtype="float32",
                channel_shared=True)
    base.update(kw)
    return A.ScanKey(**base)


# ---------------------------------------------------------------------------
# Cache persistence.
# ---------------------------------------------------------------------------

def test_cache_roundtrips_to_disk(tmp_path):
    cache = A.TuningCache()
    k1, k2 = _key(), _key(direction="bwd", dtype="bfloat16")
    e1 = {"row_tile": 16, "double_buffer": True, "us": 12.5,
          "n_grid_steps": 4, "working_set_bytes": 1024,
          "source": "measured"}
    e2 = dict(e1, row_tile=8, us=99.0)
    cache.store(k1, e1)
    cache.store(k2, e2)
    path = cache.save(tmp_path / "cache.json")

    fresh = A.TuningCache.load(path)
    assert len(fresh) == 2
    assert fresh.lookup(k1) == e1
    assert fresh.lookup(k2) == e2
    # distinct keys stay distinct under encode()
    assert k1.encode() != k2.encode()


def test_corrupt_or_missing_cache_loads_empty(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert len(A.TuningCache.load(bad)) == 0
    assert len(A.TuningCache.load(tmp_path / "nope.json")) == 0
    # wrong payload shape is also tolerated
    bad.write_text(json.dumps({"entries": [1, 2]}))
    assert len(A.TuningCache.load(bad)) == 0


def test_env_cache_layers_over_seed(tmp_path, monkeypatch):
    k = _key(device=A.device_kind(True), h=32)
    extra = A.TuningCache()
    extra.store(k, {"row_tile": 2, "double_buffer": True, "us": 1.0,
                    "n_grid_steps": 16, "working_set_bytes": 64,
                    "source": "measured"})
    path = extra.save(tmp_path / "overlay.json")
    monkeypatch.setenv(A.ENV_CACHE_PATH, str(path))
    try:
        cache = A.get_cache(reload=True)
        assert cache.lookup(k)["row_tile"] == 2
        assert A.row_tile_for(32, k.w, c=k.c, direction="fwd",
                              dtype="float32", channel_shared=True,
                              interpret=True) == 2
    finally:
        monkeypatch.delenv(A.ENV_CACHE_PATH)
        A.get_cache(reload=True)        # restore the unlayered global


# ---------------------------------------------------------------------------
# Lookup / fallback ladder.
# ---------------------------------------------------------------------------

def test_miss_falls_back_to_heuristic_without_error():
    empty = A.TuningCache()
    got = A.row_tile_for(64, 32, c=4, direction="fwd", dtype="float32",
                         channel_shared=True, cache=empty)
    want = tuning.pick_row_tile(64, 32, 4, cap=A.DEFAULT_CAP,
                                n_streams=6, carry_dtype_bytes=4).row_tile
    assert got == want
    # and matches the legacy gspn_scan wrapper's accounting exactly
    from repro.kernels.gspn_scan import pick_row_tile as wrapper
    assert got == wrapper(64, w=32, dtype_bytes=4)


def test_unknown_device_entry_is_a_miss():
    cache = A.TuningCache()
    cache.store(_key(device="tpu-v99"), {"row_tile": 2})
    got = A.row_tile_for(64, 32, c=4, direction="fwd", dtype="float32",
                         channel_shared=True, cache=cache)
    # the current device key differs, so the entry never matches
    assert got == tuning.pick_row_tile(64, 32, 4, cap=A.DEFAULT_CAP,
                                       n_streams=6).row_tile


def test_hit_overrides_heuristic():
    key = _key(device=A.device_kind(False))
    cache = A.TuningCache()
    cache.store(key, {"row_tile": 2, "double_buffer": True, "us": 1.0,
                      "n_grid_steps": 32, "working_set_bytes": 64,
                      "source": "measured"})
    got = A.row_tile_for(key.h, key.w, c=key.c, direction="fwd",
                         dtype="float32", channel_shared=True, cache=cache)
    assert got == 2  # not the heuristic's 64


@pytest.mark.parametrize("bad_entry", [
    {"row_tile": 3},            # not a power of two
    {"row_tile": 48},           # does not divide h=64
    {"row_tile": 0},
    {"row_tile": "wat"},
    {},
])
def test_invalid_cache_entry_falls_back(bad_entry):
    key = _key(device=A.device_kind(False))
    cache = A.TuningCache()
    cache.store(key, bad_entry)
    got = A.row_tile_for(key.h, key.w, c=key.c, direction="fwd",
                         dtype="float32", channel_shared=True, cache=cache)
    assert got == A.heuristic_row_tile(key)


def test_oversized_cache_entry_falls_back():
    """A tile whose minimal working set exceeds VMEM is rejected even if
    it divides the scan length (stale entry from a bigger device)."""
    key = _key(device=A.device_kind(False), h=1 << 20, w=8192)
    cache = A.TuningCache()
    cache.store(key, {"row_tile": 1 << 19})
    assert not A._entry_valid(key, {"row_tile": 1 << 19})
    got = A.row_tile_for(key.h, key.w, c=key.c, direction="fwd",
                         dtype="float32", channel_shared=True, cache=cache)
    assert got == A.heuristic_row_tile(key)


# ---------------------------------------------------------------------------
# Deterministic measurement harness.
# ---------------------------------------------------------------------------

def _scripted(costs):
    """(runner_factory, timer): the runner records which candidate is
    'executing'; the timer advances a fake clock by that candidate's cost
    per reading."""
    state = {"rt": None, "t": 0.0}

    def factory(cand):
        def fn():
            state["rt"] = cand.row_tile
        return fn

    def timer():
        state["t"] += costs[state["rt"]]
        return state["t"]

    return factory, timer


def test_autotune_deterministic_under_scripted_timer():
    key = _key()
    cands = [A.Candidate(4), A.Candidate(8), A.Candidate(16)]
    factory, timer = _scripted({4: 5.0, 8: 1.0, 16: 3.0})
    cache = A.TuningCache()
    e1 = A.autotune_key(key, candidates=cands, cache=cache,
                        runner_factory=factory, timer=timer)
    assert e1["row_tile"] == 8
    assert e1["source"] == "measured"
    assert e1["n_grid_steps"] == key.h // 8

    # identical inputs => identical winner (fresh scripted state)
    factory, timer = _scripted({4: 5.0, 8: 1.0, 16: 3.0})
    e2 = A.autotune_key(key, candidates=cands, cache=A.TuningCache(),
                        runner_factory=factory, timer=timer)
    assert e2 == e1


def test_autotune_tie_breaks_to_first_candidate():
    key = _key()
    cands = [A.Candidate(4), A.Candidate(8)]
    factory, timer = _scripted({4: 2.0, 8: 2.0})
    e = A.autotune_key(key, candidates=cands, cache=A.TuningCache(),
                       runner_factory=factory, timer=timer)
    assert e["row_tile"] == 4


def test_monkeypatched_default_timer_is_honoured(monkeypatch):
    """measure() consults the module-level default timer, so a test can
    freeze time globally."""
    ticks = iter(range(100))
    monkeypatch.setattr(A, "_default_timer", lambda: float(next(ticks)))
    dt = A.measure(lambda: None, iters=3, warmup=0)
    assert dt == 1.0      # consecutive integer ticks => 1s per call


def test_winner_never_slower_than_heuristic_candidate():
    """The heuristic's tile is always in the timed candidate set, so the
    measured winner's cost is <= the heuristic tile's cost."""
    key = _key()
    cands = A.enumerate_candidates(key)
    heur = A.heuristic_row_tile(key)
    assert heur in [c.row_tile for c in cands]
    costs = {c.row_tile: float(i + 1) for i, c in enumerate(cands)}
    factory, timer = _scripted(costs)
    e = A.autotune_key(key, candidates=cands, cache=A.TuningCache(),
                       runner_factory=factory, timer=timer)
    assert costs[e["row_tile"]] <= costs[heur]


def test_warm_measures_real_kernel(tmp_path):
    """End-to-end: one tiny spec through the real jitted interpret-mode
    kernel lands a valid measured entry in the cache."""
    cache = A.TuningCache()
    A.warm([(8, 8, 2, "fwd", "pallas", "float32", True)], cache=cache,
           iters=1, verbose=False)
    assert len(cache) == 1
    (entry,) = cache.entries.values()
    assert entry["source"] == "measured"
    assert 8 % entry["row_tile"] == 0
    path = cache.save(tmp_path / "warm.json")
    assert A.TuningCache.load(path).entries == cache.entries


# ---------------------------------------------------------------------------
# Candidate admission: the VMEM budget is a hard wall.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1 << 14, 1 << 16, 1 << 18])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_candidates_never_exceed_vmem_budget(budget, dtype):
    key = _key(h=4096, w=128, dtype=dtype)
    cands = A.enumerate_candidates(key, vmem_budget=budget)
    assert cands, (budget, dtype)
    for c in cands:
        # the minimal (single-buffered) footprint must fit — admission
        # may drop prefetch headroom, never the resident working set
        assert A.Candidate(c.row_tile, double_buffer=False) \
            .working_set(key) <= budget


def test_candidate_admission_grows_with_budget():
    key = _key(h=4096, w=128)
    small = max(c.row_tile
                for c in A.enumerate_candidates(key, vmem_budget=1 << 16))
    big = max(c.row_tile
              for c in A.enumerate_candidates(key, vmem_budget=1 << 20))
    assert big > small


def test_candidate_bf16_carry_byte_accounting():
    """Regression pin of the PR-4 accounting at the candidate level: the
    streamed term scales with the stream dtype, the carry term with the
    carry dtype — and the adjoint directions carry three f32 rows."""
    w, t, n = 128, 64, 6
    k_f32 = _key(w=w)
    k_bf16 = _key(w=w, dtype="bfloat16")
    k_bf16_carry = _key(w=w, dtype="bfloat16", carry_dtype="bfloat16")
    assert A.Candidate(t).working_set(k_f32) == n * t * w * 4 * 2 + w * 4
    assert A.Candidate(t).working_set(k_bf16) == n * t * w * 2 * 2 + w * 4
    assert A.Candidate(t).working_set(k_bf16_carry) \
        == n * t * w * 2 * 2 + w * 2
    # adjoint kernels: 5 streams, 3 carry rows, carry always f32
    k_bwd = _key(w=w, direction="bwd", dtype="bfloat16")
    assert k_bwd.carry_bytes == 3 * 4
    assert A.Candidate(t).working_set(k_bwd) \
        == 5 * t * w * 2 * 2 + w * 12
    # at a tight budget (and a scan long enough not to cap on divisors),
    # bf16 streams admit strictly larger tiles
    budget = 1 << 18
    max16 = max(c.row_tile for c in A.enumerate_candidates(
        _key(h=4096, w=w, dtype="bfloat16"), vmem_budget=budget))
    max32 = max(c.row_tile for c in A.enumerate_candidates(
        _key(h=4096, w=w), vmem_budget=budget))
    assert max16 > max32


def test_scan_key_rejects_unknown_direction():
    with pytest.raises(ValueError):
        _key(direction="sideways")


# ---------------------------------------------------------------------------
# Precision-policy routing (the fix for dtype_bytes=4-regardless-of-policy
# call sites) — parametrized over every named preset.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PRECISIONS))
def test_pick_row_tile_routes_through_policy(name):
    p = resolve_precision(name)
    sb, cb = tuning.policy_itemsizes(name)
    assert sb == jnp.dtype(p.compute_dtype).itemsize
    assert cb == jnp.dtype(p.carry_dtype).itemsize
    tc = tuning.pick_row_tile_for_policy(4096, 128, name,
                                         vmem_budget=1 << 21)
    want = tuning.pick_row_tile(4096, 128, sb, vmem_budget=1 << 21,
                                carry_dtype_bytes=cb)
    assert tc == want


def test_policy_presets_pin_expected_itemsizes():
    assert tuning.policy_itemsizes("f32") == (4, 4)
    assert tuning.policy_itemsizes("bf16") == (2, 4)      # f32 carries
    assert tuning.policy_itemsizes("bf16_f32params") == (2, 4)
    # bf16 streams unlock a >= tile vs f32 at any fixed budget
    t16 = tuning.pick_row_tile_for_policy(4096, 128, "bf16",
                                          vmem_budget=1 << 21).row_tile
    t32 = tuning.pick_row_tile_for_policy(4096, 128, "f32",
                                          vmem_budget=1 << 21).row_tile
    assert t16 >= 2 * t32


# ---------------------------------------------------------------------------
# Pipeline depth: schema-2 entries, back-compat reads, depth selection
# (DESIGN.md §12).
# ---------------------------------------------------------------------------

def test_pipeline_depth_cache_roundtrip(tmp_path):
    cache = A.TuningCache()
    key = _key(dtype="bfloat16")
    entry = {"row_tile": 16, "double_buffer": True, "pipeline_depth": 2,
             "us": 3.0, "n_grid_steps": 4, "working_set_bytes": 4096,
             "source": "measured"}
    cache.store(key, entry)
    path = cache.save(tmp_path / "depth.json")
    payload = json.loads(path.read_text())
    assert payload["schema"] == A.SCHEMA_VERSION == 3
    fresh = A.TuningCache.load(path)
    assert fresh.lookup(key)["pipeline_depth"] == 2
    plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                      dtype="bfloat16", channel_shared=True, cache=fresh)
    # device differs from "testdev" => miss; re-store under the real key
    key_dev = _key(device=A.device_kind(False), dtype="bfloat16")
    fresh.store(key_dev, entry)
    plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                      dtype="bfloat16", channel_shared=True, cache=fresh)
    assert plan == A.ScanPlan(row_tile=16, pipeline_depth=2)


def test_pre_pr6_cache_file_reads_as_depth_1(tmp_path):
    """A schema-1 file (no pipeline_depth field anywhere) must load
    without error and resolve to depth 1 — the pre-PR6 kernels."""
    key = _key(device=A.device_kind(False))
    old_payload = {"schema": 1, "entries": {key.encode(): {
        "row_tile": 8, "double_buffer": True, "us": 5.0,
        "n_grid_steps": 8, "working_set_bytes": 2048,
        "source": "measured"}}}
    path = tmp_path / "pre_pr6.json"
    path.write_text(json.dumps(old_payload))
    cache = A.TuningCache.load(path)
    assert len(cache) == 1
    plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                      dtype="float32", channel_shared=True, cache=cache)
    assert plan == A.ScanPlan(row_tile=8, pipeline_depth=1)


def test_garbage_pipeline_depth_entry_falls_back():
    key = _key(device=A.device_kind(False))
    cache = A.TuningCache()
    for bad in ("wat", 3, -1, None):
        cache.store(key, {"row_tile": 8, "pipeline_depth": bad})
        plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                          dtype="float32", channel_shared=True, cache=cache)
        assert plan.row_tile == A.heuristic_row_tile(key)
        assert plan.pipeline_depth == 1


def test_depth_enumeration_follows_stream_width():
    """Depth 2 is enumerated only for narrow (< 4-byte) streams; depth 1
    is always present."""
    depths_f32 = {c.pipeline_depth
                  for c in A.enumerate_candidates(_key())}
    depths_bf16 = {c.pipeline_depth
                   for c in A.enumerate_candidates(_key(dtype="bfloat16"))}
    assert depths_f32 == {1}
    assert depths_bf16 == {1, 2}
    assert A.heuristic_pipeline_depth(_key()) == 1
    assert A.heuristic_pipeline_depth(_key(dtype="bfloat16")) == 2


def test_explicit_args_override_plan():
    """An explicit row_tile bypasses the cache; an explicit depth wins
    over both cache and heuristic."""
    key = _key(device=A.device_kind(False), dtype="bfloat16")
    cache = A.TuningCache()
    cache.store(key, {"row_tile": 4, "pipeline_depth": 1})
    kw = dict(c=key.c, direction="fwd", dtype="bfloat16",
              channel_shared=True, cache=cache)
    assert A.plan_for(key.h, key.w, row_tile=32, **kw) \
        == A.ScanPlan(32, 2)                 # heuristic depth for bf16
    assert A.plan_for(key.h, key.w, row_tile=32, pipeline_depth=1, **kw) \
        == A.ScanPlan(32, 1)
    assert A.plan_for(key.h, key.w, pipeline_depth=2, **kw) \
        == A.ScanPlan(4, 2)                  # cache tile, forced depth


def _scripted_depth(costs):
    """Like _scripted but keyed by (row_tile, pipeline_depth)."""
    state = {"k": None, "t": 0.0}

    def factory(cand):
        def fn():
            state["k"] = (cand.row_tile, cand.pipeline_depth)
        return fn

    def timer():
        state["t"] += costs[state["k"]]
        return state["t"]

    return factory, timer


def test_scripted_timer_selects_depth_2_when_faster():
    key = _key(dtype="bfloat16")
    cands = [A.Candidate(16, pipeline_depth=1),
             A.Candidate(16, pipeline_depth=2),
             A.Candidate(32, pipeline_depth=1)]
    factory, timer = _scripted_depth({(16, 1): 9.0, (16, 2): 1.0,
                                      (32, 1): 5.0})
    cache = A.TuningCache()
    e = A.autotune_key(key, candidates=cands, cache=cache,
                       runner_factory=factory, timer=timer)
    assert e["row_tile"] == 16
    assert e["pipeline_depth"] == 2
    # ...and the stored entry drives the plan
    plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                      dtype="bfloat16", channel_shared=True, cache=cache)
    # key device is "testdev" — rebuild under the live device for lookup
    key_dev = _key(device=A.device_kind(False), dtype="bfloat16")
    cache.store(key_dev, e)
    plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                      dtype="bfloat16", channel_shared=True, cache=cache)
    assert plan == A.ScanPlan(16, 2)


def test_scripted_timer_keeps_depth_1_when_faster():
    key = _key(dtype="bfloat16")
    cands = [A.Candidate(16, pipeline_depth=1),
             A.Candidate(16, pipeline_depth=2)]
    factory, timer = _scripted_depth({(16, 1): 1.0, (16, 2): 9.0})
    e = A.autotune_key(key, candidates=cands, cache=A.TuningCache(),
                       runner_factory=factory, timer=timer)
    assert e["pipeline_depth"] == 1


def test_depth2_candidates_respect_vmem_budget():
    """The staging term is part of admission: at a tight budget the
    largest depth-2 tile is half the largest depth-1 bf16 tile."""
    key = _key(h=4096, w=128, dtype="bfloat16")
    budget = 1 << 18
    cands = A.enumerate_candidates(key, vmem_budget=budget)
    for c in cands:
        assert A.Candidate(c.row_tile, double_buffer=False,
                           pipeline_depth=c.pipeline_depth) \
            .working_set(key) <= budget
    max_d1 = max(c.row_tile for c in cands if c.pipeline_depth == 1)
    max_d2 = max(c.row_tile for c in cands if c.pipeline_depth == 2)
    # staging shrinks the biggest admissible tile (the exact ×1/2 at
    # equal buffering is pinned in test_kernels); single-buffered
    # admission can stretch depth 1 even further ahead.
    assert max_d2 <= max_d1 // 2


# ---------------------------------------------------------------------------
# Schema 3: spec-canonical keys, boundary axis, schema-2 read-compat,
# and the cache-reject observability signal (DESIGN.md §14).
# ---------------------------------------------------------------------------

def test_schema3_key_is_shape_legs_plus_spec_canonical():
    key = _key(boundary="sp_block_local")
    sp = ScanSpec(direction=key.direction, impl=key.impl,
                  channels_per_weight=2, stream_dtype=key.dtype,
                  carry_dtype=key.carry_dtype, boundary=key.boundary)
    assert key.encode() == f"testdev|h64|w32|c4|{sp.canonical()}"
    assert key.encode().endswith(sp.canonical())
    # the legacy (schema-2) spelling carries no boundary leg
    assert "bnd-" not in key.encode_legacy()
    assert key.encode_legacy() == _key().encode_legacy()


def test_scan_key_rejects_unknown_boundary():
    with pytest.raises(ValueError):
        _key(boundary="wraparound")


def test_boundary_distinguishes_schema3_entries():
    """Same shape+policy, different boundary behaviour => distinct cache
    slots; each lookup finds its own entry."""
    cache = A.TuningCache()
    entry = {"row_tile": 16, "double_buffer": True, "pipeline_depth": 1,
             "us": 1.0, "n_grid_steps": 4, "working_set_bytes": 64,
             "source": "measured"}
    k_one = _key(device=A.device_kind(False))
    k_sp = _key(device=A.device_kind(False), boundary="sp_block_local")
    cache.store(k_one, dict(entry, row_tile=16))
    cache.store(k_sp, dict(entry, row_tile=8))
    assert k_one.encode() != k_sp.encode()
    assert cache.lookup(k_one)["row_tile"] == 16
    assert cache.lookup(k_sp)["row_tile"] == 8


def test_schema2_cache_file_read_compat(tmp_path):
    """A schema-2 file (legacy 9-segment keys, no boundary leg) keeps
    serving plans: the lookup falls back to the legacy encoding, and a
    boundary-less entry serves every boundary mode."""
    key = _key(device=A.device_kind(False))
    entry = {"row_tile": 16, "double_buffer": True, "pipeline_depth": 1,
             "us": 2.0, "n_grid_steps": 4, "working_set_bytes": 1024,
             "source": "measured"}
    payload = {"schema": 2, "entries": {key.encode_legacy(): entry}}
    path = tmp_path / "schema2.json"
    path.write_text(json.dumps(payload))
    cache = A.TuningCache.load(path)
    assert len(cache) == 1
    for boundary in ("one_shot", "chunk_resume", "sp_block_local"):
        plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                          dtype="float32", channel_shared=True,
                          cache=cache, boundary=boundary)
        assert plan == A.ScanPlan(row_tile=16, pipeline_depth=1)


def test_schema3_entry_shadows_legacy_fallback():
    """When both spellings are present the schema-3 key wins — re-tuned
    entries override the migrated legacy ones."""
    key = _key(device=A.device_kind(False))
    cache = A.TuningCache()
    cache.entries[key.encode_legacy()] = {"row_tile": 8}
    assert cache.lookup(key)["row_tile"] == 8       # legacy fallback
    cache.store(key, {"row_tile": 16})
    assert cache.lookup(key)["row_tile"] == 16      # v3 shadows it


def test_seed_cache_stays_legacy_keyed_for_compat_coverage():
    """The checked-in seed cache keeps schema-2 keys on purpose: every CI
    run then exercises the legacy-fallback path against real entries."""
    seed = A.TuningCache.load(A.SEED_CACHE_PATH)
    assert len(seed) > 0
    assert all("bnd-" not in k for k in seed.entries)


def test_plan_for_spec_routes_spec_fields():
    """plan_for_spec is plan_for with every leg drawn from the spec —
    including the explicit tile/depth overrides."""
    sp = ScanSpec(direction="fwd", impl="pallas", channels_per_weight=2,
                  stream_dtype="bfloat16", row_tile=32, pipeline_depth=1)
    assert A.plan_for_spec(sp, 64, 32, c=4) == A.ScanPlan(32, 1)
    sp_auto = sp.with_(row_tile=None, pipeline_depth=None)
    key = _key(device=A.device_kind(True), dtype="bfloat16")
    assert A.plan_for_spec(sp_auto, 64, 32, c=4, cache=A.TuningCache()) \
        == A.ScanPlan(A.heuristic_row_tile(key),
                      A.heuristic_pipeline_depth(key))


def test_invalid_cache_entry_emits_reject_counter_and_event():
    """Satellite: a present-but-invalid entry must not fall through to
    the heuristic silently — the reject increments a counter and logs an
    event naming the key and the reason."""
    obs.REGISTRY.reset()
    key = _key(device=A.device_kind(False))
    cache = A.TuningCache()
    cache.store(key, {"row_tile": 128})             # does not divide h=64
    before = obs.counter("autotune_cache_rejects_total").value
    obs.enable()
    try:
        plan = A.plan_for(key.h, key.w, c=key.c, direction="fwd",
                          dtype="float32", channel_shared=True,
                          cache=cache)
        rejects = [r for r in obs.records()
                   if r.ph == "i" and r.name == "autotune.cache_reject"]
    finally:
        obs.disable()
        obs.clear()
    assert plan.row_tile == A.heuristic_row_tile(key)
    assert obs.counter("autotune_cache_rejects_total").value == before + 1
    assert rejects
    assert rejects[0].args["key"] == key.encode()
    assert "divide" in rejects[0].args["reason"]
    # a clean miss (no entry at all) stays silent — no reject signal
    obs.REGISTRY.reset()
    A.plan_for(key.h, key.w, c=key.c, direction="fwd", dtype="float32",
               channel_shared=True, cache=A.TuningCache())
    assert obs.counter("autotune_cache_rejects_total").value == 0


def test_entry_invalid_reason_strings():
    key = _key()
    reason = A._entry_invalid_reason
    assert reason(key, {"row_tile": 16}) is None
    assert "missing" in reason(key, {})
    assert "power of two" in reason(key, {"row_tile": 3})
    assert "divide" in reason(key, {"row_tile": 128})
    assert "pipeline_depth" in reason(key, {"row_tile": 16,
                                            "pipeline_depth": 7})
    big = _key(h=1 << 20, w=8192)
    assert "VMEM" in reason(big, {"row_tile": 1 << 19})


# ---------------------------------------------------------------------------
# Deprecated kwargs-style shims over plan_for_spec (PR consolidation).
# ---------------------------------------------------------------------------

def test_plan_for_shim_equivalent_to_plan_for_spec(monkeypatch):
    """The deprecated kwargs surface must resolve the IDENTICAL plan as
    the spec surface for every leg combination — cache hit, reject path,
    heuristic miss — and warn exactly once per process."""
    import warnings

    monkeypatch.setattr(A, "_plan_for_warned", False)
    cache = A.TuningCache()
    hit = _key(device=A.device_kind(False))
    cache.store(hit, {"row_tile": 16, "pipeline_depth": 2})

    cases = [
        dict(direction="fwd", channel_shared=True, dtype="float32"),
        dict(direction="bwd", channel_shared=False, dtype="bfloat16"),
        dict(direction="fwd", channel_shared=True, dtype="float32",
             boundary="chunk_resume"),
        dict(direction="fwd", channel_shared=False, dtype="float32",
             row_tile=16, pipeline_depth=1),
    ]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for kw in cases:
            legacy = A.plan_for(hit.h, hit.w, c=hit.c, impl="pallas",
                                carry_dtype="float32", cache=cache, **kw)
            spec = ScanSpec(
                direction=kw["direction"], impl="pallas",
                channels_per_weight=2 if kw["channel_shared"] else 1,
                stream_dtype=kw["dtype"], carry_dtype="float32",
                row_tile=kw.get("row_tile"),
                pipeline_depth=kw.get("pipeline_depth"),
                boundary=kw.get("boundary", "one_shot"),
                interpret=False)
            assert legacy == A.plan_for_spec(spec, hit.h, hit.w, c=hit.c,
                                             cache=cache), kw
        deprecations = [w for w in rec
                        if issubclass(w.category, DeprecationWarning)
                        and "plan_for" in str(w.message)]
    assert len(deprecations) == 1       # warn-once latch across 4 calls
    # the cache-hit case actually hit: kwargs and spec agree on the key
    assert A.plan_for(hit.h, hit.w, c=hit.c, direction="fwd",
                      channel_shared=True, cache=cache) == A.ScanPlan(16, 2)


def test_row_tile_for_is_the_tile_view_of_plan_for_spec():
    cache = A.TuningCache()
    key = _key(device=A.device_kind(False), channel_shared=False)
    cache.store(key, {"row_tile": 16, "pipeline_depth": 2})
    sp = ScanSpec(direction="fwd", impl="pallas", channels_per_weight=1,
                  interpret=False)
    assert A.row_tile_for(key.h, key.w, c=key.c, channel_shared=False,
                          cache=cache) \
        == A.plan_for_spec(sp, key.h, key.w, c=key.c, cache=cache).row_tile \
        == 16
