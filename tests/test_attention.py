"""Attention: flash custom_vjp vs full reference, decode vs full,
RoPE/M-RoPE consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (AttentionConfig, apply_attention,
                                    apply_attention_decode,
                                    chunked_attention, decode_attention,
                                    full_attention, init_attention,
                                    init_kv_cache)
from repro.models.layers import apply_mrope, apply_rope


def _qkv(b=2, s=64, hq=6, hkv=2, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("block_k", [8, 16, 64])
def test_flash_matches_full(causal, block_k):
    q, k, v = _qkv()
    o1 = chunked_attention(q, k, v, causal=causal, block_k=block_k)
    o2 = full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_full(causal):
    q, k, v = _qkv(s=32)

    def loss(fn):
        return lambda q, k, v: jnp.sum(
            jnp.sin(fn(q, k, v)))

    g1 = jax.grad(loss(lambda q, k, v: chunked_attention(
        q, k, v, causal=causal, block_k=8)), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(lambda q, k, v: full_attention(
        q, k, v, causal=causal)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_decode_matches_full_layerwise():
    cfg = AttentionConfig(dim=32, n_heads=4, n_kv_heads=2, qkv_bias=True)
    params = init_attention(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y_full = apply_attention(params, x, cfg, positions=pos)
    cache = init_kv_cache(b, 16, cfg, jnp.float32)
    outs = []
    for t in range(s):
        y, cache = apply_attention_decode(params, x[:, t:t + 1], cfg, cache)
        outs.append(y[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_rope_relative_shift_invariance():
    """RoPE inner products depend only on relative offsets."""
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 1, d))
    p0 = jnp.array([[0, 3]])
    p1 = jnp.array([[5, 8]])
    r0 = apply_rope(x, p0)
    r1 = apply_rope(x, p1)
    dot0 = jnp.sum(r0[0, 0, 0] * r0[0, 1, 0])
    dot1 = jnp.sum(r1[0, 0, 0] * r1[0, 1, 0])
    np.testing.assert_allclose(float(dot0), float(dot1), rtol=1e-5)


def test_mrope_reduces_to_rope_for_text():
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 3, d))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 6))
    a = apply_rope(x, pos)
    b = apply_mrope(x, pos3, sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_decode_attention_masks_beyond_length():
    q, k, v = _qkv(b=2, s=8, hq=4, hkv=2, d=8, seed=3)
    q1 = q[:, :1]
    out_full = decode_attention(q1, k, v, jnp.array([8, 8]))
    # poisoning cache beyond the valid length must not change the output
    k2 = k.at[:, 5:].set(1e3)
    v2 = v.at[:, 5:].set(1e3)
    out_masked = decode_attention(q1, k2, v2, jnp.array([5, 5]))
    out_ref = decode_attention(q1, k, v, jnp.array([5, 5]))
    np.testing.assert_allclose(np.asarray(out_masked), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out_full), np.asarray(out_ref))
