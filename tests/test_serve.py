"""Serving engine: continuous batching, greedy determinism, slot refill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import LMConfig, apply_lm, init_lm
from repro.serve.engine import Request, ServeEngine

pytestmark = pytest.mark.serve


def _cfg():
    return LMConfig(name="d", family="dense", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
                    unit=(("attn", 2),), n_units=1, remat="none")


def test_engine_completes_all_requests():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(p, cfg, batch_size=2, max_len=64)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(3 + i) % 128,
                           max_new_tokens=6))
    res = eng.run()
    assert sorted(res) == list(range(5))
    assert all(len(r.tokens) == 6 for r in res.values())


def test_engine_greedy_matches_reference_rollout():
    """Engine greedy decode == step-by-step argmax over the full forward."""
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.array([5, 9, 2, 11], np.int32)
    n_new = 5

    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = apply_lm(p, cfg, jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(logits[0, -1])))
    expect = toks[len(prompt):]

    eng = ServeEngine(p, cfg, batch_size=2, max_len=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n_new))
    res = eng.run()
    assert res[0].tokens == expect


def test_engine_eos_stops_early():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.array([5, 9, 2, 11], np.int32)
    eng0 = ServeEngine(p, cfg, batch_size=1, max_len=64)
    eng0.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    first = eng0.run()[0].tokens
    # use the 3rd generated token as EOS; generation must stop there
    eos = first[2]
    eng = ServeEngine(p, cfg, batch_size=1, max_len=64, eos_id=eos)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    res = eng.run()[0].tokens
    assert res[-1] == eos and len(res) <= 3


def test_engine_mixed_lengths_continuous_batching():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(p, cfg, batch_size=2, max_len=64)
    lens = [2, 9, 4, 7]
    for i, n in enumerate(lens):
        eng.submit(Request(uid=i, prompt=np.arange(3 + i) % 128,
                           max_new_tokens=n))
    res = eng.run()
    assert [len(res[i].tokens) for i in range(4)] == lens


# ---------------------------------------------------------------------------
# update_cache_slots: the scatter that refills decode slots after prefill.
# Previously untested — the sp-sharded decode path (DESIGN.md §8) relies
# on it not regressing silently.
# ---------------------------------------------------------------------------

def _gspn_cfg():
    # gspn mixer prelude + attn unit: exercises BOTH batch-axis layouts
    # (prelude caches stack (n, B, ...), unit caches (n_units, n, B, ...)).
    return LMConfig(name="g", family="gspn", n_layers=2, d_model=48,
                    n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
                    prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
                    remat="none")


def test_update_cache_slots_partial_batch():
    """Scattering a 2-request prefill into slots {0, 2} of 4 must rewrite
    exactly those batch rows of every cache leaf and no others."""
    from repro.models.lm import init_lm_cache
    from repro.serve.engine import update_cache_slots

    cfg = _gspn_cfg()
    bs, max_len = 4, 32
    caches = jax.tree.map(
        lambda a: jnp.full_like(a, 7.0) if a.dtype != jnp.int32
        else jnp.full_like(a, 7), init_lm_cache(cfg, bs, max_len))
    new = jax.tree.map(
        lambda a: jnp.full_like(a, -3.0) if a.dtype != jnp.int32
        else jnp.full_like(a, -3), init_lm_cache(cfg, 2, max_len))

    out = update_cache_slots(cfg, caches, new, [0, 2])

    prelude_keys = {f"s{si}_{kind}" for si, (w, kind, n)
                    in enumerate(cfg.stages()) if w == "prelude"}
    for key, sub in out.items():
        axis = 1 if key in prelude_keys else 2
        for leaf in jax.tree.leaves(sub):
            got = np.moveaxis(np.asarray(leaf, np.float32), axis, 0)
            np.testing.assert_array_equal(got[[0, 2]], -3.0)
            np.testing.assert_array_equal(got[[1, 3]], 7.0)


def test_update_cache_slots_reuse_is_clean():
    """Slot reuse must not leak the previous occupant's state: running a
    request in a fresh engine vs in a slot that served a longer request
    first must produce identical tokens."""
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.array([5, 9, 2, 11], np.int32)

    fresh = ServeEngine(p, cfg, batch_size=1, max_len=64)
    fresh.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    expect = fresh.run()[0].tokens

    eng = ServeEngine(p, cfg, batch_size=1, max_len=64)
    eng.submit(Request(uid=0, prompt=np.arange(9) % 128, max_new_tokens=12))
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=6))
    res = eng.run()
    assert res[1].tokens == expect
