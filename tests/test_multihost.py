"""2-host multi-process mesh lane (DESIGN.md §8, ISSUE 10 tentpole).

Two coordinated subprocesses — one forced CPU device each — initialise a
real ``jax.distributed`` runtime through the ``repro.compat`` shims
(which select the gloo cross-process collective transport before
``jax.distributed.initialize``), build a 2-way ``seq`` mesh whose axis
spans the PROCESS boundary, and run the fused opposite-direction pair
through it.  Proves the production claim on an actual multi-host mesh:

* the fused pair still emits exactly ONE boundary collective;
* every addressable output shard matches the single-host reference;
* the sp_scaling ``overlap`` rung's mesh construction (global arrays via
  ``make_array_from_callback``) is exercised end to end.

Runtimes without a working gloo transport (or that cannot bind the
loopback coordinator) skip rather than fail — same contract as the
``run_sub`` probe.
"""

import socket
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.distributed

N_PROCS = 2

# Error signatures of a runtime that cannot do multi-process CPU
# collectives at all — skip, don't fail.  Anything else is a real bug.
_SKIP_MARKERS = (
    "Multiprocess computations aren't implemented",
    "jax.distributed is not available",
    "DEADLINE_EXCEEDED",
    "UNAVAILABLE",
    "failed to connect",
    "gloo",
)

_CHILD = textwrap.dedent("""
    import os, sys
    # APPENDED so it wins: on duplicated XLA flags the LAST occurrence
    # applies, and the inherited env may already force a device count
    # (importing repro.launch.dryrun in the pytest parent sets 512).
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=1")
    proc_id, port = int(sys.argv[1]), int(sys.argv[2])

    from repro import compat
    compat.distributed_initialize(f"localhost:{port}", 2, proc_id)

    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    assert jax.process_count() == 2, jax.process_count()
    assert jax.device_count() == 2, jax.device_count()

    from repro.core import gspn as G
    from repro.kernels.ref import gspn_scan_ref
    from repro.launch.mesh import make_sp_mesh
    from repro.parallel.gspn_sp import (collectives_in_jaxpr,
                                        gspn_scan_sp_pair)

    mesh = make_sp_mesh(2)
    gw, cpw, w, h = 2, 2, 8, 12
    g = gw * cpw
    # Same seeds on both processes -> identical host-local values; wrap
    # as GLOBAL arrays sharded over the cross-process seq axis.
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (g, h, w))
    lam2 = jax.nn.sigmoid(jax.random.normal(ks[1], (2, g, h, w)))
    wl2, wc2, wr2 = (
        jnp.stack(t) for t in zip(
            G.normalize_taps(jax.random.normal(ks[2], (gw, h, w, 3))),
            G.normalize_taps(jax.random.normal(ks[3], (gw, h, w, 3)))))
    host_args = (x, wl2, wc2, wr2, lam2)
    specs = (P(None, "seq", None),) + (P(None, None, "seq", None),) * 4
    args = tuple(
        jax.make_array_from_callback(
            a.shape, NamedSharding(mesh, s),
            lambda idx, a=a: np.asarray(a)[idx])
        for a, s in zip(host_args, specs))

    # ONE boundary collective, even across real process boundaries.
    cs = collectives_in_jaxpr(
        lambda *a: gspn_scan_sp_pair(*a, mesh=mesh), *args)
    assert len(cs) == 1 and "all_gather" in cs[0][0], cs
    assert cs[0][1] == (2, gw * w + g + 3 * gw, w), cs

    out = jax.jit(lambda *a: gspn_scan_sp_pair(*a, mesh=mesh))(*args)

    # Shard-by-shard equivalence with the single-host reference: each
    # process checks exactly the rows it owns.
    want = np.stack([
        np.asarray(gspn_scan_ref(x, wl2[0], wc2[0], wr2[0], lam2[0])),
        np.asarray(gspn_scan_ref(x, wl2[1], wc2[1], wr2[1], lam2[1],
                                 reverse=True))])
    shards = out.addressable_shards
    assert shards, "process owns no output shard"
    for sh in shards:
        np.testing.assert_allclose(np.asarray(sh.data), want[sh.index],
                                   rtol=1e-5, atol=1e-5)
    print(f"MULTIHOST_OK proc={proc_id}", flush=True)
    compat.distributed_shutdown()
""")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def test_two_process_fused_pair_single_collective():
    port = _free_port()
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _CHILD, str(i), str(port)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for i in range(N_PROCS)
    ]
    outs = []
    for p in procs:
        try:
            outs.append(p.communicate(timeout=560))
        except subprocess.TimeoutExpired:
            p.kill()
            outs.append(p.communicate())
    if any(p.returncode != 0 for p in procs):
        blob = "\n".join(o[0] + o[1] for o in outs)
        if any(m in blob for m in _SKIP_MARKERS):
            pytest.skip("multi-process CPU collectives unavailable: "
                        + blob.strip().splitlines()[-1][-200:])
        assert False, "\n\n".join(
            f"proc {i} rc={p.returncode}\nSTDOUT:\n{o[0]}\nSTDERR:\n{o[1]}"
            for i, (p, o) in enumerate(zip(procs, outs)))
    for i, (out, _err) in enumerate(outs):
        assert f"MULTIHOST_OK proc={i}" in out, outs
