"""Pallas kernel validation: interpret-mode vs the pure-jnp oracle across
shapes, dtypes, chunk settings and channel-sharing modes, plus gradients
against the dense Eq.-4 oracle, and the VMEM tile tuner's working-set
math under mixed dtypes (DESIGN.md §10)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gspn as G
from repro.kernels import ref as R
from repro.kernels import tuning
from repro.kernels.ops import gspn_scan

pytestmark = pytest.mark.kernels

SHAPES = [
    (1, 4, 8),
    (2, 16, 24),
    (3, 32, 16),
    (6, 8, 128),       # lane-aligned width
    (4, 64, 32),
]


def _make(gd, h, w, gw, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (gd, h, w), dtype)
    lam = jax.random.normal(ks[1], (gd, h, w), dtype)
    logits = jax.random.normal(ks[2], (gw, h, w, 3))
    wl, wc, wr = G.normalize_taps(logits)
    return x, wl.astype(dtype), wc.astype(dtype), wr.astype(dtype), lam


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("cpw", [1, 2])
def test_pallas_fwd_matches_ref(shape, cpw):
    gd, h, w = shape
    gd = gd * cpw
    x, wl, wc, wr, lam = _make(gd, h, w, gd // cpw)
    h_ref = R.gspn_scan_ref(x, wl, wc, wr, lam)
    h_pl = gspn_scan(x, wl, wc, wr, lam, impl="pallas")
    np.testing.assert_allclose(np.asarray(h_pl), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_dtypes(dtype):
    x, wl, wc, wr, lam = _make(4, 16, 32, 4, dtype)
    h_ref = R.gspn_scan_ref(x.astype(jnp.float32), wl.astype(jnp.float32),
                            wc.astype(jnp.float32), wr.astype(jnp.float32),
                            lam.astype(jnp.float32))
    h_pl = gspn_scan(x, wl, wc, wr, lam, impl="pallas")
    assert h_pl.dtype == dtype
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(h_pl, np.float32),
                               np.asarray(h_ref), rtol=tol, atol=tol)


def test_scan_matches_dense_eq4_oracle():
    x, wl, wc, wr, lam = _make(2, 8, 12, 2)
    h_ref = R.gspn_scan_ref(x, wl, wc, wr, lam)
    h_dense = R.gspn_dense_oracle(x, wl, wc, wr, lam)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_dense),
                               rtol=1e-5, atol=1e-5)


def test_per_step_emulation_matches():
    x, wl, wc, wr, lam = _make(2, 12, 16, 2)
    h_ref = R.gspn_scan_ref(x, wl, wc, wr, lam)
    h_ps = R.gspn_scan_per_step(x, wl, wc, wr, lam, block=False)
    np.testing.assert_allclose(np.asarray(h_ps), np.asarray(h_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("cpw", [1, 3])
def test_custom_vjp_matches_autodiff(impl, cpw):
    gd, h, w = 2 * cpw, 16, 24
    x, wl, wc, wr, lam = _make(gd, h, w, gd // cpw, seed=3)
    logits = jax.random.normal(jax.random.PRNGKey(9), (gd // cpw, h, w, 3))

    def loss_ops(x, logits, lam):
        wl, wc, wr = G.normalize_taps(logits)
        return jnp.sum(jnp.sin(gspn_scan(x, wl, wc, wr, lam, impl=impl)))

    def loss_ref(x, logits, lam):
        wl, wc, wr = G.normalize_taps(logits)
        return jnp.sum(jnp.sin(R.gspn_scan_ref(x, wl, wc, wr, lam)))

    g_ops = jax.grad(loss_ops, argnums=(0, 1, 2))(x, logits, lam)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, logits, lam)
    for a, b in zip(g_ops, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_blockdiag(chunk):
    x, wl, wc, wr, lam = _make(4, 16, 20, 2, seed=5)
    out = gspn_scan(x, wl, wc, wr, lam, chunk=chunk, impl="xla")
    ref = R.gspn_scan_chunked_ref(x, wl, wc, wr, lam, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_chunk_full_equals_unchunked():
    x, wl, wc, wr, lam = _make(2, 16, 20, 2, seed=6)
    a = gspn_scan(x, wl, wc, wr, lam, chunk=16, impl="pallas")
    b = gspn_scan(x, wl, wc, wr, lam, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# VMEM tile tuner under mixed dtypes (DESIGN.md §10).
# ---------------------------------------------------------------------------

def test_working_set_math_mixed_dtypes():
    """Exact accounting: n_streams double-buffered streamed tiles in the
    STREAM dtype + one carry row in the CARRY dtype."""
    t, w, n = 64, 128, 6
    assert tuning.scan_working_set(t, w, 4, n) == n * t * w * 4 * 2 + w * 4
    # bf16 streams halve only the streamed term; the f32 carry is fixed
    assert tuning.scan_working_set(t, w, 2, n) == n * t * w * 2 * 2 + w * 4
    # carry_dtype_bytes moves only the carry term
    assert (tuning.scan_working_set(t, w, 2, n, carry_dtype_bytes=2)
            == n * t * w * 2 * 2 + w * 2)
    # headroom: disabling double-buffering halves the streamed term only
    assert (tuning.scan_working_set(t, w, 4, n, double_buffer=False)
            == n * t * w * 4 + w * 4)


def test_pick_row_tile_bf16_unlocks_double_tile():
    """At a fixed VMEM budget, halving the streamed dtype doubles the row
    tile — the §10 payoff the backward pass was missing while it
    hard-coded dtype_bytes=4."""
    budget = 2 ** 21
    t32 = tuning.pick_row_tile(4096, 128, 4, vmem_budget=budget)
    t16 = tuning.pick_row_tile(4096, 128, 2, vmem_budget=budget)
    assert t16.row_tile == 2 * t32.row_tile
    assert t32.working_set_bytes <= budget
    assert t16.working_set_bytes <= budget
    # and the bf16 choice would NOT fit if streamed at 4 bytes
    assert tuning.scan_working_set(t16.row_tile, 128, 4) > budget


def test_pick_row_tile_carry_bytes_respected():
    """An (artificially) enormous carry must shrink the tile: the carry
    term is part of the budget, not a constant 4-byte afterthought."""
    budget = 2 ** 16
    small = tuning.pick_row_tile(1024, 128, 2, vmem_budget=budget)
    big_carry = tuning.pick_row_tile(1024, 128, 2, vmem_budget=budget,
                                     carry_dtype_bytes=400)
    assert big_carry.row_tile <= small.row_tile
    assert big_carry.working_set_bytes <= budget


@pytest.mark.parametrize("h", [48, 96, 136, 4096])
@pytest.mark.parametrize("dtype_bytes", [2, 4])
def test_pick_row_tile_divides_scan_length(h, dtype_bytes):
    c = tuning.pick_row_tile(h, 64, dtype_bytes, cap=256)
    assert h % c.row_tile == 0
    assert c.row_tile & (c.row_tile - 1) == 0       # power of two
    assert c.n_grid_steps == h // c.row_tile
    assert c.row_tile <= 256


def test_bwd_row_tile_sees_streamed_dtype():
    """gspn_scan_bwd_pallas routes the REAL dy dtype into the tuner (the
    fix for the hard-coded dtype_bytes=4): at equal shapes the bf16
    adjoint may never pick a smaller tile than the f32 one."""
    from repro.kernels.gspn_scan import pick_row_tile as wrapper
    t32 = wrapper(4096, w=128, dtype_bytes=4, n_streams=5,
                  carry_dtype_bytes=12)
    t16 = wrapper(4096, w=128, dtype_bytes=2, n_streams=5,
                  carry_dtype_bytes=12)
    assert t16 >= t32


def test_depth2_staging_term_in_working_set():
    """Depth-2 adds exactly one f32 staging copy per streamed tile
    (DESIGN.md §12), independent of the stream dtype."""
    t, w, n = 64, 128, 6
    for b in (2, 4):
        assert (tuning.scan_working_set(t, w, b, n, pipeline_depth=2)
                == tuning.scan_working_set(t, w, b, n) + n * t * w * 4)
    # bf16 depth-2 footprint lands exactly on the f32 depth-1 footprint
    # (2·2 + 4 = 4·2 bytes per streamed element).
    assert (tuning.scan_working_set(t, w, 2, n, pipeline_depth=2)
            == tuning.scan_working_set(t, w, 4, n, pipeline_depth=1))


def test_admitted_tile_bf16_never_below_f32():
    """The narrow-dtype admission pin (ISSUE 6 satellite): at equal
    shapes and budget, the tile the tuner admits for a bf16 stream is
    never smaller than the f32 one — at depth 1 (halved streamed term)
    AND at the depth the heuristic would actually run bf16 at (depth 2,
    whose staging term brings it exactly back to the f32 footprint)."""
    budget = 2 ** 21
    for h, w in ((4096, 128), (1024, 64), (128, 128)):
        t32 = tuning.pick_row_tile(h, w, 4, vmem_budget=budget).row_tile
        for depth in (1, 2):
            t16 = tuning.pick_row_tile(h, w, 2, vmem_budget=budget,
                                       pipeline_depth=depth).row_tile
            assert t16 >= t32, (h, w, depth, t16, t32)


def test_depth2_halves_admissible_tile_same_dtype():
    """At a tight budget the staging copies halve the admissible tile
    RELATIVE TO THE SAME dtype at depth 1 — the §12 trade: smaller tile,
    but bulk converts instead of per-row narrow-dtype stores."""
    budget = 2 ** 21
    t16_d1 = tuning.pick_row_tile(4096, 128, 2, vmem_budget=budget,
                                  pipeline_depth=1).row_tile
    t16_d2 = tuning.pick_row_tile(4096, 128, 2, vmem_budget=budget,
                                  pipeline_depth=2).row_tile
    assert t16_d2 == t16_d1 // 2


def test_ref_vjp_helper_matches_autodiff():
    x, wl, wc, wr, lam = _make(4, 8, 12, 2, seed=7)
    dy = jax.random.normal(jax.random.PRNGKey(11), x.shape)

    def f(x, wl, wc, wr, lam):
        return jnp.sum(R.gspn_scan_ref(x, wl, wc, wr, lam) * dy)

    g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, wl, wc, wr, lam)
    dx, dwl, dwc, dwr, dlam = R.gspn_scan_ref_vjp(x, wl, wc, wr, lam, dy)
    for a, b in zip((dx, dwl, dwc, dwr, dlam), g):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
