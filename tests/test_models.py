"""Per-architecture smoke tests (reduced configs of the same family) and
prefill/decode equivalence for every cache type."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.all_archs import ASSIGNED, EXTRAS
from repro.configs.base import get_arch
from repro.models.lm import (apply_lm, init_lm,
                             lm_decode_step, lm_loss, lm_prefill,
                             count_params, count_active_params)

ALL = ASSIGNED + EXTRAS


def _batch_for(cfg, b=2, s=24, seed=0):
    tokens = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, s // 2, cfg.d_model))
    if cfg.family == "audio":
        batch["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (b, cfg.enc_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_reduced_forward_and_train_step(arch):
    """One forward + one grad step on CPU: output shapes + no NaNs."""
    cfg = get_arch(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = apply_lm(params, cfg, batch["tokens"],
                           vision_embeds=batch.get("vision_embeds"),
                           enc_frames=batch.get("enc_frames"))
    assert logits.shape == (2, 24, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, metrics = lm_loss(params, cfg, batch)
    assert np.isfinite(float(loss))

    grads = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "xlstm-1.3b", "zamba2-2.7b",
                                  "kimi-k2-1t-a32b", "qwen2-1.5b-gspn"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    import dataclasses
    # high capacity so MoE drops don't perturb the equivalence check
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s_p, s_tot = 2, 9, 14
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s_tot), 0,
                              cfg.vocab)
    logits_full, _ = apply_lm(params, cfg, toks)
    logits_pf, caches, _ = lm_prefill(params, cfg, toks[:, :s_p], max_len=20)
    np.testing.assert_allclose(
        np.asarray(logits_pf, np.float32),
        np.asarray(logits_full[:, :s_p], np.float32), rtol=3e-2, atol=3e-2)
    outs = []
    for t in range(s_p, s_tot):
        lg, caches = lm_decode_step(params, cfg, toks[:, t:t + 1], caches)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1), np.float32),
        np.asarray(logits_full[:, s_p:], np.float32), rtol=5e-2, atol=5e-2)


def test_audio_decode_with_cross_attention():
    cfg = get_arch("whisper-base").reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    frames = jax.random.normal(jax.random.PRNGKey(4),
                               (b, cfg.enc_len, cfg.d_model))
    logits_full, _ = apply_lm(params, cfg, toks, enc_frames=frames)
    logits_pf, caches, enc_kv = lm_prefill(params, cfg, toks[:, :5],
                                           max_len=16, enc_frames=frames)
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits_full[:, :5], np.float32),
                               rtol=3e-2, atol=3e-2)
    outs = []
    for t in range(5, s):
        lg, caches = lm_decode_step(params, cfg, toks[:, t:t + 1], caches,
                                    enc_kv=enc_kv)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1), np.float32),
        np.asarray(logits_full[:, 5:], np.float32), rtol=5e-2, atol=5e-2)


def test_full_config_dims_exact():
    """The full configs carry the exact published dimensions."""
    expect = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        cfg = get_arch(arch).full()
        assert cfg.n_layers == l and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff or arch == "kimi-k2-1t-a32b", arch
        assert cfg.vocab == v, arch
    kimi = get_arch("kimi-k2-1t-a32b").full()
    assert (kimi.n_layers, kimi.d_model, kimi.n_experts, kimi.top_k,
            kimi.moe_d_ff, kimi.vocab) == (61, 7168, 384, 8, 2048, 163840)
    grok = get_arch("grok-1-314b").full()
    assert (grok.n_experts, grok.top_k) == (8, 2)


def test_param_scale_sanity():
    """Active-parameter estimates land near the advertised scales."""
    approx = {
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "qwen1.5-32b": (28e9, 38e9),
        "granite-3-2b": (2.0e9, 3.3e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "qwen2.5-3b": (2.5e9, 3.9e9),
        "zamba2-2.7b": (2.0e9, 3.5e9),
        "qwen2-vl-72b": (60e9, 80e9),
        "grok-1-314b": (70e9, 90e9),   # active (top-2 of 8)
    }
    for arch, (lo, hi) in approx.items():
        n = count_active_params(get_arch(arch).full())
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_layer_pattern_counts():
    assert get_arch("xlstm-1.3b").full().layer_count() == 48
    assert get_arch("zamba2-2.7b").full().layer_count() == 54 + 9
    assert get_arch("kimi-k2-1t-a32b").full().layer_count() == 61
