"""Table 1 — global-memory throughput of the fused scan.

Paper: GSPN-2 sustains 91–93 % of A100 peak HBM bandwidth across sizes,
vs 2–6 % for GSPN-1.  Here we measure achieved bytes/s of (a) the fused
XLA scan and (b) the per-step GSPN-1 emulation on CPU, and report each as
a fraction of measured STREAM-like CPU peak — the structural claim is the
*ratio* between the two regimes and its stability across configurations.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, make_gspn_inputs, scan_bytes, time_fn)
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan

# (paper rows, CPU-scaled: same aspect, smaller)   size, batch, channels
CONFIGS = [
    (32, 32, 16),
    (64, 1, 96),
    (64, 1, 32),
    (128, 1, 32),
    (256, 1, 16),
    (256, 4, 16),
]


def _cpu_peak_bw():
    """Measured copy bandwidth as the roofline denominator."""
    a = jnp.ones((64, 1024, 1024), jnp.float32)
    cp = jax.jit(lambda x: x + 1.0)
    t = time_fn(cp, a)
    return 2 * a.size * 4 / t


def run():
    peak = _cpu_peak_bw()
    emit("table1/cpu_peak_GBs", peak / 1e9 * 1e6 / 1e6, "copy-bandwidth")
    fused = jax.jit(lambda *a: gspn_scan(*a, impl="xla"))
    out = {}
    for size, batch, ch in CONFIGS:
        x, wl, wc, wr, lam = make_gspn_inputs(batch, ch, size, size)
        nbytes = scan_bytes(batch, ch, size, size)
        t_f = time_fn(fused, x, wl, wc, wr, lam)
        bw_f = nbytes / t_f
        t_s = time_fn(lambda: R.gspn_scan_per_step(
            x, wl, wc, wr, lam, block=True), iters=1)
        bw_s = nbytes / t_s
        name = f"table1/{size}x{size}_b{batch}_c{ch}"
        emit(name, t_f * 1e6,
             f"fused={bw_f/1e9:.2f}GB/s({100*bw_f/peak:.0f}%);"
             f"per_step={bw_s/1e9:.2f}GB/s({100*bw_s/peak:.0f}%);"
             f"paper=92%vs3-8%")
        out[name] = (bw_f / peak, bw_s / peak)
    return out


if __name__ == "__main__":
    run()
