"""Spatial-sequence-parallel scaling ladder (DESIGN.md §8).

Ladder over 1/2/4/8 simulated devices × grid sizes, reporting per-scan
step time and the analytic collective traffic: the sp scan exchanges one
boundary column (plus, for the all-gather strategy, the compact (W, W)
transfer operator) instead of any full activation — the ``ratio`` column
is collective bytes over the bytes a naive activation gather would move.
The strategy each rung measures is resolved by
``SPConfig.resolved_strategy`` — the SAME rule production dispatch uses —
and the traffic model is parameterized on the wire dtype
(``boundary_dtype``), both pinned against the implementation by tests.

The ``overlap`` rungs measure the fused opposite-direction pair
(``gspn_scan_sp_pair``): one collective per pair (counted from the
jaxpr), with overlap efficiency derived from three schedules of the SAME
computation — ``overlap`` (production), ``serial`` (a barrier pins the
exchange ahead of the local scan: exchange fully exposed) and ``skip``
(no exchange: the timing floor):

    exposed = serial - skip          # exchange time on the critical path
    hidden  = serial - overlap       # how much of it overlap recovers
    overlap_efficiency = hidden / exposed

A 2-host rung repeats the measurement on a true multi-PROCESS mesh (two
coordinated children through ``repro.compat.distributed_initialize``,
gloo CPU collectives) and is skipped with a stderr note where the
runtime lacks multiprocess support.

Device counts are forced with ``--xla_force_host_platform_device_count``,
which must be set BEFORE jax imports, so each rung runs in a child
interpreter (``python -m benchmarks.sp_scaling --devices N``); the parent
``run()`` re-emits the children's CSV rows.  CPU timings are indicative
only (like fig3, the ladder is reproduced structurally); the traffic
model is exact.
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys

DEVICES = (1, 2, 4, 8)
GRIDS = [(2, 2, 256, 256), (2, 2, 512, 512)]    # (B, C_proxy, H, W)
SMOKE_DEVICES = (1, 2)
SMOKE_GRIDS = [(1, 2, 64, 64)]
MULTIHOST_PROCS = 2


def strategy_for(n_dev: int, *, pair: bool = False) -> str:
    """The strategy production resolves for this device count.

    Delegates to ``SPConfig.resolved_strategy`` so the ladder can never
    drift from what ``ops.py`` dispatch actually runs (a drift-pin test
    asserts the agreement across device counts).
    """
    from repro.parallel.gspn_sp import SPConfig
    return SPConfig(n_blocks=n_dev).resolved_strategy(pair=pair)


def collective_bytes(n_dev: int, b: int, g: int, w: int, strategy: str,
                     wire_bytes: int = 4) -> int:
    """Exact per-scan exchange traffic in ``wire_bytes``-wide payloads.

    ppermute ships boundary columns hop by hop; allgather ships the
    compact (T, b) pairs; pair_allgather ships BOTH directions' stacked
    (T, b) states plus the 3 adjoint edge weight rows in one collective
    (G_w = b: compact taps).  ``wire_bytes`` is the itemsize of the
    configured ``boundary_dtype`` (bf16 wire halves every figure).
    """
    if n_dev == 1:
        return 0
    if strategy == "ppermute":
        return (n_dev - 1) * g * w * wire_bytes
    if strategy == "allgather":
        return n_dev * (b * w * w + g * w) * wire_bytes
    if strategy == "pair_allgather":
        return n_dev * 2 * (b * w * w + g * w + 3 * b * w) * wire_bytes
    raise ValueError(f"unknown strategy {strategy!r}")


def _pair_inputs(b, cp, h, w):
    import jax.numpy as jnp
    from benchmarks.common import make_gspn_inputs

    x, wl0, wc0, wr0, lam0 = make_gspn_inputs(b, cp, h, w, seed=0)
    _, wl1, wc1, wr1, lam1 = make_gspn_inputs(b, cp, h, w, seed=1)
    return (x, jnp.stack([wl0, wl1]), jnp.stack([wc0, wc1]),
            jnp.stack([wr0, wr1]), jnp.stack([lam0, lam1]))


def _overlap_row(n_dev, tag, mesh, args, time_fn, wire_dtype="float32"):
    """Time the three exchange schedules of the fused pair and derive the
    overlap efficiency + jaxpr-counted collectives per pair."""
    import jax

    from repro.parallel.gspn_sp import (collectives_in_jaxpr,
                                        gspn_scan_sp_pair)

    times = {}
    for mode in ("overlap", "serial", "skip"):
        fn = jax.jit(lambda *a, m=mode: gspn_scan_sp_pair(
            *a, mesh=mesh, exchange_mode=m, boundary_dtype=wire_dtype))
        # the dtype-ladder precedent: relative timings get a few
        # iterations even under --smoke so one hiccup can't flip them
        times[mode] = time_fn(fn, *args, iters=10, min_iters=5)
    exposed = max(times["serial"] - times["skip"], 0.0)
    hidden = max(times["serial"] - times["overlap"], 0.0)
    eff = min(hidden / exposed, 1.0) if exposed > 0 else 0.0
    fused = collectives_in_jaxpr(
        lambda *a: gspn_scan_sp_pair(*a, mesh=mesh,
                                     boundary_dtype=wire_dtype), *args)
    per_dir = collectives_in_jaxpr(
        lambda *a: gspn_scan_sp_pair(*a, mesh=mesh, strategy="allgather",
                                     boundary_dtype=wire_dtype), *args)
    return (f"sp_scaling/{tag}", times["overlap"] * 1e6,
            f"strategy=pair_allgather;collectives_per_pair={len(fused)};"
            f"per_direction_collectives={len(per_dir)};"
            f"overlap_efficiency={eff:.3f};"
            f"serial_us={times['serial'] * 1e6:.1f};"
            f"floor_us={times['skip'] * 1e6:.1f};"
            f"exchange_exposed_us={exposed * 1e6:.1f};"
            f"exchange_hidden_us={hidden * 1e6:.1f};"
            # efficiency needs spare cores to hide I/O behind compute —
            # on a 1-core host every schedule serializes and ~0 is the
            # honest reading; multi-core runners show the real overlap
            f"host_cores={os.cpu_count()};"
            f"wire_dtype={wire_dtype}")


def _child(n_dev: int, smoke: bool) -> None:
    # appended so it wins over any inherited forced device count
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_dev}")
    import jax
    import jax.numpy as jnp

    import benchmarks.common as common
    common.SMOKE = smoke
    from benchmarks.common import emit, time_fn, make_gspn_inputs
    from repro.launch.mesh import make_sp_mesh
    from repro.parallel.gspn_sp import gspn_scan_sp

    mesh = make_sp_mesh(n_dev) if n_dev > 1 else None
    strategy = strategy_for(n_dev)
    # bf16 wire only on the full ladder — smoke keeps one rung per shape
    wires = ("float32",) if smoke or n_dev == 1 else ("float32", "bfloat16")
    for b, cp, h, w in (SMOKE_GRIDS if smoke else GRIDS):
        x, wl, wc, wr, lam = make_gspn_inputs(b, cp, h, w)
        g = b * cp
        for wire in wires:
            fn = jax.jit(lambda *a, wd=wire: gspn_scan_sp(
                *a, mesh=mesh, strategy=strategy, boundary_dtype=wd))
            t = time_fn(fn, x, wl, wc, wr, lam)
            wire_bytes = jnp.dtype(wire).itemsize
            coll = collective_bytes(n_dev, b, g, w, strategy, wire_bytes)
            act = g * h * w * 4
            suffix = "" if wire == "float32" else "_bf16wire"
            emit(f"sp_scaling/dev{n_dev}_h{h}w{w}{suffix}_us", t * 1e6,
                 f"strategy={strategy if n_dev > 1 else 'local'};"
                 f"collective_bytes={coll};activation_bytes={act};"
                 f"ratio={coll / act:.5f};wire_dtype={wire}")
        if n_dev > 1:
            name, us, derived = _overlap_row(
                n_dev, f"overlap_dev{n_dev}_h{h}w{w}_us", mesh,
                _pair_inputs(b, cp, h, w), time_fn)
            emit(name, us, derived)


def _multihost_child(proc_id: int, port: int, smoke: bool) -> None:
    # One local device per process: the 2-process mesh IS the 2 hosts.
    # appended so it wins over any inherited forced device count
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=1")
    from repro import compat
    compat.distributed_initialize(f"localhost:{port}", MULTIHOST_PROCS,
                                  proc_id)
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    import benchmarks.common as common
    common.SMOKE = smoke
    from benchmarks.common import emit, time_fn
    from repro.launch.mesh import make_sp_mesh

    mesh = make_sp_mesh(MULTIHOST_PROCS)
    b, cp, h, w = SMOKE_GRIDS[0] if smoke else GRIDS[0]
    # Same seeds on every process → identical host-local values; wrap
    # them as GLOBAL arrays sharded over the cross-process seq axis.
    local = _pair_inputs(b, cp, h, w)
    specs = (P(None, "seq", None),) + (P(None, None, "seq", None),) * 4
    args = tuple(
        jax.make_array_from_callback(
            a.shape, NamedSharding(mesh, s),
            lambda idx, a=a: np.asarray(a)[idx])
        for a, s in zip(local, specs))
    name, us, derived = _overlap_row(
        MULTIHOST_PROCS, f"overlap_hosts{MULTIHOST_PROCS}_h{h}w{w}_us",
        mesh, args, time_fn)
    if proc_id == 0:
        emit(name, us, f"{derived};hosts={MULTIHOST_PROCS}")
    compat.distributed_shutdown()


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _run_multihost(smoke: bool):
    """Launch the coordinated 2-process overlap rung; yield proc-0 rows.

    Multiprocess CPU collectives need a working gloo transport — where
    the runtime lacks it the rung is skipped with a stderr note rather
    than failing the whole ladder.
    """
    port = _free_port()
    procs = []
    for i in range(MULTIHOST_PROCS):
        cmd = [sys.executable, "-m", "benchmarks.sp_scaling",
               "--multihost-proc", str(i), "--port", str(port)]
        if smoke:
            cmd.append("--smoke")
        procs.append(subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                      stderr=subprocess.PIPE, text=True))
    outs = []
    for p in procs:
        try:
            outs.append(p.communicate(timeout=900))
        except subprocess.TimeoutExpired:
            p.kill()
            outs.append(p.communicate())
    if any(p.returncode != 0 for p in procs):
        err = " | ".join(o[1].strip().splitlines()[-1] if o[1].strip()
                         else f"rc={p.returncode}"
                         for p, o in zip(procs, outs))
        print(f"sp_scaling: multihost rung skipped ({err})",
              file=sys.stderr, flush=True)
        return []
    return [ln for ln in outs[0][0].splitlines()
            if ln.startswith("sp_scaling/")]


def run() -> None:
    import benchmarks.common as common

    devices = SMOKE_DEVICES if common.SMOKE else DEVICES
    for n_dev in devices:
        cmd = [sys.executable, "-m", "benchmarks.sp_scaling",
               "--devices", str(n_dev)]
        if common.SMOKE:
            cmd.append("--smoke")
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            raise RuntimeError(
                f"sp_scaling child (devices={n_dev}) failed:\n{r.stderr}")
        for line in r.stdout.splitlines():
            if line.startswith("sp_scaling/"):
                common.ROWS.append(line)
                print(line, flush=True)
    for line in _run_multihost(common.SMOKE):
        common.ROWS.append(line)
        print(line, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="child mode: run the rung for this device count")
    ap.add_argument("--multihost-proc", type=int, default=-1,
                    help="multi-process child mode: this process's id")
    ap.add_argument("--port", type=int, default=0,
                    help="coordinator port for --multihost-proc")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.multihost_proc >= 0:
        _multihost_child(args.multihost_proc, args.port, args.smoke)
    elif args.devices:
        _child(args.devices, args.smoke)
    else:
        if args.smoke:
            import benchmarks.common as common
            common.SMOKE = True
        print("name,us_per_call,derived")
        run()


if __name__ == "__main__":
    main()
