"""Spatial-sequence-parallel scaling ladder (DESIGN.md §8).

Ladder over 1/2/4/8 simulated devices × grid sizes, reporting per-scan
step time and the analytic collective traffic: the sp scan exchanges one
boundary column (plus, for the all-gather strategy, the compact (W, W)
transfer operator) instead of any full activation — the ``ratio`` column
is collective bytes over the bytes a naive activation gather would move.

Device counts are forced with ``--xla_force_host_platform_device_count``,
which must be set BEFORE jax imports, so each rung runs in a child
interpreter (``python -m benchmarks.sp_scaling --devices N``); the parent
``run()`` re-emits the children's CSV rows.  CPU timings are indicative
only (like fig3, the ladder is reproduced structurally); the traffic
model is exact.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

DEVICES = (1, 2, 4, 8)
GRIDS = [(2, 2, 256, 256), (2, 2, 512, 512)]    # (B, C_proxy, H, W)
SMOKE_DEVICES = (1, 2)
SMOKE_GRIDS = [(1, 2, 64, 64)]


def _strategy_for(n_dev: int) -> str:
    return "ppermute" if n_dev <= 4 else "allgather"


def collective_bytes(n_dev: int, b: int, g: int, w: int,
                     strategy: str) -> int:
    """Exact per-scan exchange traffic (f32): boundary columns for the
    ppermute chain; (T, b) pairs for the all-gather composition."""
    if n_dev == 1:
        return 0
    if strategy == "ppermute":
        return (n_dev - 1) * g * w * 4
    return n_dev * (b * w * w + g * w) * 4      # G_w = b (compact taps)


def _child(n_dev: int, smoke: bool) -> None:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev} "
        + os.environ.get("XLA_FLAGS", ""))
    import jax

    import benchmarks.common as common
    common.SMOKE = smoke
    from benchmarks.common import emit, time_fn, make_gspn_inputs
    from repro.launch.mesh import make_sp_mesh
    from repro.parallel.gspn_sp import gspn_scan_sp

    mesh = make_sp_mesh(n_dev) if n_dev > 1 else None
    strategy = _strategy_for(n_dev)
    for b, cp, h, w in (SMOKE_GRIDS if smoke else GRIDS):
        x, wl, wc, wr, lam = make_gspn_inputs(b, cp, h, w)
        g = b * cp
        fn = jax.jit(lambda *a: gspn_scan_sp(
            *a, mesh=mesh, strategy=strategy))
        t = time_fn(fn, x, wl, wc, wr, lam)
        coll = collective_bytes(n_dev, b, g, w, strategy)
        act = g * h * w * 4
        emit(f"sp_scaling/dev{n_dev}_h{h}w{w}_us", t * 1e6,
             f"strategy={strategy if n_dev > 1 else 'local'};"
             f"collective_bytes={coll};activation_bytes={act};"
             f"ratio={coll / act:.5f}")


def run() -> None:
    import benchmarks.common as common

    devices = SMOKE_DEVICES if common.SMOKE else DEVICES
    for n_dev in devices:
        cmd = [sys.executable, "-m", "benchmarks.sp_scaling",
               "--devices", str(n_dev)]
        if common.SMOKE:
            cmd.append("--smoke")
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            raise RuntimeError(
                f"sp_scaling child (devices={n_dev}) failed:\n{r.stderr}")
        for line in r.stdout.splitlines():
            if line.startswith("sp_scaling/"):
                common.ROWS.append(line)
                print(line, flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="child mode: run the rung for this device count")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.devices:
        _child(args.devices, args.smoke)
    else:
        if args.smoke:
            import benchmarks.common as common
            common.SMOKE = True
        print("name,us_per_call,derived")
        run()


if __name__ == "__main__":
    main()
