"""Figure 3 — step-by-step kernel-optimisation ladder.

The paper measures cumulative CUDA optimisations on an A100 (71.4 ms →
1.8 ms, 40×).  This container has no GPU, so we reproduce the ladder
*structurally* on CPU/XLA: each stage maps onto the TPU/XLA analogue of
the paper's CUDA change (DESIGN.md §2), and the derived column reports
the cumulative speedup for direct comparison against the paper's ratios.

Stages:
  gspn1_per_step     one dispatch per scan line, hidden state round-trips
                     through device memory (the GSPN-1 pathology)
  +fused_scan        the whole scan in ONE compiled program (kernel fuse)
  +coalesced         scan axis chosen so the vector axis is contiguous
                     (the strided variant emulates GSPN-1's layout)
  +channel_shared    GSPN-2 compact propagation: one tap set per position
                     shared by all channels (3× fewer weight bytes)
  +proxy_compress    propagate in C_proxy=8 ≪ C space (paper §4.2)
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_gspn_inputs, time_fn
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan

# CPU-scaled configuration (paper: 1024×1024, B=16, C=8 on A100).
B, C, H, W = 4, 8, 256, 256
CP = 2   # proxy dim for the final stage


def run():
    x, wl, wc, wr, lam = make_gspn_inputs(B, C, H, W, channel_shared=False)

    # Stage 0: GSPN-1 — per-line dispatch, blocking between lines.
    t0 = time_fn(
        lambda: R.gspn_scan_per_step(x, wl, wc, wr, lam, block=True),
        iters=2)
    emit("fig3/gspn1_per_step_ms", t0 * 1e6, "cum_speedup=1.00")

    # Stage 1: fused scan, but strided layout (scan over the CONTIGUOUS
    # axis => vector ops hit strided memory, like GSPN-1's accesses).
    xs = jnp.swapaxes(x, 1, 2).copy()
    ws = [jnp.swapaxes(a, 1, 2).copy() for a in (wl, wc, wr)]
    lams = jnp.swapaxes(lam, 1, 2).copy()
    fused_strided = jax.jit(lambda *a: jnp.swapaxes(
        gspn_scan(a[0], a[1], a[2], a[3], a[4], impl="xla"), 1, 2))
    t1 = time_fn(fused_strided, xs, *ws, lams)
    emit("fig3/fused_scan_ms", t1 * 1e6, f"cum_speedup={t0/t1:.2f}")

    # Stage 2: + coalesced layout (vector axis contiguous).
    fused = jax.jit(lambda *a: gspn_scan(*a, impl="xla"))
    t2 = time_fn(fused, x, wl, wc, wr, lam)
    emit("fig3/coalesced_ms", t2 * 1e6, f"cum_speedup={t0/t2:.2f}")

    # Stage 3: + channel-shared taps (compact propagation).
    x2, wl2, wc2, wr2, lam2 = make_gspn_inputs(B, C, H, W,
                                               channel_shared=True)
    t3 = time_fn(fused, x2, wl2, wc2, wr2, lam2)
    emit("fig3/channel_shared_ms", t3 * 1e6, f"cum_speedup={t0/t3:.2f}")

    # Stage 4: + compressive proxy (C -> CP).
    x3, wl3, wc3, wr3, lam3 = make_gspn_inputs(B, CP, H, W,
                                               channel_shared=True)
    t4 = time_fn(fused, x3, wl3, wc3, wr3, lam3)
    emit("fig3/proxy_compress_ms", t4 * 1e6,
         f"cum_speedup={t0/t4:.2f};paper_cum=40.0")
    return {"cum_speedup": t0 / t4}


if __name__ == "__main__":
    run()
