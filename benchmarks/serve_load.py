"""Offered-load serving ladder (see benchmarks/README.md).

Plays an open-loop arrival process against the continuous-batching engine
(DESIGN.md §9) over a (rate × prompt-length-mix) grid and reports, per
rung, mean TTFT (the CSV us_per_call column) plus derived throughput,
p50/max TTFT, mean inter-token latency and max queue depth.  Prompt
lengths are drawn from a small discrete set so jit variants are bounded;
a warm-up pass through every (chunk, tail, decode) shape keeps compile
time out of the measured TTFTs.  ``--smoke`` runs one rung with 4
requests.

Two serving-tier axes ride the same module (DESIGN.md §15):

* ``serve_load/replicas/rN`` — the SAME saturating workload through a
  threaded router with N=1 and N=2 replicas; derived fields report QPS,
  the QPS scale vs r1, and p99 TTFT against ``SLO_TTFT``.  Replica
  workers overlap wherever the host has cores for them, so the scale
  column reads ~1 on a single-core host and approaches N on CI runners.
* ``serve_load/prefix/{cold,warm}`` — a shared-prefix workload without
  and with the :class:`~repro.serve.cache.PrefixStateCache`; the warm
  rung resumes prefill from cached fold-boundary state, so its derived
  fields show the reused-token count and the TTFT/QPS payoff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import benchmarks.common as common
from repro.models.lm import LMConfig, init_lm
from repro.serve.cache import PrefixStateCache
from repro.serve.engine import Request, ServeEngine, drive
from repro.serve.router import Router

# Discrete prompt-length mixes (tokens).  "short" fits one prefill chunk;
# "long" needs 3 chunks; "mixed" interleaves both, which is the case the
# chunked-prefill/decode interleave exists for.
MIXES = {
    "short": ([24], [1.0]),
    "long": ([96], [1.0]),
    "mixed": ([24, 96], [0.6, 0.4]),
}
RATES = [8.0, 32.0, 128.0]          # offered requests/s
CHUNK = 32
N_REQ = 16

# Replica axis: saturating workload (all requests offered at t=0) through
# a threaded router; QPS = completed / makespan.  The SLO the p99 TTFT is
# judged against — generous because smoke rungs run single-iteration on
# shared CI runners.
REPLICA_COUNTS = [1, 2]
REPLICA_REQS = 12
SLO_TTFT = 5.0

PREFIX_LEN = 64                     # tokens shared by the prefix workload


def _cfg():
    return LMConfig(
        name="serve-load", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
        gspn_proxy_dim=4, gspn_row_width=16, remat="none",
        compute_dtype=jnp.float32)


def _requests(rng, n, plens, probs, rate):
    lens = rng.choice(plens, size=n, p=probs)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, int(lens[i])),
                    max_new_tokens=8) for i in range(n)]
    return reqs, arrivals


def _warm(eng, plens=(24, 96)):
    """Compile every shape a rung will hit (one-shot prefill, 32-token
    chunk + tails, decode step) so measured TTFTs measure the engine,
    not XLA."""
    for plen in plens:
        eng.submit(Request(uid=0, prompt=np.arange(plen) % 256,
                           max_new_tokens=2))
        eng.run()
        eng.reset()


def _ttft_stats(results):
    ttfts = sorted(r.ttft for r in results)
    p50 = ttfts[len(ttfts) // 2]
    p99 = ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))]
    return ttfts, p50, p99


def _offered_load(eng):
    """The original single-engine (rate × mix) grid."""
    rates = RATES[:1] if common.SMOKE else RATES
    mixes = ["mixed"] if common.SMOKE else list(MIXES)
    n_req = 4 if common.SMOKE else N_REQ

    for mix in mixes:
        plens, probs = MIXES[mix]
        for rate in rates:
            rng = np.random.default_rng(0)
            reqs, arrivals = _requests(rng, n_req, plens, probs, rate)
            dt, handles = drive(eng, reqs, arrivals)
            res = [h.result() for h in handles]
            assert len(res) == n_req and all(h.done for h in handles)
            total = sum(len(r.tokens) for r in res)
            ttfts, p50, _ = _ttft_stats(res)
            itls = [t for r in res for t in r.itl]
            mean_ttft = sum(ttfts) / len(ttfts)
            common.emit(
                f"serve_load/{mix}/rate{rate:g}", mean_ttft * 1e6,
                f"tok_s={total/dt:.1f} p50_ttft_ms={p50*1e3:.2f} "
                f"max_ttft_ms={ttfts[-1]*1e3:.2f} "
                f"itl_ms={1e3*sum(itls)/max(len(itls),1):.2f} "
                f"qdepth_mean={eng.metrics['queue_depth_mean']:.1f} "
                f"qdepth_max={eng.metrics['queue_depth_max']} "
                f"chunks={eng.metrics['prefill_chunks']}")
            eng.reset()


def _replica_ladder(cfg, params):
    """QPS scaling in replica count: the same saturating mixed workload
    through a threaded router at N=1 and N=2 (DESIGN.md §15)."""
    n_req = 6 if common.SMOKE else REPLICA_REQS
    plens, probs = MIXES["mixed"]
    base_qps = None
    for n in REPLICA_COUNTS:
        engines = [ServeEngine(params, cfg, batch_size=4, max_len=160,
                               prefill_chunk=CHUNK, seed=i)
                   for i in range(n)]
        for e in engines:
            _warm(e)
        router = Router(engines, policy="ttft", slo_ttft=SLO_TTFT,
                        threaded=True)
        rng = np.random.default_rng(1)
        reqs, _ = _requests(rng, n_req, plens, probs, rate=1.0)
        arrivals = np.zeros(n_req)          # saturating: all offered at t=0
        router.start()
        dt, handles = drive(router, reqs, arrivals)
        router.stop()
        res = [h.result() for h in handles]
        assert all(h.done for h in handles)
        qps = n_req / dt
        if base_qps is None:
            base_qps = qps
        _, p50, p99 = _ttft_stats(res)
        placed = [sum(1 for h in handles if h.replica == r)
                  for r in range(n)]
        common.emit(
            f"serve_load/replicas/r{n}", (dt / n_req) * 1e6,
            f"qps={qps:.2f} qps_scale={qps/base_qps:.2f} "
            f"p50_ttft_ms={p50*1e3:.2f} p99_ttft_ms={p99*1e3:.2f} "
            f"slo_ms={SLO_TTFT*1e3:.0f} slo_ok={int(p99 <= SLO_TTFT)} "
            f"placement={'/'.join(map(str, placed))}")


def _prefix_ladder(cfg, params):
    """Prefix/state reuse: a shared-prefix workload cold vs warm.  The
    warm rung shares one PrefixStateCache, so every admission after the
    first resumes from the cached 64-token boundary state."""
    n_req = 4 if common.SMOKE else 8
    rng = np.random.default_rng(2)
    shared = rng.integers(0, 256, PREFIX_LEN)
    reqs = [Request(uid=i,
                    prompt=np.concatenate([shared,
                                           rng.integers(0, 256, 16)]),
                    max_new_tokens=8) for i in range(n_req)]
    for name, pfx in (("cold", None), ("warm", PrefixStateCache())):
        eng = ServeEngine(params, cfg, batch_size=4, max_len=160,
                          prefill_chunk=CHUNK, prefix_cache=pfx)
        _warm(eng, plens=(PREFIX_LEN + 16,))
        dt, handles = drive(eng, reqs, np.zeros(n_req))
        res = [h.result() for h in handles]
        _, p50, p99 = _ttft_stats(res)
        reused = sum(r.cached_tokens for r in res)
        chunks = eng.metrics["prefill_chunks"]
        common.emit(
            f"serve_load/prefix/{name}", (dt / n_req) * 1e6,
            f"qps={n_req/dt:.2f} p50_ttft_ms={p50*1e3:.2f} "
            f"p99_ttft_ms={p99*1e3:.2f} chunks={chunks} "
            f"tokens_reused={reused}")


def run():
    cfg = _cfg()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_size=4, max_len=160,
                      prefill_chunk=CHUNK, scheduler="fcfs")
    _warm(eng)
    _offered_load(eng)
    _replica_ladder(cfg, params)
    _prefix_ladder(cfg, params)


if __name__ == "__main__":
    run()
