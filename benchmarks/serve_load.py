"""Offered-load serving ladder (see benchmarks/README.md).

Plays an open-loop arrival process against the continuous-batching engine
(DESIGN.md §9) over a (rate × prompt-length-mix) grid and reports, per
rung, mean TTFT (the CSV us_per_call column) plus derived throughput,
p50/max TTFT, mean inter-token latency and max queue depth.  Prompt
lengths are drawn from a small discrete set so jit variants are bounded;
a warm-up pass through every (chunk, tail, decode) shape keeps compile
time out of the measured TTFTs.  ``--smoke`` runs one rung with 4
requests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import benchmarks.common as common
from repro.models.lm import LMConfig, init_lm
from repro.serve.engine import Request, ServeEngine, drive

# Discrete prompt-length mixes (tokens).  "short" fits one prefill chunk;
# "long" needs 3 chunks; "mixed" interleaves both, which is the case the
# chunked-prefill/decode interleave exists for.
MIXES = {
    "short": ([24], [1.0]),
    "long": ([96], [1.0]),
    "mixed": ([24, 96], [0.6, 0.4]),
}
RATES = [8.0, 32.0, 128.0]          # offered requests/s
CHUNK = 32
N_REQ = 16


def _cfg():
    return LMConfig(
        name="serve-load", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
        gspn_proxy_dim=4, gspn_row_width=16, remat="none",
        compute_dtype=jnp.float32)


def _requests(rng, n, plens, probs, rate):
    lens = rng.choice(plens, size=n, p=probs)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    reqs = [Request(uid=i, prompt=rng.integers(0, 256, int(lens[i])),
                    max_new_tokens=8) for i in range(n)]
    return reqs, arrivals


def run():
    cfg = _cfg()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_size=4, max_len=160,
                      prefill_chunk=CHUNK, scheduler="fcfs")

    rates = RATES[:1] if common.SMOKE else RATES
    mixes = ["mixed"] if common.SMOKE else list(MIXES)
    n_req = 4 if common.SMOKE else N_REQ

    # Warm-up: compile every shape the ladder will hit (24-token one-shot
    # prefill, 32-token chunk, decode step) so rung TTFTs measure the
    # engine, not XLA.
    for plen in (24, 96):
        eng.submit(Request(uid=0, prompt=np.arange(plen) % 256,
                           max_new_tokens=2))
        eng.run()
        eng.reset()

    for mix in mixes:
        plens, probs = MIXES[mix]
        for rate in rates:
            rng = np.random.default_rng(0)
            reqs, arrivals = _requests(rng, n_req, plens, probs, rate)
            dt = drive(eng, reqs, arrivals)
            res = eng.results
            assert len(res) == n_req
            total = sum(len(r.tokens) for r in res.values())
            ttfts = sorted(r.ttft for r in res.values())
            itls = [t for r in res.values() for t in r.itl]
            mean_ttft = sum(ttfts) / len(ttfts)
            common.emit(
                f"serve_load/{mix}/rate{rate:g}", mean_ttft * 1e6,
                f"tok_s={total/dt:.1f} p50_ttft_ms={ttfts[len(ttfts)//2]*1e3:.2f} "
                f"max_ttft_ms={ttfts[-1]*1e3:.2f} "
                f"itl_ms={1e3*sum(itls)/max(len(itls),1):.2f} "
                f"qdepth_mean={eng.metrics['queue_depth_mean']:.1f} "
                f"qdepth_max={eng.metrics['queue_depth_max']} "
                f"chunks={eng.metrics['prefill_chunks']}")
            eng.reset()


if __name__ == "__main__":
    run()
