"""Multi-direction dispatch ladder — per-direction vs pair-fused vs
quad-batched (DESIGN.md §2).

The paper's §4.3 point is that directional passes should share one launch,
not pay per-direction dispatch + flipped-copy overhead.  On CPU/XLA we
reproduce the ladder structurally (like fig3) and additionally *prove* the
launch counts of the Pallas path by counting ``pallas_call`` invocations:

  per_direction   four sequential scans over flipped/transposed copies
                  (the GSPN-1 shape of the dispatch; 4 launches)
  pair_fused      opposite pairs fused, reverse traversal by index
                  arithmetic, one transpose at the dispatch boundary
                  (2 launches, no flipped copies)
  quad_batched    all four directions batched into ONE scan call by
                  stacking the oriented operands along G (1 launch;
                  square grids)
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from benchmarks.common import emit, time_fn
from repro.core import gspn as G
from repro.kernels import gspn_multidir as MK
from repro.kernels.ops import gspn_scan

# Square so the quad-batched rung applies (CPU-scaled).
B, CP, H, W = 2, 4, 192, 192


def _inputs(b, cp, h, w, seed=0):
    g = b * cp
    nd = len(G.DIRECTIONS)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w))
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (nd, g, h, w)))
    logits = jax.random.normal(ks[2], (nd, b, h, w, 3))
    wls, wcs, wrs = [], [], []
    for i, d in enumerate(G.DIRECTIONS):
        wl, wc, wr = G._normalize_taps_oriented(logits[i], d, "softmax")
        wls.append(wl)
        wcs.append(wc)
        wrs.append(wr)
    return x, jnp.stack(wls), jnp.stack(wcs), jnp.stack(wrs), lam


def _per_direction(x, wl, wc, wr, lam):
    return jnp.stack([
        G.directional_scan(x, wl[i], wc[i], wr[i], lam[i], d, impl="xla")
        for i, d in enumerate(G.DIRECTIONS)])


def _pair_fused(x, wl, wc, wr, lam):
    return G.directional_scan(x, wl, wc, wr, lam, G.DIRECTIONS, impl="xla")


def _quad_batched(x, wl, wc, wr, lam):
    """One scan call: directions become batched data parallelism along G
    (needs oriented operand copies — the traffic/launch trade-off the
    fused Pallas quad kernel removes)."""
    g = x.shape[0]
    cat = lambda parts: jnp.concatenate(parts, axis=0)
    xs = cat([G._to_canonical(x, d) for d in G.DIRECTIONS])
    ws = [cat([G._to_canonical(w[i], d) for i, d in enumerate(G.DIRECTIONS)])
          for w in (wl, wc, wr)]
    ls = cat([G._to_canonical(lam[i], d)
              for i, d in enumerate(G.DIRECTIONS)])
    h = gspn_scan(xs, ws[0], ws[1], ws[2], ls, impl="xla")
    return jnp.stack([G._from_canonical(h[i * g:(i + 1) * g], d)
                      for i, d in enumerate(G.DIRECTIONS)])


def _count_pallas_launches(fn):
    n = [0]
    real = pl.pallas_call

    def wrap(*a, **k):
        n[0] += 1
        return real(*a, **k)

    pl.pallas_call = wrap
    try:
        jax.block_until_ready(fn())
    finally:
        pl.pallas_call = real
    return n[0]


def run():
    x, wl, wc, wr, lam = _inputs(B, CP, H, W)

    t0 = time_fn(jax.jit(_per_direction), x, wl, wc, wr, lam)
    emit("multidir/per_direction_ms", t0 * 1e6,
         "launches=4;cum_speedup=1.00")

    t1 = time_fn(jax.jit(_pair_fused), x, wl, wc, wr, lam)
    emit("multidir/pair_fused_ms", t1 * 1e6,
         f"launches=2;cum_speedup={t0/t1:.2f}")

    t2 = time_fn(jax.jit(_quad_batched), x, wl, wc, wr, lam)
    emit("multidir/quad_batched_ms", t2 * 1e6,
         f"launches=1;cum_speedup={t0/t2:.2f}")

    # Launch-count proof on the actual Pallas path (tiny shape, interpret).
    xt, wlt, wct, wrt, lamt = _inputs(1, 2, 8, 8, seed=1)
    n_per = _count_pallas_launches(lambda: jnp.stack([
        G.directional_scan(xt, wlt[i], wct[i], wrt[i], lamt[i], d,
                           impl="multidir")
        for i, d in enumerate(G.DIRECTIONS)]))
    n_pair = _count_pallas_launches(lambda: G.directional_scan(
        xt, wlt, wct, wrt, lamt, G.DIRECTIONS, impl="multidir"))
    T = lambda a: jnp.swapaxes(a, -1, -2)
    taps4 = {k: jnp.stack([v[0], v[1], T(v[2]), T(v[3])])
             for k, v in (("wl", wlt), ("wc", wct), ("wr", wrt))}
    lam4 = jnp.stack([lamt[0], lamt[1], T(lamt[2]), T(lamt[3])])
    n_quad = _count_pallas_launches(lambda: MK.gspn_scan_quad_pallas(
        xt, taps4, lam4, channels_per_weight=2, row_tile=4))
    emit("multidir/pallas_launches", 0.0,
         f"per_direction={n_per};pair_fused={n_pair};quad={n_quad}")
    assert n_pair <= 2 and n_quad == 1, (n_per, n_pair, n_quad)
    return {"pair_speedup": t0 / t1, "launches": (n_per, n_pair, n_quad)}


if __name__ == "__main__":
    run()
