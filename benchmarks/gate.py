"""Benchmark regression gate — compare a ``benchmarks.run --json`` report
against a committed baseline (DESIGN.md §11).

    PYTHONPATH=src python -m benchmarks.gate \
        --baseline benchmarks/BENCH_baseline.json \
        --current bench-smoke.json [--tolerance 1.8] [--min-us 100]

Per rung present in BOTH reports (and above the ``--min-us`` noise floor
on at least one side) the gate computes ``ratio = current / baseline`` and

* FAILS (exit 1) when ``ratio > tolerance``   — a regression;
* notes an improvement when ``ratio < 1 / tolerance``;
* passes otherwise.

Rungs missing from either side are WARNINGS, never failures: a new
benchmark must be able to land before its baseline exists, and a renamed
or retired rung must not wedge CI — re-baseline to start gating it.

On top of the ratio band the gate enforces one ABSOLUTE ordering inside
the current report (DESIGN.md §12): at every dtype-ladder resolution the
bf16 pallas forward rung must be strictly faster than the f32 one
(``dtype/bf16/pallas/{res}/fwd``  <  ``dtype/f32/pallas/{res}/fwd``).
A violation fails the gate — and also blocks ``--update``, so a report
with the bf16 cliff re-opened can never become the baseline.  Because
the check is a within-report comparison, uniformly scaling all timings
(slower runner, injected-slowdown self-test) cannot trip it.

Re-baselining (after an intentional perf change or a runner swap)::

    PYTHONPATH=src python -m benchmarks.run --smoke --only multidir,dtype \
        --json current.json
    PYTHONPATH=src python -m benchmarks.gate \
        --baseline benchmarks/BENCH_baseline.json --current current.json \
        --update        # overwrites the baseline with the current report
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

# A 2x injected slowdown must fail under the default band (the gate's own
# acceptance test), while single-iteration smoke timings keep headroom;
# the --min-us floor keeps sub-noise rungs out of the comparison.
DEFAULT_TOLERANCE = 1.8
DEFAULT_MIN_US = 100.0


@dataclasses.dataclass
class GateResult:
    regressions: list      # (name, base_us, cur_us, ratio)
    improvements: list     # (name, base_us, cur_us, ratio)
    warnings: list         # human-readable strings
    checked: int           # rungs actually compared

    @property
    def ok(self) -> bool:
        return not self.regressions


# Report schemas this gate can read.  Schema 2 added an optional per-row
# ``stats`` dict (p10/p50/p90 µs); the comparison only consumes
# name/us_per_call, so schema-1 baselines gate schema-2 reports unchanged.
SUPPORTED_SCHEMAS = (1, 2)


class UnsupportedSchemaError(ValueError):
    """A structurally valid report from a NEWER gate than this one.

    Raised only when the schema is an int above max(SUPPORTED_SCHEMAS) —
    i.e. the report was written by a future benchmarks.run.  main()
    catches this and warn-skips (exit 0) instead of wedging CI on the
    first PR that bumps the report schema: the old gate binary cannot
    gate what it cannot parse, and a skipped gate is a visible warning
    while a crashed gate blocks every unrelated PR.  Garbage schemas
    (non-int, or unknown values BELOW the supported range) still raise
    plain ValueError — those are corrupt reports, not version skew.
    """


def load_report(path) -> dict:
    """Read and validate one --json report (schema + row shape)."""
    payload = json.loads(pathlib.Path(path).read_text())
    if not isinstance(payload, dict) or "rows" not in payload:
        raise ValueError(f"{path}: not a benchmarks.run --json report")
    schema = payload.get("schema", 1)
    if schema not in SUPPORTED_SCHEMAS:
        if isinstance(schema, int) and not isinstance(schema, bool) \
                and schema > max(SUPPORTED_SCHEMAS):
            raise UnsupportedSchemaError(
                f"{path}: report schema {schema} is newer than this gate "
                f"supports (max {max(SUPPORTED_SCHEMAS)})")
        raise ValueError(f"{path}: unsupported report schema {schema!r}")
    for row in payload["rows"]:
        if "name" not in row or "us_per_call" not in row:
            raise ValueError(f"{path}: malformed row {row!r}")
    return payload


def index_rows(payload: dict) -> dict:
    """name -> us_per_call.  Duplicate names keep the LAST row (ladders
    re-emit a rung when re-run; the final measurement wins)."""
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def dtype_ordering_violations(payload: dict) -> list:
    """Within-report check: bf16 pallas fwd must STRICTLY beat f32 at
    every dtype-ladder resolution (the pipeline_depth payoff, DESIGN.md
    §12).  Returns human-readable violation strings naming the offending
    rung and dtype; resolutions where either side is absent are skipped
    (the ratio gate's missing-rung warnings already cover those)."""
    rows = index_rows(payload)
    prefix_f32, prefix_bf16 = "dtype/f32/pallas/", "dtype/bf16/pallas/"
    violations = []
    for name in sorted(rows):
        if not (name.startswith(prefix_f32) and name.endswith("/fwd")):
            continue
        res = name[len(prefix_f32):-len("/fwd")]
        peer = f"{prefix_bf16}{res}/fwd"
        if peer not in rows:
            continue
        f32_us, bf16_us = rows[name], rows[peer]
        if bf16_us >= f32_us:
            violations.append(
                f"dtype ordering violated at rung {res}: bf16 pallas fwd "
                f"{bf16_us:.1f}us >= f32 {f32_us:.1f}us")
    return violations


def compare(baseline: dict, current: dict, *,
            tolerance: float = DEFAULT_TOLERANCE,
            min_us: float = DEFAULT_MIN_US) -> GateResult:
    """Pure comparison — both args are loaded report payloads."""
    base, cur = index_rows(baseline), index_rows(current)
    warnings = []
    for name in sorted(set(base) - set(cur)):
        warnings.append(f"baseline rung missing from current run: {name}")
    for name in sorted(set(cur) - set(base)):
        warnings.append(f"no baseline entry for {name} "
                        f"(new rung — re-baseline to start gating it)")

    regressions, improvements, checked = [], [], 0
    for name in sorted(set(base) & set(cur)):
        b_us, c_us = base[name], cur[name]
        if max(b_us, c_us) < min_us:
            continue                        # below the noise floor
        checked += 1
        ratio = c_us / max(b_us, 1e-9)
        if ratio > tolerance:
            regressions.append((name, b_us, c_us, ratio))
        elif ratio < 1.0 / tolerance:
            improvements.append((name, b_us, c_us, ratio))
    return GateResult(regressions, improvements, warnings, checked)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.gate")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="max allowed current/baseline slowdown ratio")
    ap.add_argument("--min-us", type=float, default=DEFAULT_MIN_US,
                    help="skip rungs below this on BOTH sides (noise)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current report "
                         "instead of gating (re-baselining)")
    args = ap.parse_args(argv)

    try:
        current = load_report(args.current)
    except UnsupportedSchemaError as e:
        # Forward-compat: a report from a newer benchmarks.run must not
        # wedge CI (and must not be enshrined as a baseline either).
        print(f"[gate] WARNING: {e} — skipping gate")
        return 0
    ordering = dtype_ordering_violations(current)
    for v in ordering:
        print(f"[gate] ORDERING: {v}")

    if args.update:
        if ordering:
            print(f"[gate] FAIL: refusing to re-baseline — "
                  f"{len(ordering)} dtype ordering violations in "
                  f"{args.current}")
            return 1
        pathlib.Path(args.baseline).write_text(
            json.dumps(current, indent=1) + "\n")
        print(f"[gate] re-baselined {args.baseline} from {args.current} "
              f"({len(current['rows'])} rows)")
        return 0

    try:
        baseline = load_report(args.baseline)
    except UnsupportedSchemaError as e:
        print(f"[gate] WARNING: {e} — skipping gate")
        return 0
    res = compare(baseline, current, tolerance=args.tolerance,
                  min_us=args.min_us)
    for w in res.warnings:
        print(f"[gate] WARNING: {w}")
    for name, b, c, r in res.improvements:
        print(f"[gate] improved: {name}  {b:.1f}us -> {c:.1f}us "
              f"({r:.2f}x)")
    for name, b, c, r in res.regressions:
        print(f"[gate] REGRESSION: {name}  {b:.1f}us -> {c:.1f}us "
              f"({r:.2f}x > {args.tolerance:.2f}x)")
    failed = bool(res.regressions) or bool(ordering)
    verdict = "FAIL" if failed else "ok"
    print(f"[gate] {verdict}: {res.checked} rungs compared, "
          f"{len(res.regressions)} regressions, "
          f"{len(res.improvements)} improvements, "
          f"{len(ordering)} ordering violations, "
          f"{len(res.warnings)} warnings "
          f"(tolerance {args.tolerance:.2f}x, floor {args.min_us:.0f}us)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
