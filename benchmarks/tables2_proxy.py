"""Table S2 — compressive-proxy-dimension ablation.

Paper: C_proxy 2→32 trades ≤0.2 % accuracy for 1.4× throughput on
GSPN-2-Tiny.  We reproduce the computational side: block forward time and
scan work vs C_proxy on a GSPN-2 attention block (accuracy requires
ImageNet)."""

import dataclasses

import jax

from benchmarks.common import emit, time_fn
from repro.core import gspn as G


def run():
    base = G.GSPNAttentionConfig(dim=96, proxy_dim=2, impl="xla")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 56, 56, 96))
    t_ref = None
    for cp in (2, 4, 8, 16, 32):
        cfg = dataclasses.replace(base, proxy_dim=cp)
        params = G.init_gspn_attention(jax.random.PRNGKey(1), cfg)
        fn = jax.jit(lambda p, x, c=cfg: G.apply_gspn_attention(p, x, c))
        t = time_fn(fn, params, x)
        if t_ref is None:
            t_ref = t
        emit(f"tables2/cproxy_{cp}", t * 1e6,
             f"rel_throughput={t_ref/t:.2f};"
             f"scan_params={G.gspn_attention_param_count(cfg)};"
             f"paper_acc={'83.0' if cp <= 8 else '82.9' if cp==16 else '82.8'}")


if __name__ == "__main__":
    run()
