"""Table 2 — ImageNet efficiency columns (params / MACs).

Accuracy cannot be reproduced without ImageNet + accelerators; the
efficiency columns CAN: parameter counts and MACs of GSPN-2-T/S/B at 224²
against the paper's numbers (24M/4.2G, 50M/9.2G, 89M/14.2G), plus the
GSPN-1-mode comparison (paper: GSPN-T = 30M/5.3G)."""

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.gspn2_vision import GSPN2_B, GSPN2_S, GSPN2_T, GSPN1_T
from repro.models.vision import init_vision, vision_macs

PAPER = {
    "gspn2-t": (24e6, 4.2e9), "gspn2-s": (50e6, 9.2e9),
    "gspn2-b": (89e6, 14.2e9), "gspn1-t": (30e6, 5.3e9),
}


def run():
    for cfg in (GSPN2_T, GSPN2_S, GSPN2_B, GSPN1_T):
        shapes = jax.eval_shape(lambda k, c=cfg: init_vision(k, c),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(shapes))
        macs = vision_macs(cfg)
        p_n, p_m = PAPER[cfg.name]
        emit(f"table2/{cfg.name}", 0.0,
             f"params={n/1e6:.1f}M(paper {p_n/1e6:.0f}M);"
             f"macs={macs/1e9:.2f}G(paper {p_m/1e9:.1f}G)")


if __name__ == "__main__":
    run()
