"""Figure 4 / S2 — runtime scaling with image size, batch and channels.

Paper: GSPN-2's advantage over GSPN-1 grows with resolution (36.8× fwd at
1024²) and stays 2–4×+ at large batch/channel counts.  We measure the
fused-vs-per-step ratio over the same axes (CPU-scaled sizes) and fit the
scaling exponent of the fused scan (expect ≈ linear in pixel count — the
O(√N) sequential claim is about *steps*, total work stays O(N))."""

import math

import jax

from benchmarks.common import emit, make_gspn_inputs, time_fn
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan


def run():
    fused = jax.jit(lambda *a: gspn_scan(*a, impl="xla"))

    # axis 1: image size
    sizes = [64, 128, 256]
    ts = []
    for s in sizes:
        x, wl, wc, wr, lam = make_gspn_inputs(2, 8, s, s)
        tf = time_fn(fused, x, wl, wc, wr, lam)
        tp = time_fn(lambda: R.gspn_scan_per_step(
            x, wl, wc, wr, lam, block=True), iters=1)
        ts.append(tf)
        emit(f"fig4/size_{s}", tf * 1e6, f"speedup_vs_gspn1={tp/tf:.1f}")
    exp = math.log(ts[-1] / ts[0]) / math.log((sizes[-1] / sizes[0]) ** 2)
    emit("fig4/size_scaling_exponent", 0.0,
         f"time~pixels^{exp:.2f};expect~1.0")

    # axis 2: batch
    for b in (1, 4, 16):
        x, wl, wc, wr, lam = make_gspn_inputs(b, 8, 128, 128)
        tf = time_fn(fused, x, wl, wc, wr, lam)
        emit(f"fig4/batch_{b}", tf * 1e6, "")

    # axis 3: channels (per-channel GSPN-1 weights vs shared GSPN-2)
    for c in (8, 32, 128):
        x1, wl1, wc1, wr1, lam1 = make_gspn_inputs(1, c, 128, 128,
                                                   channel_shared=False)
        x2, wl2, wc2, wr2, lam2 = make_gspn_inputs(1, c, 128, 128,
                                                   channel_shared=True)
        t1 = time_fn(fused, x1, wl1, wc1, wr1, lam1)
        t2 = time_fn(fused, x2, wl2, wc2, wr2, lam2)
        emit(f"fig4/channels_{c}", t2 * 1e6,
             f"shared_vs_perchannel_speedup={t1/t2:.2f}")


if __name__ == "__main__":
    run()
