"""Mixed-precision ladder — f32 vs bf16 across impl × resolution
(DESIGN.md §10), plus the serve-state byte ledger.

Three things are measured per (dtype, impl, resolution) rung:

* wall time of the fused forward scan (``us_per_call``) and, per dtype,
  of one fwd+bwd step through the custom-vjp entry point — on TPU the
  bf16 rungs stream half the HBM bytes and the tuner doubles the row
  tile (on CPU/interpret the timing is structural, like fig3); each
  pallas rung also reports the resolved ``(row_tile, pipeline_depth)``
  plan, and the gate's ordering check (``gate.py``) enforces that bf16
  pallas fwd strictly beats f32 at every resolution (DESIGN.md §12);
* the bf16 rel-L2 error against the f32 oracle for the same inputs —
  the number the §10 error-budget table pins (≤ 1e-2);
* the analytic streamed bytes (benchmarks.common.scan_bytes) so the
  traffic halving is visible even where timings are noisy.

The final rung builds a small served model's StateCachePool at f32 and
bf16 and reports the byte ratio — the ``--state-dtype bf16`` payoff: the
pool is what bounds decode batch at fixed memory, and the ratio is
asserted ≥ 1.9× (the integer length/pos leaves keep it just under 2×).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import benchmarks.common as common
from benchmarks.common import emit, make_gspn_inputs, scan_bytes, time_fn
from repro.kernels import ScanSpec, autotune
from repro.kernels.ops import gspn_scan
from repro.kernels.tuning import pick_row_tile_for_policy
from repro.models.lm import LMConfig
from repro.serve.cache import StateCachePool

RESOLUTIONS = [(128, 128), (256, 256)]
IMPLS = ["xla", "pallas"]
DTYPES = [("f32", jnp.float32), ("bf16", jnp.bfloat16)]
B, CP = 2, 4

# Byte-ratio floor the serve-state rung must clear (ISSUE 4 acceptance):
# float leaves halve exactly; int32 lengths/positions keep it under 2.
MIN_STATE_BYTE_RATIO = 1.9


def _serve_cfg():
    return LMConfig(
        name="dtype-ladder", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        prelude=(("gspn", 1),), unit=(("attn", 1),), n_units=1,
        gspn_proxy_dim=4, gspn_row_width=16, remat="none")


def _step(x, wl, wc, wr, lam, impl):
    def loss(x, wl, wc, wr, lam):
        return jnp.sum(
            gspn_scan(x, wl, wc, wr, lam, impl=impl).astype(jnp.float32))

    return jax.grad(loss, argnums=(0, 4))(x, wl, wc, wr, lam)


def run():
    resolutions = RESOLUTIONS[:1] if common.SMOKE else RESOLUTIONS
    for h, w in resolutions:
        inputs32 = make_gspn_inputs(B, CP, h, w)
        ref = None
        for dname, dtype in DTYPES:
            inputs = tuple(a.astype(dtype) for a in inputs32)
            for impl in IMPLS:
                fwd = jax.jit(lambda *a, impl=impl: gspn_scan(*a, impl=impl))
                # The pallas fwd rungs feed the gate's STRICT bf16<f32
                # ordering check — keep a median-of-5 even under --smoke
                # so one scheduler hiccup cannot flip the comparison.
                t_f = time_fn(fwd, *inputs, iters=5,
                              min_iters=5 if impl == "pallas" else 1)
                out = np.asarray(fwd(*inputs), np.float32)
                if dname == "f32" and impl == "xla":
                    ref = out
                err = (np.linalg.norm(out - ref)
                       / max(np.linalg.norm(ref), 1e-30))
                nbytes = jnp.dtype(dtype).itemsize
                # Byte widths follow the named precision policy (DESIGN.md
                # §10) instead of a hand-passed constant, and the emitted
                # tile is what the launch actually used: the tuner's
                # cached choice with the policy heuristic as fallback
                # (DESIGN.md §11).  The spec legs are derived from the
                # operands (not hand-written) so they track the launch's
                # own resolution inside gspn_scan_fwd_pallas (§14).
                x_in, wl_in = inputs[0], inputs[1]
                cpw = x_in.shape[0] // wl_in.shape[0]
                plan = autotune.plan_for_spec(
                    ScanSpec(direction="fwd", impl="pallas",
                             channels_per_weight=cpw,
                             stream_dtype=str(jnp.dtype(dtype))),
                    h, w, c=x_in.shape[0])
                heur = pick_row_tile_for_policy(
                    h, w, dname, cap=autotune.DEFAULT_CAP,
                    pipeline_depth=plan.pipeline_depth).row_tile
                mb = scan_bytes(B, CP, h, w, dtype_bytes=nbytes) / 2 ** 20
                emit(f"dtype/{dname}/{impl}/{h}x{w}/fwd", t_f * 1e6,
                     f"rel_err={err:.2e};row_tile={plan.row_tile};"
                     f"pipeline_depth={plan.pipeline_depth};heur={heur};"
                     f"stream_mb={mb:.1f}")
            step = jax.jit(lambda *a: _step(*a, impl="xla"))
            t_s = time_fn(step, *inputs)
            emit(f"dtype/{dname}/xla/{h}x{w}/step", t_s * 1e6, "")

    # Serve-state byte ledger: the ≥1.9× reduction the acceptance pins.
    # The f32 rung pins an explicitly-f32 pool (the full-f32 policy; the
    # repo default already kept KV pages in cfg.compute_dtype, but GSPN /
    # SSM propagation state was f32) against --state-dtype bf16.
    cfg = _serve_cfg()
    pool32 = StateCachePool(cfg, n_slots=4, max_len=256,
                            state_dtype=jnp.float32)
    pool16 = StateCachePool(cfg, n_slots=4, max_len=256,
                            state_dtype=jnp.bfloat16)
    ratio = pool32.nbytes / pool16.nbytes
    emit("dtype/serve_state_bytes", 0.0,
         f"f32={pool32.nbytes};bf16={pool16.nbytes};ratio={ratio:.3f}")
    assert ratio >= MIN_STATE_BYTE_RATIO, (
        f"serve-state byte reduction {ratio:.3f}x < {MIN_STATE_BYTE_RATIO}x")
    return {"state_byte_ratio": ratio}


if __name__ == "__main__":
    run()
