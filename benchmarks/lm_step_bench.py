"""Framework overhead: per-arch reduced-config train-step throughput on
CPU (tokens/s) — one row per assigned architecture."""


import jax

from benchmarks.common import emit, time_fn
from repro.configs.all_archs import ASSIGNED, EXTRAS
from repro.configs.base import get_arch
from repro.models.lm import init_lm, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def run():
    b, s = 4, 64
    for arch in ASSIGNED + EXTRAS:
        cfg = get_arch(arch).reduced()
        params = init_lm(jax.random.PRNGKey(0), cfg)
        ocfg = AdamWConfig()
        opt = adamw_init(ocfg, params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (b, s // 2, cfg.d_model))
        if cfg.family == "audio":
            batch["enc_frames"] = jax.random.normal(
                jax.random.PRNGKey(3), (b, cfg.enc_len, cfg.d_model))

        @jax.jit
        def step(params, opt, batch):
            (loss, _), g = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
            params, opt, _ = adamw_update(ocfg, g, opt, params)
            return params, opt, loss

        t = time_fn(lambda: step(params, opt, batch), iters=2)
        emit(f"lm_step/{arch}", t * 1e6,
             f"tokens_per_s={b*s/t:.0f}")


if __name__ == "__main__":
    run()
