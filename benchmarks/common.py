"""Benchmark utilities: timing, CSV emission, shared GSPN inputs."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import gspn as G

ROWS = []

# Set by ``benchmarks.run --smoke``: every rung runs exactly one timed
# iteration so a full bench sweep can gate a PR in seconds.  Timings are
# then indicative only — the CSV still exercises every code path.
SMOKE = False


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def time_fn(fn, *args, iters: int = 3, warmup: int = 1,
            min_iters: int = 1) -> float:
    """Median wall time (seconds) of fn(*args) with block_until_ready.

    ``min_iters`` floors the iteration count under --smoke: rungs whose
    RELATIVE timing is gated (the dtype-ordering check, DESIGN.md §12)
    ask for a few iterations even in smoke mode so a single scheduler
    hiccup cannot flip the comparison."""
    if SMOKE:
        iters, warmup = max(1, min_iters), min(warmup, 1)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def make_gspn_inputs(batch: int, channels: int, h: int, w: int,
                     channel_shared: bool = True, seed: int = 0,
                     dtype=jnp.float32):
    """Inputs for the canonical scan: x/lam (B*C, H, W); taps (Gw, H, W)."""
    g = batch * channels
    gw = batch if channel_shared else g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w), dtype)
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w))).astype(dtype)
    wl, wc, wr = G.normalize_taps(
        jax.random.normal(ks[2], (gw, h, w, 3)))
    return x, wl.astype(dtype), wc.astype(dtype), wr.astype(dtype), lam


def scan_bytes(batch, channels, h, w, channel_shared=True, dtype_bytes=4):
    """Analytic HBM traffic of one fused directional scan: read x, λ, taps,
    write h (the carry stays on-chip — the GSPN-2 design point)."""
    g = batch * channels
    gw = batch if channel_shared else g
    per_plane = h * w * dtype_bytes
    return (2 * g + 3 * gw + g) * per_plane     # x, lam reads + 3 taps + h

def scan_flops(batch, channels, h, w):
    """4 FMAs per element per directional pass."""
    return batch * channels * h * w * 8
