"""Benchmark utilities: timing, CSV emission, shared GSPN inputs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import gspn as G

ROWS = []

# Per-row distribution stats, parallel to ROWS (schema-2 payloads,
# DESIGN.md §13): ``time_fn`` records its iteration spread here via
# LAST_STATS; ``emit`` consumes-and-clears it into ROW_STATS so each CSV
# row carries the p10/p50/p90 of the timing run that produced it (None
# for derived rows emitted without a fresh time_fn call).
ROW_STATS = []
LAST_STATS = None

# Set by ``benchmarks.run --smoke``: every rung runs exactly one timed
# iteration so a full bench sweep can gate a PR in seconds.  Timings are
# then indicative only — the CSV still exercises every code path.
SMOKE = False


def emit(name: str, us_per_call: float, derived: str = ""):
    global LAST_STATS
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    ROW_STATS.append(LAST_STATS)
    LAST_STATS = None
    print(line, flush=True)


def _percentile(sorted_times, q: float) -> float:
    """Nearest-rank percentile of an already-sorted list."""
    i = min(len(sorted_times) - 1, int(round(q * (len(sorted_times) - 1))))
    return sorted_times[i]


def time_fn(fn, *args, iters: int = 3, warmup: int = 1,
            min_iters: int = 1) -> float:
    """Median wall time (seconds) of fn(*args) with block_until_ready.

    ``min_iters`` floors the iteration count under --smoke: rungs whose
    RELATIVE timing is gated (the dtype-ordering check, DESIGN.md §12)
    ask for a few iterations even in smoke mode so a single scheduler
    hiccup cannot flip the comparison.

    Side effect: records the iteration spread (p10/p50/p90 µs) into
    ``LAST_STATS`` for the next ``emit`` to attach to its row (schema-2
    --json payloads)."""
    global LAST_STATS
    if SMOKE:
        iters, warmup = max(1, min_iters), min(warmup, 1)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = obs.monotonic()
        jax.block_until_ready(fn(*args))
        times.append(obs.monotonic() - t0)
    times.sort()
    LAST_STATS = {"iters": len(times),
                  "p10_us": round(_percentile(times, 0.1) * 1e6, 3),
                  "p50_us": round(_percentile(times, 0.5) * 1e6, 3),
                  "p90_us": round(_percentile(times, 0.9) * 1e6, 3)}
    return times[len(times) // 2]


def make_gspn_inputs(batch: int, channels: int, h: int, w: int,
                     channel_shared: bool = True, seed: int = 0,
                     dtype=jnp.float32):
    """Inputs for the canonical scan: x/lam (B*C, H, W); taps (Gw, H, W)."""
    g = batch * channels
    gw = batch if channel_shared else g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, h, w), dtype)
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (g, h, w))).astype(dtype)
    wl, wc, wr = G.normalize_taps(
        jax.random.normal(ks[2], (gw, h, w, 3)))
    return x, wl.astype(dtype), wc.astype(dtype), wr.astype(dtype), lam


def scan_bytes(batch, channels, h, w, channel_shared=True, dtype_bytes=4):
    """Analytic HBM traffic of one fused directional scan: read x, λ, taps,
    write h (the carry stays on-chip — the GSPN-2 design point)."""
    g = batch * channels
    gw = batch if channel_shared else g
    per_plane = h * w * dtype_bytes
    return (2 * g + 3 * gw + g) * per_plane     # x, lam reads + 3 taps + h

def scan_flops(batch, channels, h, w):
    """4 FMAs per element per directional pass."""
    return batch * channels * h * w * 8
