"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,table1,...] [--smoke]

``--smoke`` runs every rung with a single timed iteration — a cheap CI
gate that exercises all benchmark code paths without meaningful timings.
"""

import argparse
import sys
import traceback

MODULES = [
    ("fig3", "benchmarks.fig3_kernel_ladder"),
    ("multidir", "benchmarks.multidir_ladder"),
    ("sp", "benchmarks.sp_scaling"),
    ("table1", "benchmarks.table1_throughput"),
    ("fig4", "benchmarks.fig4_scaling"),
    ("table2", "benchmarks.table2_imagenet"),
    ("tables2", "benchmarks.tables2_proxy"),
    ("lm_step", "benchmarks.lm_step_bench"),
    ("serve_load", "benchmarks.serve_load"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="1 timed iteration per rung (CI smoke gate)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        import benchmarks.common as common
        common.SMOKE = True

    print("name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            import importlib
            mod = importlib.import_module(modname)
            mod.run()
        except Exception:  # noqa: BLE001
            failed.append(key)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
