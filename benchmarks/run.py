"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,table1,...] \
        [--smoke] [--json out.json]

``--smoke`` runs every rung with a single timed iteration — a cheap CI
gate that exercises all benchmark code paths without meaningful timings.
``--json`` additionally writes the emitted rows (plus smoke/only
metadata) to a file — the artifact CI uploads per push so the perf
trajectory survives across PRs.
"""

import argparse
import json
import sys
import traceback

# --json payload schema version; benchmarks/gate.py validates it before
# comparing runs, so bump it when the row shape changes.  Schema 2 adds
# an optional per-row ``stats`` dict (p10/p50/p90 µs of the timing run —
# DESIGN.md §13); the gate reads schema 1 and 2 (a schema-1 row is a
# schema-2 row with stats=None).
JSON_SCHEMA = 2

MODULES = [
    ("fig3", "benchmarks.fig3_kernel_ladder"),
    ("multidir", "benchmarks.multidir_ladder"),
    ("sp", "benchmarks.sp_scaling"),
    ("dtype", "benchmarks.dtype_ladder"),
    ("table1", "benchmarks.table1_throughput"),
    ("fig4", "benchmarks.fig4_scaling"),
    ("table2", "benchmarks.table2_imagenet"),
    ("tables2", "benchmarks.tables2_proxy"),
    ("lm_step", "benchmarks.lm_step_bench"),
    ("serve_load", "benchmarks.serve_load"),
]


def build_payload(rows, *, smoke: bool, only=None, failed=(),
                  row_stats=None) -> dict:
    """The --json artifact: parsed CSV rows + run metadata.  One function
    builds it (and the gate's loader validates it) so the schema cannot
    drift between writer and reader.  ``row_stats`` (parallel to rows)
    carries each row's p10/p50/p90 timing spread; missing/short lists
    pad with None."""
    row_stats = list(row_stats or [])
    parsed = []
    for i, line in enumerate(rows):
        name, us, derived = line.split(",", 2)
        parsed.append({"name": name, "us_per_call": float(us),
                       "derived": derived,
                       "stats": row_stats[i] if i < len(row_stats) else None})
    return {"schema": JSON_SCHEMA, "smoke": smoke,
            "only": sorted(only or []), "failed": list(failed),
            "rows": parsed}


def main() -> None:
    from repro.launch import args as largs
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="1 timed iteration per rung (CI smoke gate)")
    ap.add_argument("--json", default="",
                    help="also write rows to this JSON file (CI artifact)")
    largs.add_observability_args(ap)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    from repro import obs
    import benchmarks.common as common
    if args.smoke:
        common.SMOKE = True
    largs.setup_observability(args)

    print("name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            import importlib
            mod = importlib.import_module(modname)
            with obs.trace(f"bench.{key}", module=modname):
                mod.run()
        except Exception:  # noqa: BLE001
            failed.append(key)
            traceback.print_exc()

    if args.json:
        payload = build_payload(common.ROWS, smoke=args.smoke, only=only,
                                failed=failed, row_stats=common.ROW_STATS)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[run] wrote {len(payload['rows'])} rows to {args.json}",
              file=sys.stderr)
    if args.trace_out:
        print(f"[run] trace: {obs.save_chrome_trace(args.trace_out)} "
              f"({len(obs.records())} events)", file=sys.stderr)
    if args.metrics_out:
        print(f"[run] metrics: {obs.save_metrics(args.metrics_out)}",
              file=sys.stderr)

    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
