"""Pallas TPU kernels for the GSPN-2 fused line scan.

TPU adaptation of the paper's single-CUDA-kernel design (DESIGN.md §2):

* the whole scan runs inside **one** ``pl.pallas_call`` — the grid walks
  ``(G, H_tiles)`` sequentially and the row loop runs *inside* the kernel,
  eliminating the per-step dispatches of GSPN-1;
* the previous row's hidden state is staged in a **VMEM scratch carry**
  that persists across sequential grid steps — the TPU analogue of the
  paper's shared-memory staging of ``h[i-1]`` (it never round-trips to HBM);
* W is the innermost (lane) dimension so the tridiagonal matvec becomes
  three shifted vector FMAs on fully-coalesced tiles — the analogue of the
  paper's coalesced-access layout;
* channel-shared propagation weights are expressed through the BlockSpec
  ``index_map`` (``g // channels_per_weight``) so the compact-channel mode
  reads each weight tile once per channel group instead of materialising a
  broadcast — the paper's compact channel propagation;
* the channel-slice grid axis plays the role of the paper's 2D thread
  blocks (spatial × cSlice).

Array layout: ``x, lam, out: (G, H, W)``; ``wl, wc, wr: (G_w, H, W)`` with
``G = G_w * channels_per_weight``.  All kernels compute in f32 and cast the
output back to the input dtype; the VMEM carry row is kept in
``carry_dtype`` (f32 under the default mixed-precision policy, DESIGN.md
§10) while the streamed tiles take whatever dtype the operands carry, so
bf16 operands halve the streamed working set and unlock 2× larger row
tiles from the tuner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.kernels import autotune, tuning
from repro.kernels.spec import ScanSpec

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x; accept
# either so the kernels run on the container's pinned jax.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_ROW_TILE = 256


def pick_row_tile(h: int, cap: int = DEFAULT_ROW_TILE, *, w: int = 128,
                  dtype_bytes: int = 4, n_streams: int = 6,
                  carry_dtype_bytes: int = 4,
                  pipeline_depth: int = 1) -> int:
    """Heuristic row-tile choice (the tuner's fallback tier).

    Thin wrapper (old signature preserved) over the single VMEM-aware
    implementation in :func:`repro.kernels.tuning.pick_row_tile`: largest
    power-of-two divisor of ``h`` not exceeding ``cap`` whose streamed
    working set fits the VMEM budget.  ``dtype_bytes`` is the STREAMED
    dtype; ``carry_dtype_bytes`` the VMEM carry's.  Launch sites no longer
    call this directly — they go through ``autotune.plan_for_spec``, which
    prefers a measured cache entry and falls back to this accounting
    (DESIGN.md §11/§12).
    """
    return tuning.pick_row_tile(h, w, dtype_bytes, cap=cap,
                                n_streams=n_streams,
                                carry_dtype_bytes=carry_dtype_bytes,
                                pipeline_depth=pipeline_depth).row_tile


def _row(ref, r):
    """Load row ``r`` of a (1, TH, W) block as a (1, W) f32 tile."""
    return ref[0, pl.dslice(r, 1), :].astype(jnp.float32)


def _shift_right(v):
    """(..., W): v[..., j] -> v[..., j-1], position 0 becomes 0."""
    rolled = jnp.roll(v, 1, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, v.ndim - 1)
    return jnp.where(idx == 0, 0.0, rolled)


def _shift_left(v):
    """(..., W): v[..., j] -> v[..., j+1], last position becomes 0."""
    rolled = jnp.roll(v, -1, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, v.shape, v.ndim - 1)
    return jnp.where(idx == v.shape[-1] - 1, 0.0, rolled)


# ---------------------------------------------------------------------------
# Depth-2 staging helpers (DESIGN.md §12).
#
# The staged pipeline widens every streamed block to f32 ONCE per grid
# step (one bulk convert instead of a per-row widen through the narrow-
# dtype retiling path), broadcasts channel-shared weights in VMEM, runs
# the row recurrence as a ``lax.scan`` over the STAGED VALUES — so the
# sequential loop touches no ref at all: no per-row masked loads, no
# per-row stores — and writes the scan's stacked f32 output stage back
# through ONE bulk downcast.  Between grid steps the BlockSpec revolving
# buffers keep the next tile's DMA in flight while the current tile
# computes; the f32 carry block never leaves VMEM.
# ---------------------------------------------------------------------------

def _stage_widen(ref, cpw: int = 1):
    """Bulk-load a (Gw, T, W) block as f32, broadcast to (Gw*cpw, T, W)."""
    staged = ref[...].astype(jnp.float32)
    if cpw > 1:
        gw = staged.shape[0]
        staged = jnp.broadcast_to(staged[:, None],
                                  (gw, cpw) + staged.shape[1:])
        staged = staged.reshape((gw * cpw,) + staged.shape[2:])
    return staged


def _stage_rows(ref, cpw: int = 1):
    """Stage a (Gw, T, W) block as (T, G, W) f32 scan inputs."""
    return jnp.swapaxes(_stage_widen(ref, cpw), 0, 1)


def _masked_shifts(shape):
    """Edge-masked lane shifts with the iota/compare hoisted OUT of the
    sequential loop: the masks are built once per grid step, so each scan
    step pays one roll + one select per shift instead of re-deriving the
    edge mask.  Identical values to ``_shift_right``/``_shift_left``."""
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    first, last = idx == 0, idx == shape[-1] - 1

    def sr(v):
        return jnp.where(first, 0.0, jnp.roll(v, 1, axis=-1))

    def sl(v):
        return jnp.where(last, 0.0, jnp.roll(v, -1, axis=-1))

    return sr, sl


def _dir_scan(step, init, xs, reverse):
    """``lax.scan`` whose row direction follows a TRACED flag: the staged
    multidir kernels pick the reverse walk per grid step (direction axis)
    without flipping any staged data — ``reverse=True`` consumes rows
    last→first and stacks each output at its row's natural position,
    exactly the legacy kernels' ``r_eff`` indexing (identical values row
    for row, so depth parity stays bitwise)."""
    return jax.lax.cond(
        reverse,
        lambda: jax.lax.scan(step, init, xs, reverse=True),
        lambda: jax.lax.scan(step, init, xs))


# ---------------------------------------------------------------------------
# Forward kernel.
# ---------------------------------------------------------------------------

def _fwd_kernel(row_tile, chunk_tiles,
                x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
    t = pl.program_id(1)

    @pl.when(t % chunk_tiles == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def body(r, h_prev):
        h_new = (
            _row(wl_ref, r) * _shift_right(h_prev)
            + _row(wc_ref, r) * h_prev
            + _row(wr_ref, r) * _shift_left(h_prev)
            + _row(lam_ref, r) * _row(x_ref, r)
        )
        o_ref[0, pl.dslice(r, 1), :] = h_new.astype(o_ref.dtype)
        return h_new

    # The row recurrence runs in f32 regardless of the streamed dtype; the
    # cross-tile carry is stored in the scratch's dtype (carry_dtype).
    carry_ref[...] = jax.lax.fori_loop(
        0, row_tile, body,
        carry_ref[...].astype(jnp.float32)).astype(carry_ref.dtype)


def _fwd_kernel_staged(row_tile, chunk_tiles, cpw,
                       x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref,
                       carry_ref):
    """Depth-2 forward kernel: all G planes per grid step, staged streams.

    Same f32 recurrence and operation order as ``_fwd_kernel`` vectorised
    over the plane axis — the two depths are bit-identical (the
    conformance grid asserts exact agreement).  The recurrence runs as a
    ``lax.scan`` over the staged rows, so the only ref traffic per grid
    step is one bulk load per stream and one bulk downcast store."""
    del row_tile
    t = pl.program_id(0)

    @pl.when(t % chunk_tiles == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    xs = _stage_rows(x_ref)                         # (T, G, W) f32
    lams = _stage_rows(lam_ref)
    wls = _stage_rows(wl_ref, cpw)                  # (Gw,T,W) -> (T,G,W)
    wcs = _stage_rows(wc_ref, cpw)
    wrs = _stage_rows(wr_ref, cpw)
    sr, sl = _masked_shifts(xs.shape[1:])

    # NOTE: lam*x stays INSIDE the step on purpose — hoisting it to a bulk
    # multiply changes which mul/add pairs the CPU backend contracts into
    # FMAs, breaking the bitwise depth-1 agreement in f32 streams.
    def step(h_prev, row):
        x_r, wl_r, wc_r, wr_r, lam_r = row
        h_new = (
            wl_r * sr(h_prev)
            + wc_r * h_prev
            + wr_r * sl(h_prev)
            + lam_r * x_r
        )
        return h_new, h_new

    h0 = carry_ref[...].astype(jnp.float32)[:, 0, :]         # (G, W)
    h_last, ys = jax.lax.scan(step, h0, (xs, wls, wcs, wrs, lams))
    carry_ref[...] = h_last[:, None, :].astype(carry_ref.dtype)
    # ONE bulk downcast writeback per tile — the per-row narrow-dtype
    # store was the bf16 cliff (DESIGN.md §12).
    o_ref[...] = jnp.swapaxes(ys, 0, 1).astype(o_ref.dtype)


def gspn_scan_fwd_pallas(x, wl, wc, wr, lam, *,
                         spec: ScanSpec | None = None,
                         channels_per_weight: int = 1,
                         chunk: int | None = None, row_tile: int | None = None,
                         interpret: bool = True, carry_dtype=jnp.float32,
                         pipeline_depth: int | None = None):
    """Fused forward line scan.  Returns h: (G, H, W) in x.dtype.

    Configuration travels as ONE ``ScanSpec`` (DESIGN.md §14); the loose
    keyword arguments survive as a legacy construction path used only
    when ``spec`` is None.  Streamed tiles take the operands' dtype; the
    VMEM carry row persists in ``spec.carry_dtype`` (f32 by default —
    the mixed-precision policy's accumulator discipline, DESIGN.md §10).
    ``spec.pipeline_depth`` selects the kernel structure (DESIGN.md §12):
    1 walks planes × tiles with per-row loads/stores (the classic
    stream); 2 blocks all planes into each grid step and stages the
    streams in f32 — bulk widen on load, one bulk downcast writeback —
    so narrow dtypes never pay a per-row retiling penalty.  ``None``
    resolves both the tile and the depth through the autotuner (measured
    cache entry keyed on the spec's canonical serialization, heuristic
    fallback).
    """
    g, h, w = x.shape
    if spec is None:
        spec = ScanSpec(channels_per_weight=channels_per_weight,
                        carry_dtype=str(jnp.dtype(carry_dtype)),
                        row_tile=row_tile, pipeline_depth=pipeline_depth,
                        interpret=interpret)
    # Normalise the identity legs this kernel owns: it IS the pallas fwd
    # entry, and it streams whatever dtype the operands carry.
    spec = spec.with_(direction="fwd", impl="pallas",
                      stream_dtype=str(jnp.dtype(x.dtype)))
    cpw = spec.channels_per_weight
    gw = g // cpw
    assert wl.shape[0] * cpw == g, (wl.shape, g, cpw)
    chunk = h if chunk is None else chunk
    assert h % chunk == 0, (h, chunk)
    carry_dtype = jnp.dtype(spec.carry_dtype)
    interpret = spec.interpret
    plan = autotune.plan_for_spec(spec, min(h, chunk), w, c=g)
    row_tile, pipeline_depth = plan.row_tile, plan.pipeline_depth
    assert chunk % row_tile == 0, (chunk, row_tile)
    assert pipeline_depth in (1, 2), pipeline_depth
    chunk_tiles = chunk // row_tile

    # Traced-launch span (DESIGN.md §13): fires once per jit trace of this
    # launch site, annotated with the tuner-resolved plan.
    with obs.trace("kernel.launch", kernel="gspn_scan_fwd",
                   row_tile=row_tile, pipeline_depth=pipeline_depth,
                   dtype=str(jnp.dtype(x.dtype)), g=g, h=h, w=w):
        if pipeline_depth == 1:
            data_spec = pl.BlockSpec((1, row_tile, w),
                                     lambda gi, ti: (gi, ti, 0))
            wt_spec = pl.BlockSpec((1, row_tile, w),
                                   lambda gi, ti: (gi // cpw, ti, 0))
            return pl.pallas_call(
                functools.partial(_fwd_kernel, row_tile, chunk_tiles),
                grid=(g, h // row_tile),
                in_specs=[data_spec, wt_spec, wt_spec, wt_spec, data_spec],
                out_specs=data_spec,
                out_shape=jax.ShapeDtypeStruct((g, h, w), x.dtype),
                scratch_shapes=[pltpu.VMEM((1, w), carry_dtype)],
                compiler_params=CompilerParams(
                    dimension_semantics=("arbitrary", "arbitrary"),
                ),
                interpret=interpret,
            )(x, wl, wc, wr, lam)

        data_spec = pl.BlockSpec((g, row_tile, w), lambda ti: (0, ti, 0))
        wt_spec = pl.BlockSpec((gw, row_tile, w), lambda ti: (0, ti, 0))
        return pl.pallas_call(
            functools.partial(_fwd_kernel_staged, row_tile, chunk_tiles, cpw),
            grid=(h // row_tile,),
            in_specs=[data_spec, wt_spec, wt_spec, wt_spec, data_spec],
            out_specs=data_spec,
            out_shape=jax.ShapeDtypeStruct((g, h, w), x.dtype),
            scratch_shapes=[pltpu.VMEM((g, 1, w), carry_dtype)],
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",),
            ),
            interpret=interpret,
        )(x, wl, wc, wr, lam)


# ---------------------------------------------------------------------------
# Backward (adjoint) kernel.
#
# Runs on H-flipped arrays so the sequential grid walks rows from last to
# first.  The carry holds the three tap*adjoint products of the previously
# processed (i.e. next-in-original-order) row:
#     g[i] = dy[i] + shift_left(wl[i+1]*g[i+1]) + wc[i+1]*g[i+1]
#                  + shift_right(wr[i+1]*g[i+1])
# ---------------------------------------------------------------------------

def _bwd_kernel(row_tile, chunk_tiles,
                dy_ref, wl_ref, wc_ref, wr_ref, g_ref, carry_ref):
    t = pl.program_id(1)

    @pl.when(t % chunk_tiles == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def body(r, _):
        prod_l = carry_ref[0, :, :]
        prod_c = carry_ref[1, :, :]
        prod_r = carry_ref[2, :, :]
        g_row = (
            _row(dy_ref, r)
            + _shift_left(prod_l)
            + prod_c
            + _shift_right(prod_r)
        )
        g_ref[0, pl.dslice(r, 1), :] = g_row.astype(g_ref.dtype)
        carry_ref[0, :, :] = _row(wl_ref, r) * g_row
        carry_ref[1, :, :] = _row(wc_ref, r) * g_row
        carry_ref[2, :, :] = _row(wr_ref, r) * g_row
        return 0

    jax.lax.fori_loop(0, row_tile, body, 0)


def _bwd_kernel_staged(row_tile, chunk_tiles, cpw,
                       dy_ref, wl_ref, wc_ref, wr_ref, g_ref, carry_ref):
    """Depth-2 adjoint kernel: all planes per grid step, staged streams.
    Same f32 recurrence and operation order as ``_bwd_kernel`` vectorised
    over the plane axis (the three tap·adjoint carry rows ride the
    ``lax.scan`` carry instead of round-tripping through scratch —
    identical f32 values either way)."""
    del row_tile
    t = pl.program_id(0)

    @pl.when(t % chunk_tiles == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    dys = _stage_rows(dy_ref)                       # (T, G, W) f32
    wls = _stage_rows(wl_ref, cpw)
    wcs = _stage_rows(wc_ref, cpw)
    wrs = _stage_rows(wr_ref, cpw)
    sr, sl = _masked_shifts(dys.shape[1:])

    def step(prods, row):
        dy_r, wl_r, wc_r, wr_r = row
        prod_l, prod_c, prod_r = prods
        g_row = (
            dy_r
            + sl(prod_l)
            + prod_c
            + sr(prod_r)
        )
        return (wl_r * g_row, wc_r * g_row, wr_r * g_row), g_row

    p0 = (carry_ref[0][:, 0, :], carry_ref[1][:, 0, :],
          carry_ref[2][:, 0, :])
    prods, ys = jax.lax.scan(step, p0, (dys, wls, wcs, wrs))
    carry_ref[0], carry_ref[1], carry_ref[2] = \
        (p[:, None, :] for p in prods)
    g_ref[...] = jnp.swapaxes(ys, 0, 1).astype(g_ref.dtype)


def gspn_scan_bwd_pallas(dy, wl, wc, wr, *, spec: ScanSpec | None = None,
                         channels_per_weight: int = 1,
                         chunk: int | None = None, row_tile: int | None = None,
                         interpret: bool = True,
                         pipeline_depth: int | None = None):
    """Adjoint scan.  Inputs are in ORIGINAL orientation; flipping is done
    here.  Returns g = dL/dh pre-output-layer: (G, H, W) f32.
    ``pipeline_depth=2`` is the staged pipeline (DESIGN.md §12)."""
    g_dim, h, w = dy.shape
    if spec is None:
        spec = ScanSpec(channels_per_weight=channels_per_weight,
                        row_tile=row_tile, pipeline_depth=pipeline_depth,
                        interpret=interpret)
    # The streamed operands are dy + the three taps (their real dtype —
    # bf16 streams unlock 2× larger row tiles); the adjoint carry is three
    # f32 tap·adjoint rows regardless of the policy (the "bwd" direction
    # leg encodes both the 5-stream count and the 3-row carry).
    spec = spec.with_(direction="bwd", impl="pallas",
                      stream_dtype=str(jnp.dtype(dy.dtype)),
                      carry_dtype="float32")
    cpw = spec.channels_per_weight
    gw = g_dim // cpw
    chunk = h if chunk is None else chunk
    assert h % chunk == 0, (h, chunk)
    interpret = spec.interpret
    plan = autotune.plan_for_spec(spec, min(h, chunk), w, c=g_dim)
    row_tile, pipeline_depth = plan.row_tile, plan.pipeline_depth
    assert pipeline_depth in (1, 2), pipeline_depth
    chunk_tiles = chunk // row_tile

    dy_f = jnp.flip(dy, axis=1)
    wl_f = jnp.flip(wl, axis=1)
    wc_f = jnp.flip(wc, axis=1)
    wr_f = jnp.flip(wr, axis=1)

    with obs.trace("kernel.launch", kernel="gspn_scan_bwd",
                   row_tile=row_tile, pipeline_depth=pipeline_depth,
                   dtype=str(jnp.dtype(dy.dtype)), g=g_dim, h=h, w=w):
        if pipeline_depth == 1:
            data_spec = pl.BlockSpec((1, row_tile, w),
                                     lambda gi, ti: (gi, ti, 0))
            wt_spec = pl.BlockSpec((1, row_tile, w),
                                   lambda gi, ti: (gi // cpw, ti, 0))
            g_f = pl.pallas_call(
                functools.partial(_bwd_kernel, row_tile, chunk_tiles),
                grid=(g_dim, h // row_tile),
                in_specs=[data_spec, wt_spec, wt_spec, wt_spec],
                out_specs=data_spec,
                out_shape=jax.ShapeDtypeStruct((g_dim, h, w), jnp.float32),
                scratch_shapes=[pltpu.VMEM((3, 1, w), jnp.float32)],
                compiler_params=CompilerParams(
                    dimension_semantics=("arbitrary", "arbitrary"),
                ),
                interpret=interpret,
            )(dy_f, wl_f, wc_f, wr_f)
        else:
            data_spec = pl.BlockSpec((g_dim, row_tile, w),
                                     lambda ti: (0, ti, 0))
            wt_spec = pl.BlockSpec((gw, row_tile, w), lambda ti: (0, ti, 0))
            g_f = pl.pallas_call(
                functools.partial(_bwd_kernel_staged, row_tile, chunk_tiles,
                                  cpw),
                grid=(h // row_tile,),
                in_specs=[data_spec, wt_spec, wt_spec, wt_spec],
                out_specs=data_spec,
                out_shape=jax.ShapeDtypeStruct((g_dim, h, w), jnp.float32),
                scratch_shapes=[pltpu.VMEM((3, g_dim, 1, w), jnp.float32)],
                compiler_params=CompilerParams(
                    dimension_semantics=("arbitrary",),
                ),
                interpret=interpret,
            )(dy_f, wl_f, wc_f, wr_f)
    return jnp.flip(g_f, axis=1)
