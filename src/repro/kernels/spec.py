"""Declarative scan configuration: the ``ScanSpec`` (DESIGN.md §14).

GSPN-2's pitch is one kernel structure serving many propagation variants,
yet before this module every launch path hand-threaded the same knobs
(direction, channel mode, dtype policy, row_tile, pipeline_depth,
boundary behaviour) as loose keyword arguments — adding one knob meant
touching five call sites.  ``ScanSpec`` is the single frozen, hashable
value that carries ALL of them:

* every launch site (``ops`` dispatch, ``gspn_scan`` fwd/bwd,
  ``gspn_multidir`` pair/quad, the sp block-local scan, the serve
  chunked-prefill path) constructs ONE spec and hands it down;
* the autotuner keys its persistent cache on the spec's canonical
  serialization (:func:`canonical_key` — cache schema 3);
* the test suite enumerates the full admissible spec space
  (:func:`enumerate_specs`) and runs every emitted spec fwd+grad against
  the reference, so a new propagation variant is a spec plus an
  automatic conformance entry, not a fifth kernel fork.

This module is a LEAF: it imports nothing from the rest of the kernel
stack so every layer (kernels, ops, sp, core, autotune, benchmarks) can
import it without cycles.
"""

from __future__ import annotations

import dataclasses
import itertools

import jax.numpy as jnp

# The admissible vocabulary.  ``direction`` names the fused-kernel entry
# (not the spatial orientation tb/bt/lr/rl — orientation is handled by
# core/gspn canonicalisation and always lowers to one of these).
DIRECTIONS = ("fwd", "bwd", "pair_fwd", "pair_bwd", "quad")

# How a scan segment relates to state outside itself (DESIGN.md §14):
#   one_shot        — the whole sequence in one launch, zero initial carry;
#   chunk_resume    — serve chunked prefill: the carry enters as a
#                     synthetic resumed row (core/gspn.gspn_seq_prefill_chunk);
#   sp_block_local  — sequence-parallel block-local scan: zero initial
#                     carry per block, boundaries exchanged by collectives
#                     (parallel/gspn_sp).
BOUNDARIES = ("one_shot", "chunk_resume", "sp_block_local")

# Kernel-selection leg.  "auto" resolves per backend (ops._resolve_impl);
# "sp" routes to the sequence-parallel wrapper; the rest name concrete
# implementations.
IMPLS = ("auto", "pallas", "multidir", "xla", "per_step", "sp")

_ADJOINT = {"fwd": "bwd", "pair_fwd": "pair_bwd"}


def canonical_key(direction: str, impl: str, stream_dtype: str,
                  carry_dtype: str, channel_shared: bool,
                  boundary: str) -> str:
    """The policy leg of the schema-3 autotune cache key.  Shared between
    :meth:`ScanSpec.canonical` and ``autotune.ScanKey.encode`` so "keyed
    on the spec's canonical serialization" is literally true: a ScanKey's
    encoding ends with the owning spec's canonical string."""
    return (f"{direction}|{impl}|{stream_dtype}|carry-{carry_dtype}"
            f"|cs{int(channel_shared)}|bnd-{boundary}")


def _dtype_name(dtype) -> str:
    try:
        return str(jnp.dtype(dtype))
    except TypeError as exc:
        raise ValueError(f"unknown dtype {dtype!r}") from exc


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Everything one fused-scan launch needs to know about itself.

    Frozen and built only from hashables so a spec can be a custom_vjp
    nondiff argument, a dict key, and a cache key.  Shape-derived fields
    (``channels_per_weight``, ``stream_dtype``) are refined by the
    dispatch layer from the operands; the caller-supplied values act as
    defaults.
    """

    direction: str = "fwd"             # DIRECTIONS
    impl: str = "auto"                 # IMPLS
    channels_per_weight: int = 1       # compact channel mode: G = G_w·cpw
    stream_dtype: str = "float32"      # streamed operand tiles
    carry_dtype: str = "float32"       # VMEM carry (f32 under the policy)
    row_tile: int | None = None        # None = ask the autotuner
    pipeline_depth: int | None = None  # None = tuner/heuristic; 1 | 2
    boundary: str = "one_shot"         # BOUNDARIES
    interpret: bool = True             # Pallas interpret mode (CPU path)

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"unknown direction {self.direction!r}; "
                             f"expected one of {DIRECTIONS}")
        if self.impl not in IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; "
                             f"expected one of {IMPLS}")
        if self.boundary not in BOUNDARIES:
            raise ValueError(f"unknown boundary {self.boundary!r}; "
                             f"expected one of {BOUNDARIES}")
        if not isinstance(self.channels_per_weight, int) \
                or self.channels_per_weight < 1:
            raise ValueError(f"channels_per_weight must be a positive int, "
                             f"got {self.channels_per_weight!r}")
        if self.row_tile is not None and (
                not isinstance(self.row_tile, int) or self.row_tile < 1):
            raise ValueError(f"row_tile must be a positive int or None, "
                             f"got {self.row_tile!r}")
        if self.pipeline_depth not in (None, 1, 2):
            raise ValueError(f"pipeline_depth must be None, 1 or 2, "
                             f"got {self.pipeline_depth!r}")
        # Normalise dtype spellings ("f32", np.float32, jnp.bfloat16) to
        # the canonical numpy name so spec equality/hashing — and through
        # them the cache key — never splits on spelling.
        object.__setattr__(self, "stream_dtype",
                           _dtype_name(self.stream_dtype))
        object.__setattr__(self, "carry_dtype",
                           _dtype_name(self.carry_dtype))

    # -- derived views -----------------------------------------------------

    @property
    def channel_shared(self) -> bool:
        """Compact channel propagation active (weights span cpw planes)."""
        return self.channels_per_weight > 1

    @property
    def channel_mode(self) -> str:
        return "shared" if self.channel_shared else "per_channel"

    @property
    def stream_bytes(self) -> int:
        return jnp.dtype(self.stream_dtype).itemsize

    # -- serialization / derivation ---------------------------------------

    def canonical(self) -> str:
        """Canonical policy serialization — the trailing leg of the
        schema-3 autotune cache key (see :func:`canonical_key`)."""
        return canonical_key(self.direction, self.impl, self.stream_dtype,
                             self.carry_dtype, self.channel_shared,
                             self.boundary)

    def spec_id(self) -> str:
        """Full human-readable identity (test ids, trace annotations)."""
        t = self.row_tile if self.row_tile is not None else "auto"
        d = self.pipeline_depth if self.pipeline_depth is not None else "auto"
        mode = "interp" if self.interpret else "compiled"
        return (f"{self.canonical()}|cpw{self.channels_per_weight}"
                f"|t{t}|d{d}|{mode}")

    def with_(self, **changes) -> "ScanSpec":
        """``dataclasses.replace`` with re-validation (frozen update)."""
        return dataclasses.replace(self, **changes)

    def adjoint(self) -> "ScanSpec":
        """The spec of this launch's backward pass: the adjoint direction
        with the always-f32 adjoint carry (DESIGN.md §10).  Only forward
        directions have a fused adjoint kernel."""
        if self.direction not in _ADJOINT:
            raise ValueError(f"no fused adjoint for direction "
                             f"{self.direction!r}")
        return self.with_(direction=_ADJOINT[self.direction],
                          carry_dtype="float32")


def enumerate_specs(*, boundaries=("one_shot",),
                    cpws=(1, 3)) -> list[ScanSpec]:
    """The FULL admissible forward spec grid — the single source of truth
    the conformance sweep runs against (every emitted spec must pass
    fwd+grad vs the reference; tests/test_conformance.py).

    Shape of the grid:

    * direction × impl follows the dispatch matrix (fwd: pallas/xla,
      pair_fwd: multidir/xla, quad: multidir-only);
    * stream dtype f32 and bf16; carry is f32 (the policy default) plus
      the aggressive stream-width carry for narrow streams;
    * channel mode per-channel (cpw=1) and compact (cpw>1);
    * pipeline depth 1 and 2 for the fused kernels (the kernels accept
      depth 2 at any dtype — the tuner's narrow-stream restriction is
      admission policy, not capability), None for xla (no pipeline);
    * every requested boundary behaviour (numerics are boundary-label
      invariant; the label keys the cache and the routing).

    Backward/adjoint specs are not enumerated separately: every grid
    entry runs fwd AND grad, which exercises the adjoint kernels through
    ``ScanSpec.adjoint``.
    """
    impls_for = {"fwd": ("pallas", "xla"),
                 "pair_fwd": ("multidir", "xla"),
                 "quad": ("multidir",)}
    out: list[ScanSpec] = []
    for direction, boundary, cpw in itertools.product(
            impls_for, boundaries, cpws):
        for impl in impls_for[direction]:
            for stream in ("float32", "bfloat16"):
                if impl == "xla":
                    # XLA reference path: no VMEM carry, no pipeline —
                    # those legs collapse to the policy default.
                    out.append(ScanSpec(
                        direction=direction, impl=impl,
                        channels_per_weight=cpw, stream_dtype=stream,
                        boundary=boundary))
                    continue
                carries = ("float32",) if stream == "float32" \
                    else ("float32", "bfloat16")
                for carry, depth in itertools.product(carries, (1, 2)):
                    out.append(ScanSpec(
                        direction=direction, impl=impl,
                        channels_per_weight=cpw, stream_dtype=stream,
                        carry_dtype=carry, pipeline_depth=depth,
                        boundary=boundary))
    return out
