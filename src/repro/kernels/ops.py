"""Jit-friendly public ops for the GSPN-2 line scan.

Two ``custom_vjp`` primitive-like entry points with hand-derived adjoint
scans (DESIGN.md §2) are used by ``repro.core.gspn``:

* ``gspn_scan``      — one directional line scan (G, H, W) -> (G, H, W);
* ``gspn_scan_pair`` — one OPPOSITE-DIRECTION PAIR in a single fused
  launch: the canonical top→bottom scan and its bottom→top mirror share
  every ``x`` tile, so a full four-direction GSPN pass costs two launches
  instead of four (see ``repro.core.gspn.directional_scan``).

The impl matrix (both entry points):

* ``impl="pallas"``  — the fused Pallas TPU kernel (``interpret=True`` on
  CPU for validation; compiled Mosaic on real TPUs);
* ``impl="multidir"``— the fused opposite-pair Pallas kernel
  (``kernels/gspn_multidir.py``); for the single-direction ``gspn_scan``
  this degenerates to ``pallas`` (same kernel family, one direction);
* ``impl="xla"``     — a single ``lax.scan`` per direction (the fused-scan
  analogue at the XLA level; used for the multi-pod dry-run where Pallas
  cannot lower on the CPU backend);
* ``impl="per_step"``— the GSPN-1 emulation (benchmarks only; forward-only).
* ``impl="sp"``      — the spatially-sharded scan (``parallel/gspn_sp.py``,
  DESIGN.md §8): the scan dimension is partitioned over the ``seq`` mesh
  axis, one compact boundary exchange per scan.  Extra kwargs ``mesh`` /
  ``seq_axis`` / ``sp_strategy`` select the mesh axis and collective
  strategy; without a usable mesh it falls back to the single-device path.
* ``impl="auto"``    — pallas/multidir on TPU, xla elsewhere.

Layout: ``x, lam: (G, H, W)``; ``wl, wc, wr: (G_w, H, W)`` with
``G_w ∈ {G, G // channels_per_weight}`` (channel-shared compact mode).
Pair-op operands carry a leading direction axis of size 2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import gspn_multidir as _mk
from repro.kernels import gspn_scan as _pk
from repro.kernels import ref as _ref
from repro.kernels.spec import ScanSpec


def _base_spec(spec: ScanSpec | None, *, impl, row_tile, interpret,
               carry_dtype, pipeline_depth, boundary) -> ScanSpec:
    """One ScanSpec per public call (DESIGN.md §14): the caller's spec
    verbatim, or one built from the legacy keyword arguments.  The spec
    is the nondiff custom_vjp argument — frozen and hashable by
    construction."""
    if spec is not None:
        return spec
    return ScanSpec(impl=impl, row_tile=row_tile, interpret=interpret,
                    carry_dtype=str(jnp.dtype(carry_dtype)),
                    pipeline_depth=pipeline_depth, boundary=boundary)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "multidir":
        # The pair kernel family; a single-direction scan through it is
        # just the pallas path.
        return "pallas"
    return impl


def _resolve_pair_impl(impl: str) -> str:
    if impl == "auto":
        return "multidir" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        return "multidir"
    if impl not in ("multidir", "xla"):
        raise ValueError(
            f"impl {impl!r} not supported for the fused pair scan")
    return impl


def _fwd_dispatch(spec: ScanSpec, x, wl, wc, wr, lam):
    impl = _resolve_impl(spec.impl)
    # Traced-dispatch span (DESIGN.md §13): fires once per jit trace.
    with obs.trace("kernel.dispatch", op="gspn_scan", impl=impl,
                   dtype=str(jnp.dtype(x.dtype)), shape=str(x.shape)):
        if impl == "pallas":
            return _pk.gspn_scan_fwd_pallas(x, wl, wc, wr, lam, spec=spec)
        if impl == "xla":
            return _ref.gspn_scan_ref(x, wl, wc, wr, lam)
        if impl == "per_step":
            return _ref.gspn_scan_per_step(x, wl, wc, wr, lam)
    raise ValueError(f"unknown impl {impl!r}")


def _bwd_adjoint_xla(dy, wl_b, wc_b, wr_b, reverse: bool = True):
    """Adjoint scan via lax.scan; weights pre-broadcast to full G. f32 out.

    ``reverse=True`` is the adjoint of the top→bottom forward scan (walks
    rows last→first); ``reverse=False`` is the adjoint of the bottom→top
    forward scan (walks rows first→last).
    """
    zeros = jnp.zeros_like(dy[:, 0], dtype=jnp.float32)

    def body(prods, row):
        dy_r, wl_r, wc_r, wr_r = row
        p_l, p_c, p_r = prods
        g_r = (dy_r.astype(jnp.float32)
               + _ref._shift_left(p_l) + p_c + _ref._shift_right(p_r))
        wf = (wl_r.astype(jnp.float32), wc_r.astype(jnp.float32),
              wr_r.astype(jnp.float32))
        return (wf[0] * g_r, wf[1] * g_r, wf[2] * g_r), g_r

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (dy, wl_b, wc_b, wr_b))
    _, gs = jax.lax.scan(body, (zeros, zeros, zeros), xs, reverse=reverse)
    return jnp.moveaxis(gs, 0, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gspn_core(spec: ScanSpec, x, wl, wc, wr, lam):
    return _fwd_dispatch(spec, x, wl, wc, wr, lam)


def _gspn_core_fwd(spec, x, wl, wc, wr, lam):
    h = _fwd_dispatch(spec, x, wl, wc, wr, lam)
    return h, (x, wl, wc, wr, lam, h)


def _gspn_core_bwd(spec, res, dy):
    x, wl, wc, wr, lam, h = res
    g_dim = x.shape[0]
    cpw = spec.channels_per_weight
    impl = _resolve_impl(spec.impl)

    with obs.trace("kernel.dispatch", op="gspn_scan_bwd", impl=impl,
                   dtype=str(jnp.dtype(dy.dtype)), shape=str(dy.shape)):
        if impl == "pallas":
            g = _pk.gspn_scan_bwd_pallas(dy, wl, wc, wr,
                                         spec=spec.adjoint())
        else:
            wl_b = _ref._broadcast_w(wl, g_dim)
            wc_b = _ref._broadcast_w(wc, g_dim)
            wr_b = _ref._broadcast_w(wr, g_dim)
            g = _bwd_adjoint_xla(dy, wl_b, wc_b, wr_b)

    g = g.astype(jnp.float32)
    h32 = h.astype(jnp.float32)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h32[:, :1]), h32[:, :-1]], axis=1)
    dx = (lam.astype(jnp.float32) * g).astype(x.dtype)
    dlam = (x.astype(jnp.float32) * g).astype(lam.dtype)
    dwl = g * _ref._shift_right(h_prev)
    dwc = g * h_prev
    dwr = g * _ref._shift_left(h_prev)
    if cpw > 1:
        gw = g_dim // cpw
        shp = (gw, cpw) + dwl.shape[1:]
        dwl = dwl.reshape(shp).sum(axis=1)
        dwc = dwc.reshape(shp).sum(axis=1)
        dwr = dwr.reshape(shp).sum(axis=1)
    return (dx, dwl.astype(wl.dtype), dwc.astype(wc.dtype),
            dwr.astype(wr.dtype), dlam)


_gspn_core.defvjp(_gspn_core_fwd, _gspn_core_bwd)


def gspn_scan(x, wl, wc, wr, lam, *, spec: ScanSpec | None = None,
              chunk: int | None = None,
              impl: str = "auto", row_tile: int | None = None,
              interpret: bool = True, mesh=None, seq_axis: str = "seq",
              sp_strategy: str = "auto", carry_dtype="float32",
              sp_boundary_dtype=None, pipeline_depth: int | None = None,
              boundary: str = "one_shot"):
    """GSPN line scan with optional GSPN-local chunking.

    x, lam: (G, H, W); wl/wc/wr: (G_w, H, W), G_w divides G.
    Returns h: (G, H, W) in x.dtype.  Differentiable in all tensor args.
    Configuration travels as ONE ``ScanSpec`` (DESIGN.md §14): pass
    ``spec=`` directly, or let the legacy knob kwargs (``impl`` /
    ``row_tile`` / ``interpret`` / ``carry_dtype`` / ``pipeline_depth``
    / ``boundary``) build one — they are ignored when ``spec`` is given.
    ``mesh``/``seq_axis``/``sp_strategy``/``sp_boundary_dtype`` are sp
    ROUTING arguments (where the scan runs / the wire dtype), not scan
    policy, so they stay outside the spec and only apply to
    ``impl="sp"``.
    """
    spec = _base_spec(spec, impl=impl, row_tile=row_tile,
                      interpret=interpret, carry_dtype=carry_dtype,
                      pipeline_depth=pipeline_depth, boundary=boundary)
    if spec.impl == "sp":
        from repro.parallel.gspn_sp import gspn_scan_sp
        return gspn_scan_sp(x, wl, wc, wr, lam, spec=spec, mesh=mesh,
                            axis_name=seq_axis, strategy=sp_strategy,
                            chunk=chunk, boundary_dtype=sp_boundary_dtype)
    g, h, w = x.shape
    gw = wl.shape[0]
    assert g % gw == 0, (g, gw)
    cpw = g // gw
    # Refine the shape/operand-derived legs the caller cannot know.
    spec = spec.with_(direction="fwd",
                      stream_dtype=str(jnp.dtype(x.dtype)))

    if chunk is not None and chunk != h:
        assert h % chunk == 0, (h, chunk)
        n = h // chunk
        # Differentiable broadcast + fold; core then runs with cpw=1 so the
        # chunk index can be absorbed into the leading grid dimension.
        wl_b = _ref._broadcast_w(wl, g)
        wc_b = _ref._broadcast_w(wc, g)
        wr_b = _ref._broadcast_w(wr, g)

        def fold(a):
            return a.reshape(g * n, chunk, w)

        out = _gspn_core(spec.with_(channels_per_weight=1), fold(x),
                         fold(wl_b), fold(wc_b), fold(wr_b), fold(lam))
        return out.reshape(g, h, w)

    return _gspn_core(spec.with_(channels_per_weight=cpw),
                      x, wl, wc, wr, lam)


# ---------------------------------------------------------------------------
# Fused opposite-direction pair scan (DESIGN.md §2).
#
# Semantics per pair entry (both in the UNFLIPPED layout of x):
#   out[0][i] = wl[0,i]*h[i-1,j-1] + wc[0,i]*h[i-1,j] + wr[0,i]*h[i-1,j+1]
#               + lam[0,i]*x[i]            (top→bottom)
#   out[1][i] = same recurrence with i-1 -> i+1   (bottom→top)
# ---------------------------------------------------------------------------

def _pair_fwd_dispatch(spec: ScanSpec, x, wl2, wc2, wr2, lam2):
    impl = _resolve_pair_impl(spec.impl)
    with obs.trace("kernel.dispatch", op="gspn_scan_pair", impl=impl,
                   dtype=str(jnp.dtype(x.dtype)), shape=str(x.shape)):
        if impl == "multidir":
            return _mk.gspn_scan_bidir_pallas(
                x, {"wl": wl2, "wc": wc2, "wr": wr2}, lam2, spec=spec)
        fwd = _ref.gspn_scan_ref(x, wl2[0], wc2[0], wr2[0], lam2[0])
        rev = _ref.gspn_scan_ref(x, wl2[1], wc2[1], wr2[1], lam2[1],
                                 reverse=True)
        return jnp.stack([fwd, rev])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gspn_pair_core(spec: ScanSpec, x, wl2, wc2, wr2, lam2):
    return _pair_fwd_dispatch(spec, x, wl2, wc2, wr2, lam2)


def _gspn_pair_fwd(spec, x, wl2, wc2, wr2, lam2):
    h2 = _pair_fwd_dispatch(spec, x, wl2, wc2, wr2, lam2)
    return h2, (x, wl2, wc2, wr2, lam2, h2)


def _gspn_pair_bwd(spec, res, dy2):
    x, wl2, wc2, wr2, lam2, h2 = res
    g_dim = x.shape[0]
    cpw = spec.channels_per_weight
    impl = _resolve_pair_impl(spec.impl)

    with obs.trace("kernel.dispatch", op="gspn_scan_pair_bwd", impl=impl,
                   dtype=str(jnp.dtype(dy2.dtype)), shape=str(dy2.shape)):
        if impl == "multidir":
            g2 = _mk.gspn_scan_bidir_bwd_pallas(dy2, wl2, wc2, wr2,
                                                spec=spec.adjoint())
        else:
            gs = []
            for d, reverse in ((0, True), (1, False)):
                wl_b = _ref._broadcast_w(wl2[d], g_dim)
                wc_b = _ref._broadcast_w(wc2[d], g_dim)
                wr_b = _ref._broadcast_w(wr2[d], g_dim)
                gs.append(_bwd_adjoint_xla(dy2[d], wl_b, wc_b, wr_b,
                                           reverse=reverse))
            g2 = jnp.stack(gs)

    g2 = g2.astype(jnp.float32)
    h32 = h2.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    # Previous-row state per direction: d=0 reads row i-1, d=1 reads i+1.
    h_prev = jnp.stack([
        jnp.concatenate([jnp.zeros_like(h32[0, :, :1]), h32[0, :, :-1]],
                        axis=1),
        jnp.concatenate([h32[1, :, 1:], jnp.zeros_like(h32[1, :, :1])],
                        axis=1),
    ])
    dx = ((lam2[0].astype(jnp.float32) * g2[0])
          + (lam2[1].astype(jnp.float32) * g2[1])).astype(x.dtype)
    dlam2 = (x32[None] * g2).astype(lam2.dtype)
    dwl2 = g2 * _ref._shift_right(h_prev)
    dwc2 = g2 * h_prev
    dwr2 = g2 * _ref._shift_left(h_prev)
    if cpw > 1:
        gw = g_dim // cpw
        shp = (2, gw, cpw) + dwl2.shape[2:]
        dwl2 = dwl2.reshape(shp).sum(axis=2)
        dwc2 = dwc2.reshape(shp).sum(axis=2)
        dwr2 = dwr2.reshape(shp).sum(axis=2)
    return (dx, dwl2.astype(wl2.dtype), dwc2.astype(wc2.dtype),
            dwr2.astype(wr2.dtype), dlam2)


_gspn_pair_core.defvjp(_gspn_pair_fwd, _gspn_pair_bwd)


def gspn_scan_pair(x, wl2, wc2, wr2, lam2, *, spec: ScanSpec | None = None,
                   chunk: int | None = None,
                   impl: str = "auto", row_tile: int | None = None,
                   interpret: bool = True, mesh=None, seq_axis: str = "seq",
                   sp_strategy: str = "auto", carry_dtype="float32",
                   sp_boundary_dtype=None, pipeline_depth: int | None = None,
                   boundary: str = "one_shot"):
    """Fused opposite-direction pair scan with optional GSPN-local chunking.

    x: (G, H, W) — SHARED by both directions; wl2/wc2/wr2: (2, G_w, H, W)
    with G_w dividing G; lam2: (2, G, H, W).  Entry 0 scans top→bottom over
    axis -2, entry 1 bottom→top; all operands and outputs stay in the
    UNFLIPPED layout of x (the reverse traversal is index arithmetic inside
    the kernel, never a flipped copy).  Returns (2, G, H, W) in x.dtype.
    Differentiable in all tensor args.  As for :func:`gspn_scan`,
    configuration travels as ONE ``ScanSpec`` — the knob kwargs are the
    legacy construction path, ignored when ``spec`` is given.
    ``impl="sp"`` shards the pair over the ``seq_axis`` mesh axis with a
    SINGLE shared boundary collective for both directions
    (:func:`repro.parallel.gspn_sp.gspn_scan_sp_pair`, DESIGN.md §8).
    """
    spec = _base_spec(spec, impl=impl, row_tile=row_tile,
                      interpret=interpret, carry_dtype=carry_dtype,
                      pipeline_depth=pipeline_depth, boundary=boundary)
    g, h, w = x.shape
    gw = wl2.shape[1]
    assert g % gw == 0, (g, gw)
    cpw = g // gw
    spec = spec.with_(direction="pair_fwd",
                      stream_dtype=str(jnp.dtype(x.dtype)))

    if spec.impl == "sp":
        from repro.parallel.gspn_sp import gspn_scan_sp_pair
        return gspn_scan_sp_pair(x, wl2, wc2, wr2, lam2, spec=spec,
                                 mesh=mesh, axis_name=seq_axis,
                                 strategy=sp_strategy, chunk=chunk,
                                 boundary_dtype=sp_boundary_dtype)

    if chunk is not None and chunk != h:
        assert h % chunk == 0, (h, chunk)
        n = h // chunk
        wl_b = jnp.stack([_ref._broadcast_w(wl2[d], g) for d in (0, 1)])
        wc_b = jnp.stack([_ref._broadcast_w(wc2[d], g) for d in (0, 1)])
        wr_b = jnp.stack([_ref._broadcast_w(wr2[d], g) for d in (0, 1)])

        def fold(a):           # (G, H, W) -> (G*n, chunk, W)
            return a.reshape(g * n, chunk, w)

        def fold2(a):          # (2, G, H, W) -> (2, G*n, chunk, W)
            return a.reshape(2, g * n, chunk, w)

        out = _gspn_pair_core(spec.with_(channels_per_weight=1), fold(x),
                              fold2(wl_b), fold2(wc_b), fold2(wr_b),
                              fold2(lam2))
        return out.reshape(2, g, h, w)

    return _gspn_pair_core(spec.with_(channels_per_weight=cpw),
                           x, wl2, wc2, wr2, lam2)
