"""Jit-friendly public ops for the GSPN-2 line scan.

``gspn_scan`` is the single entry point used by ``repro.core.gspn``.  It is
a ``custom_vjp`` primitive-like function with a hand-derived adjoint scan
(DESIGN.md §2), selectable between:

* ``impl="pallas"``  — the fused Pallas TPU kernel (``interpret=True`` on
  CPU for validation; compiled Mosaic on real TPUs);
* ``impl="xla"``     — a single ``lax.scan`` (the fused-scan analogue at the
  XLA level; used for the multi-pod dry-run where Pallas cannot lower on
  the CPU backend);
* ``impl="per_step"``— the GSPN-1 emulation (benchmarks only; forward-only).
* ``impl="auto"``    — pallas on TPU, xla elsewhere.

Layout: ``x, lam: (G, H, W)``; ``wl, wc, wr: (G_w, H, W)`` with
``G_w ∈ {G, G // channels_per_weight}`` (channel-shared compact mode).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import gspn_scan as _pk
from repro.kernels import ref as _ref


@dataclasses.dataclass(frozen=True)
class ScanConfig:
    impl: str = "auto"
    channels_per_weight: int = 1
    row_tile: int | None = None
    interpret: bool = True


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _fwd_dispatch(cfg: ScanConfig, x, wl, wc, wr, lam):
    impl = _resolve_impl(cfg.impl)
    if impl == "pallas":
        return _pk.gspn_scan_fwd_pallas(
            x, wl, wc, wr, lam,
            channels_per_weight=cfg.channels_per_weight,
            row_tile=cfg.row_tile, interpret=cfg.interpret)
    if impl == "xla":
        return _ref.gspn_scan_ref(x, wl, wc, wr, lam)
    if impl == "per_step":
        return _ref.gspn_scan_per_step(x, wl, wc, wr, lam)
    raise ValueError(f"unknown impl {impl!r}")


def _bwd_adjoint_xla(dy, wl_b, wc_b, wr_b):
    """Adjoint scan via lax.scan; weights pre-broadcast to full G. f32 out."""
    zeros = jnp.zeros_like(dy[:, 0], dtype=jnp.float32)

    def body(prods, row):
        dy_r, wl_r, wc_r, wr_r = row
        p_l, p_c, p_r = prods
        g_r = (dy_r.astype(jnp.float32)
               + _ref._shift_left(p_l) + p_c + _ref._shift_right(p_r))
        wf = (wl_r.astype(jnp.float32), wc_r.astype(jnp.float32),
              wr_r.astype(jnp.float32))
        return (wf[0] * g_r, wf[1] * g_r, wf[2] * g_r), g_r

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (dy, wl_b, wc_b, wr_b))
    _, gs = jax.lax.scan(body, (zeros, zeros, zeros), xs, reverse=True)
    return jnp.moveaxis(gs, 0, 1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gspn_core(cfg: ScanConfig, x, wl, wc, wr, lam):
    return _fwd_dispatch(cfg, x, wl, wc, wr, lam)


def _gspn_core_fwd(cfg, x, wl, wc, wr, lam):
    h = _fwd_dispatch(cfg, x, wl, wc, wr, lam)
    return h, (x, wl, wc, wr, lam, h)


def _gspn_core_bwd(cfg, res, dy):
    x, wl, wc, wr, lam, h = res
    g_dim = x.shape[0]
    cpw = cfg.channels_per_weight
    impl = _resolve_impl(cfg.impl)

    if impl == "pallas":
        g = _pk.gspn_scan_bwd_pallas(
            dy, wl, wc, wr, channels_per_weight=cpw,
            row_tile=cfg.row_tile, interpret=cfg.interpret)
    else:
        wl_b = _ref._broadcast_w(wl, g_dim)
        wc_b = _ref._broadcast_w(wc, g_dim)
        wr_b = _ref._broadcast_w(wr, g_dim)
        g = _bwd_adjoint_xla(dy, wl_b, wc_b, wr_b)

    g = g.astype(jnp.float32)
    h32 = h.astype(jnp.float32)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h32[:, :1]), h32[:, :-1]], axis=1)
    dx = (lam.astype(jnp.float32) * g).astype(x.dtype)
    dlam = (x.astype(jnp.float32) * g).astype(lam.dtype)
    dwl = g * _ref._shift_right(h_prev)
    dwc = g * h_prev
    dwr = g * _ref._shift_left(h_prev)
    if cpw > 1:
        gw = g_dim // cpw
        shp = (gw, cpw) + dwl.shape[1:]
        dwl = dwl.reshape(shp).sum(axis=1)
        dwc = dwc.reshape(shp).sum(axis=1)
        dwr = dwr.reshape(shp).sum(axis=1)
    return (dx, dwl.astype(wl.dtype), dwc.astype(wc.dtype),
            dwr.astype(wr.dtype), dlam)


_gspn_core.defvjp(_gspn_core_fwd, _gspn_core_bwd)


def gspn_scan(x, wl, wc, wr, lam, *, chunk: int | None = None,
              impl: str = "auto", row_tile: int | None = None,
              interpret: bool = True):
    """GSPN line scan with optional GSPN-local chunking.

    x, lam: (G, H, W); wl/wc/wr: (G_w, H, W), G_w divides G.
    Returns h: (G, H, W) in x.dtype.  Differentiable in all tensor args.
    """
    g, h, w = x.shape
    gw = wl.shape[0]
    assert g % gw == 0, (g, gw)
    cpw = g // gw

    if chunk is not None and chunk != h:
        assert h % chunk == 0, (h, chunk)
        n = h // chunk
        # Differentiable broadcast + fold; core then runs with cpw=1 so the
        # chunk index can be absorbed into the leading grid dimension.
        wl_b = _ref._broadcast_w(wl, g)
        wc_b = _ref._broadcast_w(wc, g)
        wr_b = _ref._broadcast_w(wr, g)

        def fold(a):
            return a.reshape(g * n, chunk, w)

        cfg = ScanConfig(impl=impl, channels_per_weight=1,
                         row_tile=row_tile, interpret=interpret)
        out = _gspn_core(cfg, fold(x), fold(wl_b), fold(wc_b), fold(wr_b),
                         fold(lam))
        return out.reshape(g, h, w)

    cfg = ScanConfig(impl=impl, channels_per_weight=cpw,
                     row_tile=row_tile, interpret=interpret)
    return _gspn_core(cfg, x, wl, wc, wr, lam)
