"""Empirical kernel autotuner with a persistent cache (DESIGN.md §11).

``kernels/tuning.py`` picks row tiles from a static VMEM model — correct
admission, but blind to what the device actually prefers (the paper's §4.3
occupancy balance is an *empirical* optimum: one warp per channel slice
only wins when the tile shape matches the hardware).  This module closes
the loop the way Triton-style kernels do: enumerate the admissible
configs, **time them** under jit with proper warmup, and persist the
winner to a JSON cache keyed by everything that changes the optimum —

    (device_kind, H, W, C, direction, impl, stream dtype, carry dtype,
     channel_shared)

Resolution order at every launch site (``plan_for_spec``):

1. an explicit ``row_tile=`` argument always wins (never consults us);
2. a cache hit — env-overridable path (``GSPN_TUNE_CACHE``) layered over
   the checked-in seed cache (``tune_cache_seed.json``, recorded in CPU
   interpret mode so CI exercises the hit path) — validated against the
   shape (must divide H, fit the VMEM budget) before use;
3. graceful fallback: the static heuristic ``tuning.pick_row_tile`` with
   the same stream/carry byte accounting (unknown device, cache miss, or
   a stale/invalid entry all land here, silently).

The candidate enumerator is the single source of truth for what the tuner
may emit; the oracle-conformance grid (``tests/test_conformance.py``)
draws from the same enumerator, so any cache entry is by construction a
config the conformance suite has proven safe.

CLI (also the CI cache-artifact producer)::

    PYTHONPATH=src python -m repro.kernels.autotune warm --out tune.json
    PYTHONPATH=src python -m repro.kernels.autotune show
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import sys

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import spec as spec_mod
from repro.kernels import tuning
from repro.kernels.spec import BOUNDARIES, ScanSpec

ENV_CACHE_PATH = "GSPN_TUNE_CACHE"
SEED_CACHE_PATH = pathlib.Path(__file__).with_name("tune_cache_seed.json")
# Schema 2 (PR 6): entries gained a "pipeline_depth" field (1 = the
# revolving-buffer BlockSpec stream, 2 = the explicitly staged pipeline —
# DESIGN.md §12).  Schema-1 files load unchanged: a missing field reads
# as depth 1, reproducing the pre-PR6 kernels exactly.
# Schema 3 (PR 8): keys are the ScanSpec canonical serialization — the
# legacy key plus a trailing "|bnd-{boundary}" leg (DESIGN.md §14).
# Schema-2 files load unchanged: lookup falls back to the legacy
# encoding, so a boundary-less entry serves every boundary mode.
SCHEMA_VERSION = 3

# Heuristic-fallback tile cap — matches gspn_scan.DEFAULT_ROW_TILE so a
# cache miss reproduces the pre-tuner behaviour bit-for-bit.  Measured
# candidates may explore beyond it (ENUM_CAP).
DEFAULT_CAP = 256
ENUM_CAP = 512

# Per-direction kernel geometry: streamed operand count and VMEM carry
# rows (the adjoint kernels hold three tap·adjoint rows, always f32 —
# see gspn_scan._bwd_kernel / gspn_multidir._bwd_pair_kernel).
DIRECTIONS = ("fwd", "bwd", "pair_fwd", "pair_bwd", "quad")
_N_STREAMS = {"fwd": 6, "bwd": 5, "pair_fwd": 6, "pair_bwd": 5, "quad": 6}
_CARRY_ROWS = {"fwd": 1, "bwd": 3, "pair_fwd": 1, "pair_bwd": 3, "quad": 1}

# Pipeline depths the kernels implement (DESIGN.md §12).  Depth 2 (the
# explicitly staged pipeline) is only ever ENUMERATED for narrow streams
# (< 4 bytes): the stage exists to amortise the narrow-dtype widen-on-load
# and sublane retiling over a whole tile, and for f32 streams it is a dead
# VMEM copy that doubles residency for nothing.  The kernels themselves
# accept depth 2 at any dtype (the conformance grid proves both depths
# bit-identical) — the restriction is admission policy, not capability.
PIPELINE_DEPTHS = (1, 2)

# Injectable timer — tests monkeypatch this (or pass ``timer=``) to make
# the measurement harness deterministic.  The default is the repo-wide
# monotonic span clock (DESIGN.md §13) — never wall clock.
_default_timer = obs.monotonic

# Every (key -> plan) resolution this process has made, bounded.  The
# serve engine annotates its decode-step spans with this (DESIGN.md §13)
# so a trace shows exactly which kernel configuration ran.
_RESOLVED_CAP = 256
_RESOLVED: dict[str, tuple[int, int, str]] = {}


def _record_plan(key: "ScanKey", plan: "ScanPlan", source: str):
    if key.encode() not in _RESOLVED and len(_RESOLVED) >= _RESOLVED_CAP:
        return
    prev = _RESOLVED.get(key.encode())
    _RESOLVED[key.encode()] = (plan.row_tile, plan.pipeline_depth, source)
    if prev is None:
        obs.event("kernel.plan", key=key.encode(), row_tile=plan.row_tile,
                  pipeline_depth=plan.pipeline_depth, source=source)


def resolved_plans() -> dict:
    """``key.encode() -> (row_tile, pipeline_depth, source)`` for every
    launch-site resolution so far."""
    return dict(_RESOLVED)


def plans_summary() -> str:
    """Compact one-line view: ``dir@hHxwW/dtype:tT-dD`` per resolved key
    (the decode-step span annotation)."""
    parts = []
    for enc, (t, d, _src) in sorted(_RESOLVED.items()):
        seg = enc.split("|")
        label = "|".join(seg[1:5]) if len(seg) >= 5 else enc
        parts.append(f"{label}:t{t}-d{d}")
    return " ".join(parts)


@functools.lru_cache(maxsize=4)
def device_kind(interpret: bool = False) -> str:
    """Normalised device cache key ('TPU v5e' → 'tpu-v5e').  Interpret-mode
    runs (the CPU validation path) get their own namespace so interpreter
    timings can never leak onto real silicon, and vice versa."""
    kind = jax.devices()[0].device_kind.lower().replace(" ", "-")
    return f"{kind}+interpret" if interpret else kind


@dataclasses.dataclass(frozen=True)
class ScanKey:
    """Everything that changes the empirical optimum of one scan launch."""
    device: str
    h: int                       # scan length (rows per carry segment)
    w: int                       # lane width
    c: int                       # G — flattened (batch, channel) planes
    direction: str               # fwd | bwd | pair_fwd | pair_bwd | quad
    impl: str                    # pallas | multidir
    dtype: str                   # streamed dtype (operand tiles)
    carry_dtype: str             # VMEM carry dtype (f32 under the policy)
    channel_shared: bool         # compact channel propagation active
    boundary: str = "one_shot"   # one_shot | chunk_resume | sp_block_local

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"unknown direction {self.direction!r}; "
                             f"expected one of {DIRECTIONS}")
        if self.boundary not in BOUNDARIES:
            raise ValueError(f"unknown boundary {self.boundary!r}; "
                             f"expected one of {BOUNDARIES}")

    def encode(self) -> str:
        """Schema-3 key: device + shape legs, then the ScanSpec canonical
        serialization verbatim (spec.canonical_key) — appending the
        boundary leg at the END keeps ``plans_summary``'s segment parsing
        and every schema-2 prefix intact."""
        return f"{self.device}|h{self.h}|w{self.w}|c{self.c}|" + \
            spec_mod.canonical_key(self.direction, self.impl, self.dtype,
                                   self.carry_dtype, self.channel_shared,
                                   self.boundary)

    def encode_legacy(self) -> str:
        """The schema-2 key (no boundary leg) — the read-compat fallback
        for caches written before schema 3."""
        return (f"{self.device}|h{self.h}|w{self.w}|c{self.c}"
                f"|{self.direction}|{self.impl}|{self.dtype}"
                f"|carry-{self.carry_dtype}|cs{int(self.channel_shared)}")

    @property
    def stream_bytes(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    @property
    def carry_bytes(self) -> int:
        """VMEM-resident carry bytes per lane: carry rows × itemsize."""
        return _CARRY_ROWS[self.direction] * jnp.dtype(self.carry_dtype).itemsize

    @property
    def n_streams(self) -> int:
        return _N_STREAMS[self.direction]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One tunable layout.  ``row_tile`` is the tile knob that reaches the
    kernel (rows per sequential grid step — the grid split is ``h //
    row_tile``); ``double_buffer`` is the admission layout: True reserves
    prefetch headroom for pipelining (the safe default), False admits
    larger tiles that fit only single-buffered (the aggressive layout the
    measurement decides on).  ``pipeline_depth`` selects the kernel
    structure itself: 1 = the revolving-buffer BlockSpec stream (the
    pre-PR6 kernels, bit-for-bit), 2 = the explicitly staged pipeline
    (DESIGN.md §12: bulk widen-on-load input stages + f32 out-stage with
    one downcast writeback per tile)."""
    row_tile: int
    double_buffer: bool = True
    pipeline_depth: int = 1

    def working_set(self, key: ScanKey) -> int:
        return tuning.scan_working_set(
            self.row_tile, key.w, key.stream_bytes, key.n_streams,
            double_buffer=self.double_buffer,
            carry_dtype_bytes=key.carry_bytes,
            pipeline_depth=self.pipeline_depth)


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """What a launch site needs from the tuner: the tile AND the pipeline
    structure (``row_tile_for`` survives as the tile-only view)."""
    row_tile: int
    pipeline_depth: int = 1


def depth_admissible(key: ScanKey, pipeline_depth: int) -> bool:
    """Admission policy for the staged pipeline: depth 2 only pays for
    narrow (< 4-byte) streams — see PIPELINE_DEPTHS."""
    if pipeline_depth == 1:
        return True
    return pipeline_depth == 2 and key.stream_bytes < 4


def heuristic_pipeline_depth(key: ScanKey) -> int:
    """Static-fallback depth: the staged pipeline for narrow streams
    (bf16/fp8), the classic stream for full-width f32."""
    return 2 if key.stream_bytes < 4 else 1


def enumerate_candidates(key: ScanKey, *,
                         vmem_budget: int = tuning.VMEM_BYTES,
                         cap: int = ENUM_CAP) -> list[Candidate]:
    """All configs the tuner may time (and therefore emit) for ``key``:
    power-of-two divisors of the scan length whose working set fits the
    VMEM budget — double-buffered where possible, single-buffered as the
    aggressive extension — at every admissible pipeline depth (depth 2
    only for narrow streams).  Deduplicated on ``(row_tile,
    pipeline_depth)`` (the knobs that reach the kernel), keeping the
    double-buffered admission label."""
    out: list[Candidate] = []
    seen: set[tuple[int, int]] = set()
    t = 1
    while t <= cap and key.h % t == 0:
        for depth in PIPELINE_DEPTHS:
            if not depth_admissible(key, depth):
                continue
            for db in (True, False):
                cand = Candidate(row_tile=t, double_buffer=db,
                                 pipeline_depth=depth)
                if (t, depth) not in seen \
                        and cand.working_set(key) <= vmem_budget:
                    seen.add((t, depth))
                    out.append(cand)
        t *= 2
    return out


def heuristic_row_tile(key: ScanKey, *, cap: int = DEFAULT_CAP,
                       vmem_budget: int = tuning.VMEM_BYTES,
                       pipeline_depth: int | None = None) -> int:
    """The static-VMEM-model fallback — identical accounting to the
    pre-tuner call sites (cache miss ⇒ unchanged behaviour).  The depth
    defaults to the heuristic depth for the key's stream dtype so the
    fallback tile is admissible for the kernel structure it will run."""
    depth = (heuristic_pipeline_depth(key) if pipeline_depth is None
             else pipeline_depth)
    return tuning.pick_row_tile(
        key.h, key.w, key.stream_bytes, vmem_budget=vmem_budget, cap=cap,
        n_streams=key.n_streams, carry_dtype_bytes=key.carry_bytes,
        pipeline_depth=depth).row_tile


# ---------------------------------------------------------------------------
# Persistent cache.
# ---------------------------------------------------------------------------

class TuningCache:
    """JSON-backed ``key.encode() -> entry`` map.

    Entries are plain dicts: ``{"row_tile", "double_buffer", "us",
    "n_grid_steps", "working_set_bytes", "source"}``.  Corrupt or
    missing files load as empty caches (the tuner must never take the
    serving path down)."""

    def __init__(self, entries: dict | None = None,
                 path: str | os.PathLike | None = None):
        self.entries: dict[str, dict] = dict(entries or {})
        self.path = pathlib.Path(path) if path else None

    @classmethod
    def load(cls, path) -> "TuningCache":
        path = pathlib.Path(path)
        try:
            payload = json.loads(path.read_text())
            entries = payload.get("entries", {})
            if not isinstance(entries, dict):
                raise ValueError("entries is not a mapping")
        except (OSError, ValueError, json.JSONDecodeError) as exc:
            print(f"[autotune] ignoring unreadable cache {path}: {exc!r}",
                  file=sys.stderr)
            entries = {}
        return cls(entries, path=path)

    def save(self, path=None) -> pathlib.Path:
        path = pathlib.Path(path) if path else self.path
        if path is None:
            raise ValueError("no cache path to save to")
        payload = {"schema": SCHEMA_VERSION, "entries": self.entries}
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
        self.path = path
        return path

    def lookup(self, key: ScanKey) -> dict | None:
        """Schema-3 key first, then the schema-2 legacy encoding: a
        boundary-less pre-schema-3 entry serves every boundary mode (the
        tile optimum does not depend on how the segment resumes)."""
        hit = self.entries.get(key.encode())
        if hit is not None:
            return hit
        return self.entries.get(key.encode_legacy())

    def store(self, key: ScanKey, entry: dict):
        self.entries[key.encode()] = dict(entry)

    def merge(self, other: "TuningCache"):
        self.entries.update(other.entries)

    def __len__(self):
        return len(self.entries)


_CACHE: TuningCache | None = None


def get_cache(reload: bool = False) -> TuningCache:
    """Process-global cache: checked-in seed, overlaid (entries win) by
    the ``GSPN_TUNE_CACHE`` path when set."""
    global _CACHE
    if _CACHE is None or reload:
        cache = (TuningCache.load(SEED_CACHE_PATH)
                 if SEED_CACHE_PATH.exists() else TuningCache())
        env = os.environ.get(ENV_CACHE_PATH)
        if env:
            cache.merge(TuningCache.load(env))
            cache.path = pathlib.Path(env)
        _CACHE = cache
    return _CACHE


def load_cache(path) -> int:
    """Layer an explicit cache file over the global cache (the launchers'
    ``--tune-cache`` flag).  Returns the number of entries loaded."""
    extra = TuningCache.load(path)
    cache = get_cache()
    cache.merge(extra)
    cache.path = extra.path
    return len(extra)


def _entry_depth(entry: dict) -> int:
    """Pipeline depth recorded in a cache entry; schema-1 entries (no
    field) read as depth 1 — the pre-PR6 kernel structure."""
    try:
        return int(entry.get("pipeline_depth", 1))
    except (TypeError, ValueError):
        return -1


def _entry_invalid_reason(key: ScanKey, entry: dict, *,
                          vmem_budget: int = tuning.VMEM_BYTES) -> str | None:
    """Why a cache entry cannot be honoured for this key, or ``None`` when
    it is valid: the row tile must be a power of two dividing H, the
    pipeline depth known, and the minimal (single-buffered) working set at
    that depth must fit the budget.  ``plan_for`` turns a non-None reason
    into an obs counter + event so a corrupted or stale cache is visible
    instead of silently degrading to the heuristic."""
    try:
        t = int(entry["row_tile"])
    except (KeyError, TypeError, ValueError):
        return f"row_tile missing or non-integer: {entry.get('row_tile')!r}"
    if t < 1 or (t & (t - 1)):
        return f"row_tile {t} is not a positive power of two"
    if key.h % t:
        return f"row_tile {t} does not divide h={key.h}"
    depth = _entry_depth(entry)
    if depth not in PIPELINE_DEPTHS:
        return (f"pipeline_depth {entry.get('pipeline_depth')!r} not in "
                f"{PIPELINE_DEPTHS}")
    ws = Candidate(t, double_buffer=False,
                   pipeline_depth=depth).working_set(key)
    if ws > vmem_budget:
        return f"working set {ws}B exceeds VMEM budget {vmem_budget}B"
    return None


def _entry_valid(key: ScanKey, entry: dict, *,
                 vmem_budget: int = tuning.VMEM_BYTES) -> bool:
    """Boolean view of :func:`_entry_invalid_reason`."""
    return _entry_invalid_reason(key, entry, vmem_budget=vmem_budget) is None


def plan_for_spec(spec: ScanSpec, h: int, w: int, *, c: int = 0,
                  cache: TuningCache | None = None,
                  cap: int = DEFAULT_CAP) -> ScanPlan:
    """THE launch-site planning entry point: tuned ``(row_tile,
    pipeline_depth)`` if the cache knows this (device, shape, spec-policy)
    key, heuristic otherwise.  The cache key is the spec's canonical
    serialization (``ScanKey.encode`` ends with ``spec.canonical()``)
    plus the device and shape legs.  The spec's explicit ``row_tile`` /
    ``pipeline_depth`` fields always win; an explicit tile bypasses the
    cache entirely (a measured entry's depth belongs to the tile it was
    measured with) and takes the heuristic depth unless one is given.

    Every fused-scan launch (fwd, bwd, pair, quad — and through them the
    chunked-prefill and sp block-local paths) funnels here, so one cache
    governs the whole stack.  The kwargs-style :func:`plan_for` survives
    only as a deprecation shim over this function."""
    key = ScanKey(device_kind(spec.interpret), h, w, c, spec.direction,
                  spec.impl, str(jnp.dtype(spec.stream_dtype)),
                  str(jnp.dtype(spec.carry_dtype)),
                  spec.channel_shared, spec.boundary)
    if spec.row_tile is not None:
        depth = (heuristic_pipeline_depth(key) if spec.pipeline_depth is None
                 else spec.pipeline_depth)
        plan = ScanPlan(spec.row_tile, depth)
        _record_plan(key, plan, "explicit")
        return plan
    cache = cache if cache is not None else get_cache()
    entry = cache.lookup(key)
    if entry is not None:
        reason = _entry_invalid_reason(key, entry)
        if reason is None:
            t, depth = int(entry["row_tile"]), _entry_depth(entry)
            source = "cache"
        else:
            # A present-but-unusable entry is a signal (corrupt file,
            # stale shape, hand-edited cache) — count it and log why
            # before degrading to the heuristic.
            obs.counter("autotune_cache_rejects_total").inc()
            obs.event("autotune.cache_reject", key=key.encode(),
                      reason=reason)
            entry = None
    if entry is None:
        depth = heuristic_pipeline_depth(key)
        t = heuristic_row_tile(key, cap=cap, pipeline_depth=depth)
        source = "heuristic"
    if spec.pipeline_depth is not None:
        depth = spec.pipeline_depth
    plan = ScanPlan(t, depth)
    _record_plan(key, plan, source)
    return plan


# Warn-once latch for the deprecated kwargs-style entry points.  Module
# state (not functools caching) so a test can reset it explicitly.
_plan_for_warned = False


def _spec_from_kwargs(direction, impl, dtype, carry_dtype, channel_shared,
                      interpret, row_tile, pipeline_depth,
                      boundary) -> ScanSpec:
    """Fold the legacy loose-kwargs planning surface into a ScanSpec.
    ``channel_shared`` is a bool in the old surface; the spec carries the
    actual channel count, but only the >1 bit reaches the cache key, so
    any shared count reproduces the legacy key exactly."""
    return ScanSpec(direction=direction, impl=impl,
                    channels_per_weight=2 if channel_shared else 1,
                    stream_dtype=str(jnp.dtype(dtype)),
                    carry_dtype=str(jnp.dtype(carry_dtype)),
                    row_tile=row_tile, pipeline_depth=pipeline_depth,
                    boundary=boundary, interpret=interpret)


def plan_for(h: int, w: int, *, c: int = 0, direction: str = "fwd",
             impl: str = "pallas", dtype="float32",
             carry_dtype="float32", channel_shared: bool = False,
             interpret: bool = False, cache: TuningCache | None = None,
             cap: int = DEFAULT_CAP, row_tile: int | None = None,
             pipeline_depth: int | None = None,
             boundary: str = "one_shot") -> ScanPlan:
    """DEPRECATED kwargs-style shim over :func:`plan_for_spec` — builds
    the equivalent ScanSpec and forwards.  Kept so pre-spec callers keep
    resolving identical plans (pinned by tests/test_autotune.py); new
    code should construct a :class:`ScanSpec` and call
    :func:`plan_for_spec`.  Warns once per process."""
    global _plan_for_warned
    if not _plan_for_warned:
        _plan_for_warned = True
        import warnings
        warnings.warn(
            "autotune.plan_for is deprecated; construct a ScanSpec and "
            "call plan_for_spec", DeprecationWarning, stacklevel=2)
    spec = _spec_from_kwargs(direction, impl, dtype, carry_dtype,
                             channel_shared, interpret, row_tile,
                             pipeline_depth, boundary)
    return plan_for_spec(spec, h, w, c=c, cache=cache, cap=cap)


def row_tile_for(h: int, w: int, *, c: int = 0, direction: str = "fwd",
                 impl: str = "pallas", dtype="float32",
                 carry_dtype="float32", channel_shared: bool = False,
                 interpret: bool = False, cache: TuningCache | None = None,
                 cap: int = DEFAULT_CAP) -> int:
    """Tile-only view of :func:`plan_for_spec` (kept for callers that
    manage the pipeline structure themselves)."""
    spec = _spec_from_kwargs(direction, impl, dtype, carry_dtype,
                             channel_shared, interpret, None, None,
                             "one_shot")
    return plan_for_spec(spec, h, w, c=c, cache=cache, cap=cap).row_tile


# ---------------------------------------------------------------------------
# Measurement harness.
# ---------------------------------------------------------------------------

def measure(fn, *, iters: int = 3, warmup: int = 1, timer=None) -> float:
    """Median wall seconds of ``fn()`` with ``block_until_ready``.
    ``timer`` is injectable (defaults to the module's ``_default_timer``)
    so tests can drive the harness deterministically."""
    timer = timer or _default_timer
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = timer()
        jax.block_until_ready(fn())
        times.append(timer() - t0)
    times.sort()
    return times[len(times) // 2]


def _make_operands(key: ScanKey, seed: int = 0):
    """Synthetic operands matching the key's layout.  Taps are softmaxed
    per position (row-stochastic-ish) so timings run on realistic
    magnitudes; the tuner never checks numerics — the conformance grid
    owns that."""
    dtype = jnp.dtype(key.dtype)
    g = max(key.c, 1)
    gw = 1 if key.channel_shared else g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (g, key.h, key.w), jnp.float32)
    lam = jax.nn.sigmoid(
        jax.random.normal(ks[1], (g, key.h, key.w), jnp.float32))
    taps = jax.nn.softmax(
        jax.random.normal(ks[2], (gw, key.h, key.w, 3), jnp.float32), axis=-1)
    wl, wc, wr = taps[..., 0], taps[..., 1], taps[..., 2]
    cast = lambda a: a.astype(dtype)
    return tuple(map(cast, (x, wl, wc, wr, lam))), g // gw


def default_runner_factory(key: ScanKey, *, interpret: bool = True,
                           seed: int = 0):
    """Builds, per candidate, a zero-arg jitted launch of the ACTUAL
    kernel the key describes (lazy kernel imports — this module is
    imported by the kernels themselves)."""
    from repro.kernels import gspn_multidir as _mk
    from repro.kernels import gspn_scan as _pk

    (x, wl, wc, wr, lam), cpw = _make_operands(key, seed)

    def factory(cand: Candidate):
        # The candidate's knobs travel as ONE ScanSpec — the same object
        # a production launch site would hand down (DESIGN.md §14).
        sp = ScanSpec(direction=key.direction, impl=key.impl,
                      channels_per_weight=max(cpw, 1),
                      stream_dtype=key.dtype, carry_dtype=key.carry_dtype,
                      row_tile=cand.row_tile,
                      pipeline_depth=cand.pipeline_depth,
                      boundary=key.boundary, interpret=interpret)
        if key.direction == "fwd":
            run = jax.jit(lambda *a: _pk.gspn_scan_fwd_pallas(*a, spec=sp))
            args = (x, wl, wc, wr, lam)
        elif key.direction == "bwd":
            run = jax.jit(lambda *a: _pk.gspn_scan_bwd_pallas(*a, spec=sp))
            args = (x, wl, wc, wr)          # x stands in for dy
        elif key.direction == "pair_fwd":
            pair = lambda a: jnp.stack([a, a])
            run = jax.jit(lambda xx, l2, w2, c2, r2: _mk.gspn_scan_bidir_pallas(
                xx, {"wl": w2, "wc": c2, "wr": r2}, l2, spec=sp))
            args = (x, pair(lam), pair(wl), pair(wc), pair(wr))
        elif key.direction == "pair_bwd":
            pair = lambda a: jnp.stack([a, a])
            run = jax.jit(lambda d2, w2, c2, r2: _mk.gspn_scan_bidir_bwd_pallas(
                d2, w2, c2, r2, spec=sp))
            args = (pair(x), pair(wl), pair(wc), pair(wr))
        elif key.direction == "quad":
            quad = lambda a: jnp.stack([a] * 4)
            run = jax.jit(lambda xx, l4, w4, c4, r4: _mk.gspn_scan_quad_pallas(
                xx, {"wl": w4, "wc": c4, "wr": r4}, l4, spec=sp))
            args = (x, quad(lam), quad(wl), quad(wc), quad(wr))
        else:  # pragma: no cover — ScanKey.__post_init__ guards this
            raise ValueError(key.direction)
        return lambda: run(*args)

    return factory


def autotune_key(key: ScanKey, *, candidates=None, iters: int = 3,
                 warmup: int = 1, cache: TuningCache | None = None,
                 timer=None, runner_factory=None,
                 interpret: bool = True) -> dict:
    """Time every candidate for ``key`` and cache the winner.

    The candidate list always contains the heuristic's choice (the
    enumerator admits every tile the heuristic may pick), so the measured
    winner is never slower than the heuristic beyond timing noise.
    Returns the stored entry; ties break toward the first (smallest,
    double-buffered) candidate, making the harness deterministic under a
    fixed candidate list and timer.
    """
    cands = list(candidates if candidates is not None
                 else enumerate_candidates(key))
    cache = cache if cache is not None else get_cache()
    if not cands:
        entry = {"row_tile": heuristic_row_tile(key), "double_buffer": True,
                 "pipeline_depth": heuristic_pipeline_depth(key),
                 "us": None, "n_grid_steps": None, "working_set_bytes": None,
                 "source": "heuristic"}
        cache.store(key, entry)
        return entry
    if runner_factory is None:
        runner_factory = default_runner_factory(key, interpret=interpret)

    timed: list[tuple[float, Candidate]] = []
    with obs.trace("autotune.key", key=key.encode(),
                   n_candidates=len(cands)):
        for cand in cands:
            fn = runner_factory(cand)
            with obs.trace("autotune.measure", row_tile=cand.row_tile,
                           pipeline_depth=cand.pipeline_depth):
                us = measure(fn, iters=iters, warmup=warmup,
                             timer=timer) * 1e6
            obs.event("autotune.candidate", key=key.encode(),
                      row_tile=cand.row_tile,
                      pipeline_depth=cand.pipeline_depth, us=round(us, 3))
            timed.append((us, cand))
    obs.counter("autotune_keys_measured_total").inc()
    obs.counter("autotune_candidates_timed_total").inc(len(timed))
    best_us, best = min(timed, key=lambda r: r[0])
    entry = {
        "row_tile": best.row_tile,
        "double_buffer": best.double_buffer,
        "pipeline_depth": best.pipeline_depth,
        "us": round(best_us, 3),
        "n_grid_steps": key.h // best.row_tile,
        "working_set_bytes": best.working_set(key),
        "source": "measured",
    }
    cache.store(key, entry)
    return entry


# ---------------------------------------------------------------------------
# Warm list + CLI (the CI tuning-cache artifact producer).
# ---------------------------------------------------------------------------

# (h, w, c, direction, impl, dtype, channel_shared) — the smoke-ladder and
# test shapes; carry follows the §10 policy (f32; adjoints are always f32).
WARM_SPECS = [
    (64, 64, 8, "fwd", "pallas", "float32", True),
    (64, 64, 8, "fwd", "pallas", "bfloat16", True),
    (64, 64, 8, "bwd", "pallas", "float32", True),
    (64, 64, 8, "pair_fwd", "multidir", "float32", True),
    (128, 128, 8, "fwd", "pallas", "float32", True),
    (128, 128, 8, "fwd", "pallas", "bfloat16", True),
    (128, 128, 8, "bwd", "pallas", "float32", True),
    (128, 128, 8, "bwd", "pallas", "bfloat16", True),
    (128, 128, 8, "pair_fwd", "multidir", "float32", True),
    (128, 128, 8, "pair_fwd", "multidir", "bfloat16", True),
    (128, 128, 8, "pair_bwd", "multidir", "float32", True),
    (192, 192, 8, "fwd", "pallas", "float32", True),
]


def warm(specs=None, *, cache: TuningCache | None = None, iters: int = 2,
         warmup: int = 1, interpret: bool = True, verbose: bool = True):
    """Tune every spec on the current device and return the cache."""
    cache = cache if cache is not None else get_cache()
    for h, w, c, direction, impl, dtype, cs in (specs or WARM_SPECS):
        key = ScanKey(device_kind(interpret), h, w, c, direction, impl,
                      str(jnp.dtype(dtype)), "float32", cs)
        entry = autotune_key(key, iters=iters, warmup=warmup, cache=cache,
                             interpret=interpret)
        if verbose:
            print(f"[autotune] {key.encode()} -> row_tile="
                  f"{entry['row_tile']} depth={entry['pipeline_depth']} "
                  f"({entry['us']}us)", file=sys.stderr)
    return cache


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.kernels.autotune")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_warm = sub.add_parser("warm", help="measure the built-in warm list")
    ap_warm.add_argument("--out", default="",
                         help="write the cache here (default: seed path)")
    ap_warm.add_argument("--iters", type=int, default=2)
    ap_warm.add_argument("--warmup", type=int, default=1,
                         help="discarded runs per candidate before timing "
                              "(2+ recommended when re-measuring the seed)")
    sub.add_parser("show", help="print the resolved cache")
    args = ap.parse_args(argv)

    if args.cmd == "warm":
        # Measure into a FRESH cache: the artifact must contain only this
        # device's fresh measurements, never the layered seed/env entries.
        cache = warm(cache=TuningCache(), iters=args.iters,
                     warmup=args.warmup)
        path = cache.save(args.out or SEED_CACHE_PATH)
        print(f"[autotune] wrote {len(cache)} entries to {path}")
        return 0
    if args.cmd == "show":
        cache = get_cache(reload=True)
        print(json.dumps({"schema": SCHEMA_VERSION,
                          "entries": cache.entries}, indent=1,
                         sort_keys=True))
        return 0
    return 2  # pragma: no cover


if __name__ == "__main__":
    sys.exit(main())
