"""Fused dual-direction GSPN scan — the TPU analogue of the paper's §4.3
stream-based concurrency.

GSPN-1 ran the four directional passes as separate kernel streams; on TPU
we fuse opposite directions (T→B and B→T) into ONE ``pallas_call`` whose
leading grid axis selects the direction.  The input ``x`` tile is shared
between both directions via the BlockSpec index map — each x/λ tile
streams from HBM once per direction pair instead of once per direction in
the flipped copy the naive path materialises, and the sequential grid
gives the scheduler twice the pipelineable work per launch.

Direction handling is pure index arithmetic: for d=1 (B→T) the H tiles
are visited in reverse (index_map) and rows within a tile iterate
backwards (in-kernel ``r_eff``).  No flipped copies of any operand exist.

Layout: x (G, H, W); taps/lam stacked per direction (2, G_w, H, W) /
(2, G, H, W).  Output (2, G, H, W): out[0] = T→B scan, out[1] = B→T scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gspn_scan import (_row, _shift_left, _shift_right,
                                     pick_row_tile)


def _kernel(row_tile,
            x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
    d = pl.program_id(0)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def body(r, h_prev):
        # T->B walks rows forward; B->T walks them backward.
        r_eff = jnp.where(d == 0, r, row_tile - 1 - r)
        h_new = (
            _row(wl_ref, r_eff) * _shift_right(h_prev)
            + _row(wc_ref, r_eff) * h_prev
            + _row(wr_ref, r_eff) * _shift_left(h_prev)
            + _row(lam_ref, r_eff) * _row(x_ref, r_eff)
        )
        o_ref[0, pl.dslice(r_eff, 1), :] = h_new.astype(o_ref.dtype)
        return h_new

    carry_ref[...] = jax.lax.fori_loop(0, row_tile, body, carry_ref[...])


def gspn_scan_bidir_pallas(x, taps, lam2, *, channels_per_weight: int = 1,
                           row_tile: int | None = None,
                           interpret: bool = True):
    """x: (G, H, W); taps: dict with wl/wc/wr each (2, G_w, H, W);
    lam2: (2, G, H, W).  Returns (2, G, H, W) — both directional scans."""
    g, h, w = x.shape
    cpw = channels_per_weight
    row_tile = row_tile or pick_row_tile(h)
    assert h % row_tile == 0
    n_tiles = h // row_tile

    def ti_eff(d, ti):
        return jnp.where(d == 0, ti, n_tiles - 1 - ti)

    # x is SHARED: both directions read the same tiles (in opposite order).
    x_spec = pl.BlockSpec((1, row_tile, w),
                          lambda d, gi, ti: (gi, ti_eff(d, ti), 0))
    wt_spec = pl.BlockSpec((1, 1, row_tile, w),
                           lambda d, gi, ti: (d, gi // cpw, ti_eff(d, ti), 0))
    lam_spec = pl.BlockSpec((1, 1, row_tile, w),
                            lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))
    out_spec = pl.BlockSpec((1, 1, row_tile, w),
                            lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))

    def kernel(x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
        _kernel(row_tile, x_ref,
                wl_ref.at[0], wc_ref.at[0], wr_ref.at[0], lam_ref.at[0],
                o_ref.at[0], carry_ref)

    return pl.pallas_call(
        kernel,
        grid=(2, g, n_tiles),
        in_specs=[x_spec, wt_spec, wt_spec, wt_spec, lam_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((2, g, h, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, w), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",) * 3),
        interpret=interpret,
    )(x, taps["wl"], taps["wc"], taps["wr"], lam2)
