"""Fused multi-direction GSPN scan — the TPU analogue of the paper's §4.3
stream-based concurrency (DESIGN.md §2).

GSPN-1 ran the four directional passes as separate kernel streams; here
opposite directions are fused into ONE ``pallas_call`` whose leading grid
axis selects the direction:

* :func:`gspn_scan_bidir_pallas` — forward scan for one opposite pair
  (canonical top→bottom plus its bottom→top mirror).  The input ``x`` tile
  is shared between both directions via the BlockSpec index map — each x
  tile streams from HBM once per direction pair instead of once per
  direction in the flipped copy the naive path materialises, and the
  sequential grid gives the scheduler twice the pipelineable work per
  launch.
* :func:`gspn_scan_bidir_bwd_pallas` — the fused adjoint of the pair:
  direction 0's adjoint walks rows last→first, direction 1's first→last,
  again in one launch with no flipped copies.
* :func:`gspn_scan_quad_pallas` — all FOUR directions in a single launch
  for square grids: ``x`` and its transpose are stacked once at the
  dispatch boundary and the index map picks the orientation per direction
  (``d // 2``).  Forward-only; used by the benchmark ladder to demonstrate
  the paper's single-launch design point.

A full four-direction dispatch (the L→R/R→L pair handled by one transpose
at the dispatch boundary) therefore costs **two** launches for arbitrary
H×W — see ``repro.core.gspn.directional_scan`` — or one for square grids.

Direction handling is pure index arithmetic: for the reverse member of a
pair the H tiles are visited in reverse (index_map) and rows within a tile
iterate backwards (in-kernel ``r_eff``).  No flipped copies of any operand
exist in either the forward or the adjoint pass.

Layout: x (G, H, W); taps/lam stacked per direction (2, G_w, H, W) /
(2, G, H, W).  Output (2, G, H, W): out[0] = top→bottom scan, out[1] =
bottom→top scan (both in the UNFLIPPED layout of x).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.kernels import autotune
from repro.kernels.gspn_scan import (CompilerParams, _dir_scan, _masked_shifts,
                                     _row, _shift_left, _shift_right,
                                     _stage_rows)
from repro.kernels.spec import ScanSpec


def _launch_span(name, plan, dtype, g, h, w):
    """Traced-launch span for the fused kernels (DESIGN.md §13): fires
    once per jit trace, annotated with the tuner-resolved plan."""
    return obs.trace("kernel.launch", kernel=name, row_tile=plan.row_tile,
                     pipeline_depth=plan.pipeline_depth,
                     dtype=str(jnp.dtype(dtype)), g=g, h=h, w=w)


def _pair_spec(spec: ScanSpec | None, direction: str, dtype, *,
               channels_per_weight: int = 1, carry_dtype=jnp.float32,
               interpret: bool = True, row_tile: int | None = None,
               pipeline_depth: int | None = None) -> ScanSpec:
    """Build (from legacy kwargs) or normalise the spec of one fused
    pair/quad launch: these entry points own the ``multidir`` impl leg,
    the direction, and the streamed dtype (always the operands')."""
    if spec is None:
        spec = ScanSpec(channels_per_weight=channels_per_weight,
                        carry_dtype=str(jnp.dtype(carry_dtype)),
                        row_tile=row_tile, pipeline_depth=pipeline_depth,
                        interpret=interpret)
    changes = dict(direction=direction, impl="multidir",
                   stream_dtype=str(jnp.dtype(dtype)))
    if direction == "pair_bwd":
        changes["carry_dtype"] = "float32"   # adjoint carry is always f32
    return spec.with_(**changes)


def _pair_plan(spec: ScanSpec, h: int, w: int, c: int) -> "autotune.ScanPlan":
    """Tile + pipeline depth for the fused pair/quad kernels: measured
    cache entry when the tuner knows this spec's canonical key at this
    (device, shape), VMEM-heuristic fallback otherwise (DESIGN.md
    §11/§12/§14).  The fallback shares the single-direction kernels' cap
    so fused/unfused tile identically on a cache miss."""
    return autotune.plan_for_spec(spec, h, w, c=c)


# ---------------------------------------------------------------------------
# Forward pair kernel.
# ---------------------------------------------------------------------------

def _kernel(row_tile,
            x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
    d = pl.program_id(0)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def body(r, h_prev):
        # T->B walks rows forward; B->T walks them backward.
        r_eff = jnp.where(d % 2 == 0, r, row_tile - 1 - r)
        h_new = (
            _row(wl_ref, r_eff) * _shift_right(h_prev)
            + _row(wc_ref, r_eff) * h_prev
            + _row(wr_ref, r_eff) * _shift_left(h_prev)
            + _row(lam_ref, r_eff) * _row(x_ref, r_eff)
        )
        o_ref[0, pl.dslice(r_eff, 1), :] = h_new.astype(o_ref.dtype)
        return h_new

    # f32 row recurrence; cross-tile carry stored in the scratch's dtype.
    carry_ref[...] = jax.lax.fori_loop(
        0, row_tile, body,
        carry_ref[...].astype(jnp.float32)).astype(carry_ref.dtype)


def _kernel_staged(row_tile, cpw,
                   x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref,
                   carry_ref):
    """Depth-2 pair/quad forward kernel: all planes of one direction per
    grid step, staged streams (DESIGN.md §12).  The refs arrive with the
    direction axis already peeled (``.at[0]``); same f32 recurrence and
    operation order as ``_kernel`` vectorised over the plane axis.  The
    sequential loop is a ref-free ``_dir_scan`` whose row direction
    follows the grid's direction axis — no staged data is ever flipped
    (identical values row for row to the legacy ``r_eff`` walk)."""
    del row_tile
    d = pl.program_id(0)
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    xs = _stage_rows(x_ref)                         # (T, G, W) f32
    lams = _stage_rows(lam_ref)
    wls = _stage_rows(wl_ref, cpw)
    wcs = _stage_rows(wc_ref, cpw)
    wrs = _stage_rows(wr_ref, cpw)
    sr, sl = _masked_shifts(xs.shape[1:])

    # lam*x stays inside the step — see the parity note in
    # gspn_scan._fwd_kernel_staged (FMA contraction vs depth 1).
    def step(h_prev, row):
        x_r, wl_r, wc_r, wr_r, lam_r = row
        h_new = (
            wl_r * sr(h_prev)
            + wc_r * h_prev
            + wr_r * sl(h_prev)
            + lam_r * x_r
        )
        return h_new, h_new

    h0 = carry_ref[...].astype(jnp.float32)[:, 0, :]         # (G, W)
    # T->B walks rows forward; B->T walks them backward.
    h_last, ys = _dir_scan(step, h0, (xs, wls, wcs, wrs, lams),
                           d % 2 != 0)
    carry_ref[...] = h_last[:, None, :].astype(carry_ref.dtype)
    o_ref[...] = jnp.swapaxes(ys, 0, 1).astype(o_ref.dtype)


def gspn_scan_bidir_pallas(x, taps, lam2, *, spec: ScanSpec | None = None,
                           channels_per_weight: int = 1,
                           row_tile: int | None = None,
                           interpret: bool = True,
                           carry_dtype=jnp.float32,
                           pipeline_depth: int | None = None):
    """x: (G, H, W); taps: dict with wl/wc/wr each (2, G_w, H, W);
    lam2: (2, G, H, W).  Returns (2, G, H, W) — both directional scans.
    Configuration travels as ONE ``ScanSpec`` (DESIGN.md §14; the loose
    kwargs are the legacy construction path): streams in the operands'
    dtype, carries in ``spec.carry_dtype``; ``pipeline_depth=2`` is the
    staged pipeline (DESIGN.md §12)."""
    g, h, w = x.shape
    spec = _pair_spec(spec, "pair_fwd", x.dtype,
                      channels_per_weight=channels_per_weight,
                      carry_dtype=carry_dtype, interpret=interpret,
                      row_tile=row_tile, pipeline_depth=pipeline_depth)
    cpw = spec.channels_per_weight
    gw = g // cpw
    carry_dtype = jnp.dtype(spec.carry_dtype)
    interpret = spec.interpret
    plan = _pair_plan(spec, h, w, g)
    row_tile, pipeline_depth = plan.row_tile, plan.pipeline_depth
    assert h % row_tile == 0
    assert pipeline_depth in (1, 2), pipeline_depth
    n_tiles = h // row_tile

    def ti_eff(d, ti):
        return jnp.where(d == 0, ti, n_tiles - 1 - ti)

    if pipeline_depth == 1:
        # x is SHARED: both directions read the same tiles (opposite order).
        x_spec = pl.BlockSpec((1, row_tile, w),
                              lambda d, gi, ti: (gi, ti_eff(d, ti), 0))
        wt_spec = pl.BlockSpec(
            (1, 1, row_tile, w),
            lambda d, gi, ti: (d, gi // cpw, ti_eff(d, ti), 0))
        lam_spec = pl.BlockSpec((1, 1, row_tile, w),
                                lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))
        out_spec = pl.BlockSpec((1, 1, row_tile, w),
                                lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))

        def kernel(x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
            _kernel(row_tile, x_ref,
                    wl_ref.at[0], wc_ref.at[0], wr_ref.at[0], lam_ref.at[0],
                    o_ref.at[0], carry_ref)

        call = pl.pallas_call(
            kernel,
            grid=(2, g, n_tiles),
            in_specs=[x_spec, wt_spec, wt_spec, wt_spec, lam_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((2, g, h, w), x.dtype),
            scratch_shapes=[pltpu.VMEM((1, w), carry_dtype)],
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",) * 3),
            interpret=interpret,
        )
        with _launch_span("gspn_pair_fwd", plan, x.dtype, g, h, w):
            return call(x, taps["wl"], taps["wc"], taps["wr"], lam2)

    x_spec = pl.BlockSpec((g, row_tile, w),
                          lambda d, ti: (0, ti_eff(d, ti), 0))
    wt_spec = pl.BlockSpec((1, gw, row_tile, w),
                           lambda d, ti: (d, 0, ti_eff(d, ti), 0))
    lam_spec = pl.BlockSpec((1, g, row_tile, w),
                            lambda d, ti: (d, 0, ti_eff(d, ti), 0))
    out_spec = pl.BlockSpec((1, g, row_tile, w),
                            lambda d, ti: (d, 0, ti_eff(d, ti), 0))

    def kernel(x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
        _kernel_staged(row_tile, cpw, x_ref,
                       wl_ref.at[0], wc_ref.at[0], wr_ref.at[0],
                       lam_ref.at[0], o_ref.at[0], carry_ref)

    call = pl.pallas_call(
        kernel,
        grid=(2, n_tiles),
        in_specs=[x_spec, wt_spec, wt_spec, wt_spec, lam_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((2, g, h, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1, w), carry_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",) * 2),
        interpret=interpret,
    )
    with _launch_span("gspn_pair_fwd", plan, x.dtype, g, h, w):
        return call(x, taps["wl"], taps["wc"], taps["wr"], lam2)


# ---------------------------------------------------------------------------
# Adjoint pair kernel.
#
# The adjoint of the top→bottom scan walks rows from LAST to FIRST; the
# adjoint of the bottom→top scan walks FIRST to LAST — so the fused adjoint
# is the forward pair kernel's traversal with the direction roles swapped.
# The carry holds the three tap*adjoint products of the previously
# processed row:
#     d=0:  g[i] = dy[i] + shift_left(wl[i+1]*g[i+1]) + wc[i+1]*g[i+1]
#                        + shift_right(wr[i+1]*g[i+1])
#     d=1:  same with i+1 -> i-1.
# ---------------------------------------------------------------------------

def _bwd_pair_kernel(row_tile,
                     dy_ref, wl_ref, wc_ref, wr_ref, g_ref, carry_ref):
    d = pl.program_id(0)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def body(r, _):
        # Adjoint traversal is opposite to the forward one per direction.
        r_eff = jnp.where(d == 0, row_tile - 1 - r, r)
        g_row = (
            _row(dy_ref, r_eff)
            + _shift_left(carry_ref[0, :, :])
            + carry_ref[1, :, :]
            + _shift_right(carry_ref[2, :, :])
        )
        g_ref[0, pl.dslice(r_eff, 1), :] = g_row.astype(g_ref.dtype)
        carry_ref[0, :, :] = _row(wl_ref, r_eff) * g_row
        carry_ref[1, :, :] = _row(wc_ref, r_eff) * g_row
        carry_ref[2, :, :] = _row(wr_ref, r_eff) * g_row
        return 0

    jax.lax.fori_loop(0, row_tile, body, 0)


def _bwd_pair_kernel_staged(row_tile, cpw,
                            dy_ref, wl_ref, wc_ref, wr_ref, g_ref,
                            carry_ref):
    """Depth-2 fused adjoint: all planes of one direction per grid step,
    staged streams, three f32 tap·adjoint carry rows per plane riding the
    ``_dir_scan`` carry.  Direction 0's adjoint walks rows last→first —
    the scan's traced ``reverse`` flag, no staged data is flipped."""
    del row_tile
    d = pl.program_id(0)
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _reset():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    dys = _stage_rows(dy_ref)                       # (T, G, W) f32
    wls = _stage_rows(wl_ref, cpw)
    wcs = _stage_rows(wc_ref, cpw)
    wrs = _stage_rows(wr_ref, cpw)
    sr, sl = _masked_shifts(dys.shape[1:])

    def step(prods, row):
        dy_r, wl_r, wc_r, wr_r = row
        prod_l, prod_c, prod_r = prods
        g_row = (
            dy_r
            + sl(prod_l)
            + prod_c
            + sr(prod_r)
        )
        return (wl_r * g_row, wc_r * g_row, wr_r * g_row), g_row

    p0 = (carry_ref[0][:, 0, :], carry_ref[1][:, 0, :],
          carry_ref[2][:, 0, :])
    # Adjoint traversal is opposite to the forward one per direction.
    prods, ys = _dir_scan(step, p0, (dys, wls, wcs, wrs), d == 0)
    carry_ref[0], carry_ref[1], carry_ref[2] = \
        (p[:, None, :] for p in prods)
    g_ref[...] = jnp.swapaxes(ys, 0, 1).astype(g_ref.dtype)


def gspn_scan_bidir_bwd_pallas(dy2, wl2, wc2, wr2, *,
                               spec: ScanSpec | None = None,
                               channels_per_weight: int = 1,
                               row_tile: int | None = None,
                               interpret: bool = True,
                               pipeline_depth: int | None = None):
    """Fused adjoint of the pair scan.  dy2: (2, G, H, W); w*2:
    (2, G_w, H, W), all in the UNFLIPPED layout.  Returns g2 = dL/dh
    (pre-output-layer) as (2, G, H, W) f32 — one launch, no flipped
    copies."""
    _, g_dim, h, w = dy2.shape
    # Streamed dtype is dy2's (bf16 tiles halve the working set); the
    # adjoint carry is three f32 tap·adjoint rows regardless of policy
    # (encoded by the "pair_bwd" direction leg — _pair_spec forces it).
    spec = _pair_spec(spec, "pair_bwd", dy2.dtype,
                      channels_per_weight=channels_per_weight,
                      interpret=interpret, row_tile=row_tile,
                      pipeline_depth=pipeline_depth)
    cpw = spec.channels_per_weight
    gw = g_dim // cpw
    interpret = spec.interpret
    plan = _pair_plan(spec, h, w, g_dim)
    row_tile, pipeline_depth = plan.row_tile, plan.pipeline_depth
    assert h % row_tile == 0
    assert pipeline_depth in (1, 2), pipeline_depth
    n_tiles = h // row_tile

    def ti_eff(d, ti):
        # Opposite tile order to the forward pass, per direction.
        return jnp.where(d == 0, n_tiles - 1 - ti, ti)

    if pipeline_depth == 1:
        wt_spec = pl.BlockSpec(
            (1, 1, row_tile, w),
            lambda d, gi, ti: (d, gi // cpw, ti_eff(d, ti), 0))
        data_spec = pl.BlockSpec((1, 1, row_tile, w),
                                 lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))

        def kernel(dy_ref, wl_ref, wc_ref, wr_ref, g_ref, carry_ref):
            _bwd_pair_kernel(row_tile, dy_ref.at[0],
                             wl_ref.at[0], wc_ref.at[0], wr_ref.at[0],
                             g_ref.at[0], carry_ref)

        call = pl.pallas_call(
            kernel,
            grid=(2, g_dim, n_tiles),
            in_specs=[data_spec, wt_spec, wt_spec, wt_spec],
            out_specs=data_spec,
            out_shape=jax.ShapeDtypeStruct((2, g_dim, h, w), jnp.float32),
            scratch_shapes=[pltpu.VMEM((3, 1, w), jnp.float32)],
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",) * 3),
            interpret=interpret,
        )
        with _launch_span("gspn_pair_bwd", plan, dy2.dtype, g_dim, h, w):
            return call(dy2, wl2, wc2, wr2)

    wt_spec = pl.BlockSpec((1, gw, row_tile, w),
                           lambda d, ti: (d, 0, ti_eff(d, ti), 0))
    data_spec = pl.BlockSpec((1, g_dim, row_tile, w),
                             lambda d, ti: (d, 0, ti_eff(d, ti), 0))

    def kernel(dy_ref, wl_ref, wc_ref, wr_ref, g_ref, carry_ref):
        _bwd_pair_kernel_staged(row_tile, cpw, dy_ref.at[0],
                                wl_ref.at[0], wc_ref.at[0], wr_ref.at[0],
                                g_ref.at[0], carry_ref)

    call = pl.pallas_call(
        kernel,
        grid=(2, n_tiles),
        in_specs=[data_spec, wt_spec, wt_spec, wt_spec],
        out_specs=data_spec,
        out_shape=jax.ShapeDtypeStruct((2, g_dim, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((3, g_dim, 1, w), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",) * 2),
        interpret=interpret,
    )
    with _launch_span("gspn_pair_bwd", plan, dy2.dtype, g_dim, h, w):
        return call(dy2, wl2, wc2, wr2)


# ---------------------------------------------------------------------------
# Single-launch quad kernel (square grids).
# ---------------------------------------------------------------------------

def gspn_scan_quad_pallas(x, taps4, lam4, *, spec: ScanSpec | None = None,
                          channels_per_weight: int = 1,
                          row_tile: int | None = None,
                          interpret: bool = True,
                          carry_dtype=jnp.float32,
                          pipeline_depth: int | None = None):
    """All four directions in ONE ``pallas_call`` (square H == W only).

    x: (G, N, N).  taps4: dict wl/wc/wr each (4, G_w, N, N); lam4:
    (4, G, N, N) — directions ordered (tb, bt, lr, rl) with the lr/rl
    entries already in TRANSPOSED geometry (rows of entry 2/3 are the
    original columns).  ``x`` and its transpose are stacked once here; the
    index map then selects the orientation per direction (``d // 2``), so
    each grid step streams exactly one x tile — the paper's single-launch
    design point with no flipped copies.

    Returns (4, G, N, N): entries 0/1 in original orientation, entries 2/3
    transposed (callers undo the transpose at the dispatch boundary).
    Forward-only — training uses the pair dispatch (ops.gspn_scan_pair).
    """
    g, h, w = x.shape
    assert h == w, "quad single-launch dispatch requires a square grid"
    spec = _pair_spec(spec, "quad", x.dtype,
                      channels_per_weight=channels_per_weight,
                      carry_dtype=carry_dtype, interpret=interpret,
                      row_tile=row_tile, pipeline_depth=pipeline_depth)
    cpw = spec.channels_per_weight
    gw = g // cpw
    carry_dtype = jnp.dtype(spec.carry_dtype)
    interpret = spec.interpret
    plan = _pair_plan(spec, h, w, g)
    row_tile, pipeline_depth = plan.row_tile, plan.pipeline_depth
    assert h % row_tile == 0
    assert pipeline_depth in (1, 2), pipeline_depth
    n_tiles = h // row_tile

    xx = jnp.stack([x, jnp.swapaxes(x, -1, -2)])        # (2, G, N, N)

    def ti_eff(d, ti):
        return jnp.where(d % 2 == 0, ti, n_tiles - 1 - ti)

    if pipeline_depth == 1:
        xx_spec = pl.BlockSpec(
            (1, 1, row_tile, w),
            lambda d, gi, ti: (d // 2, gi, ti_eff(d, ti), 0))
        wt_spec = pl.BlockSpec(
            (1, 1, row_tile, w),
            lambda d, gi, ti: (d, gi // cpw, ti_eff(d, ti), 0))
        lam_spec = pl.BlockSpec((1, 1, row_tile, w),
                                lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))
        out_spec = pl.BlockSpec((1, 1, row_tile, w),
                                lambda d, gi, ti: (d, gi, ti_eff(d, ti), 0))

        def kernel(x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
            _kernel(row_tile, x_ref.at[0],
                    wl_ref.at[0], wc_ref.at[0], wr_ref.at[0], lam_ref.at[0],
                    o_ref.at[0], carry_ref)

        call = pl.pallas_call(
            kernel,
            grid=(4, g, n_tiles),
            in_specs=[xx_spec, wt_spec, wt_spec, wt_spec, lam_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((4, g, h, w), x.dtype),
            scratch_shapes=[pltpu.VMEM((1, w), carry_dtype)],
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",) * 3),
            interpret=interpret,
        )
        with _launch_span("gspn_quad_fwd", plan, x.dtype, g, h, w):
            return call(xx, taps4["wl"], taps4["wc"], taps4["wr"], lam4)

    xx_spec = pl.BlockSpec((1, g, row_tile, w),
                           lambda d, ti: (d // 2, 0, ti_eff(d, ti), 0))
    wt_spec = pl.BlockSpec((1, gw, row_tile, w),
                           lambda d, ti: (d, 0, ti_eff(d, ti), 0))
    lam_spec = pl.BlockSpec((1, g, row_tile, w),
                            lambda d, ti: (d, 0, ti_eff(d, ti), 0))
    out_spec = pl.BlockSpec((1, g, row_tile, w),
                            lambda d, ti: (d, 0, ti_eff(d, ti), 0))

    def kernel(x_ref, wl_ref, wc_ref, wr_ref, lam_ref, o_ref, carry_ref):
        _kernel_staged(row_tile, cpw, x_ref.at[0],
                       wl_ref.at[0], wc_ref.at[0], wr_ref.at[0],
                       lam_ref.at[0], o_ref.at[0], carry_ref)

    call = pl.pallas_call(
        kernel,
        grid=(4, n_tiles),
        in_specs=[xx_spec, wt_spec, wt_spec, wt_spec, lam_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((4, g, h, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1, w), carry_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",) * 2),
        interpret=interpret,
    )
    with _launch_span("gspn_quad_fwd", plan, x.dtype, g, h, w):
        return call(xx, taps4["wl"], taps4["wc"], taps4["wr"], lam4)
