"""Pure-jnp oracle for the GSPN line scan.

Canonical semantics (top-to-bottom scan over axis -2, vectorised over the
last axis W):

    h[i, j] = wl[i,j] * h[i-1, j-1]
            + wc[i,j] * h[i-1, j]
            + wr[i,j] * h[i-1, j+1]
            + lam[i,j] * x[i,j]

with h[-1] = 0 and out-of-range neighbours contributing 0.  All arrays are
laid out ``(G, H, W)`` where ``G`` flattens (batch, channel) — or
(batch,) when the propagation weights are channel-shared, in which case the
weight arrays carry ``G_w = G // channels_per_weight`` leading entries and
are broadcast.

Two reference implementations live here:

* :func:`gspn_scan_ref` — a single ``jax.lax.scan`` over rows.  This is the
  *algorithmic* fused-scan oracle used to validate the Pallas kernel.
* :func:`gspn_scan_per_step` — the GSPN-1 emulation: one separately-compiled
  XLA computation per row, hidden state round-tripping through host-visible
  buffers between steps.  Used by the fig-3 benchmark ladder to reproduce
  the paper's launch-bound baseline structurally.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def _shift_right(v: jnp.ndarray) -> jnp.ndarray:
    """v[..., j] -> v[..., j-1]; position 0 becomes 0."""
    pad = [(0, 0)] * (v.ndim - 1) + [(1, 0)]
    return jnp.pad(v, pad)[..., :-1]


def _shift_left(v: jnp.ndarray) -> jnp.ndarray:
    """v[..., j] -> v[..., j+1]; last position becomes 0."""
    pad = [(0, 0)] * (v.ndim - 1) + [(0, 1)]
    return jnp.pad(v, pad)[..., 1:]


def _broadcast_w(w: jnp.ndarray, g: int) -> jnp.ndarray:
    """Broadcast channel-shared weights (G_w, H, W) to (G, H, W)."""
    gw = w.shape[0]
    if gw == g:
        return w
    assert g % gw == 0, f"G={g} not a multiple of G_w={gw}"
    reps = g // gw
    return jnp.broadcast_to(w[:, None], (gw, reps) + w.shape[1:]).reshape(
        (g,) + w.shape[1:]
    )


def step_row(h_prev, x_row, wl_row, wc_row, wr_row, lam_row):
    """One scan step: all inputs (..., W) for the current row."""
    return (
        wl_row * _shift_right(h_prev)
        + wc_row * h_prev
        + wr_row * _shift_left(h_prev)
        + lam_row * x_row
    )


def gspn_scan_ref(x, wl, wc, wr, lam, h0=None, reverse: bool = False):
    """Fused-scan oracle.  x, lam: (G, H, W); wl/wc/wr: (G_w, H, W).

    Returns h: (G, H, W).  ``reverse=True`` scans bottom-to-top (this is a
    *data* reversal, equivalent to flipping H before and after).
    """
    g = x.shape[0]
    wl = _broadcast_w(wl, g)
    wc = _broadcast_w(wc, g)
    wr = _broadcast_w(wr, g)
    if h0 is None:
        h0 = jnp.zeros_like(x[:, 0])

    def body(h_prev, row):
        x_r, wl_r, wc_r, wr_r, lam_r = row
        h = step_row(h_prev, x_r, wl_r, wc_r, wr_r, lam_r)
        return h, h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (x, wl, wc, wr, lam))
    _, hs = jax.lax.scan(body, h0, xs, reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def gspn_scan_chunked_ref(x, wl, wc, wr, lam, chunk: int):
    """GSPN-local: propagation confined to segments of ``chunk`` rows.

    Equivalent to resetting the carry every ``chunk`` rows.
    """
    g, h, w = x.shape
    assert h % chunk == 0, f"H={h} not divisible by chunk={chunk}"
    n = h // chunk
    # Broadcast shared weights to full G *before* folding: folding interleaves
    # the chunk index into the leading dim, which would otherwise break the
    # grouped-broadcast convention of gspn_scan_ref.
    wl = _broadcast_w(wl, g)
    wc = _broadcast_w(wc, g)
    wr = _broadcast_w(wr, g)

    def fold(a):
        return a.reshape(a.shape[0] * n, chunk, w)

    out = gspn_scan_ref(fold(x), fold(wl), fold(wc), fold(wr), fold(lam))
    return out.reshape(g, h, w)


# ---------------------------------------------------------------------------
# GSPN-1 emulation: per-step "kernel launches".
# ---------------------------------------------------------------------------

@jax.jit
def _one_step(h_prev, x_row, wl_row, wc_row, wr_row, lam_row):
    return step_row(h_prev, x_row, wl_row, wc_row, wr_row, lam_row)


def gspn_scan_per_step(x, wl, wc, wr, lam, block: bool = True):
    """GSPN-1 structural emulation: one dispatch per row.

    Each row is a separate jitted call whose result is materialised
    (``block_until_ready``) before the next row is dispatched — mirroring
    GSPN-1's per-step kernel launches and HBM round trips.  Numerically
    identical to :func:`gspn_scan_ref`.
    """
    g = x.shape[0]
    wl = _broadcast_w(wl, g)
    wc = _broadcast_w(wc, g)
    wr = _broadcast_w(wr, g)
    h_prev = jnp.zeros_like(x[:, 0])
    rows = []
    for i in range(x.shape[1]):
        h_prev = _one_step(h_prev, x[:, i], wl[:, i], wc[:, i], wr[:, i], lam[:, i])
        if block:
            h_prev.block_until_ready()
        rows.append(h_prev)
    return jnp.stack(rows, axis=1)


# ---------------------------------------------------------------------------
# Dense affinity-matrix oracle (Eq. 4 of the paper): O(H^2 W^2) — tiny
# shapes only.  Validates that the scan equals y = G @ x with the
# block-lower-triangular G built from tridiagonal w products.
# ---------------------------------------------------------------------------

def _tridiag(wl_row, wc_row, wr_row):
    """Materialise the (W, W) tridiagonal matrix for one row."""
    w = wc_row.shape[-1]
    m = jnp.zeros((w, w), wc_row.dtype)
    m = m + jnp.diag(wc_row)
    m = m + jnp.diag(wl_row[1:], k=-1)   # h_new[k] += wl[k] * h_prev[k-1]
    m = m + jnp.diag(wr_row[:-1], k=1)   # h_new[k] += wr[k] * h_prev[k+1]
    return m


def gspn_dense_oracle(x, wl, wc, wr, lam):
    """Materialised Eq.-4 oracle for a single (H, W) slice per G entry."""
    g_dim, h_dim, _ = x.shape
    wl = _broadcast_w(wl, g_dim)
    wc = _broadcast_w(wc, g_dim)
    wr = _broadcast_w(wr, g_dim)
    outs = []
    for g in range(g_dim):
        hs = []
        h_prev = jnp.zeros_like(x[g, 0])
        for i in range(h_dim):
            m = _tridiag(wl[g, i], wc[g, i], wr[g, i])
            h_prev = m @ h_prev + lam[g, i] * x[g, i]
            hs.append(h_prev)
        outs.append(jnp.stack(hs))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Reference VJP (used to validate the custom_vjp in ops.py).
# ---------------------------------------------------------------------------

def gspn_scan_ref_vjp(x, wl, wc, wr, lam, dy):
    """Hand-derived backward pass, pure jnp.  Returns (dx, dwl, dwc, dwr, dlam).

    Adjoint recurrence (g = dL/dh):
        g[H-1] = dy[H-1]
        g[i]   = dy[i] + W[i+1]^T g[i+1]
        (W^T g)[m] = wl[m+1] g[m+1] + wc[m] g[m] + wr[m-1] g[m-1]
    """
    g_dim = x.shape[0]
    gw_dim = wl.shape[0]
    wl_b = _broadcast_w(wl, g_dim)
    wc_b = _broadcast_w(wc, g_dim)
    wr_b = _broadcast_w(wr, g_dim)

    h = gspn_scan_ref(x, wl_b, wc_b, wr_b, lam)

    def body(g_next_products, row):
        dy_r, wl_r, wc_r, wr_r = row
        pl_, pc_, pr_ = g_next_products
        g_r = dy_r + _shift_left(pl_) + pc_ + _shift_right(pr_)
        return (wl_r * g_r, wc_r * g_r, wr_r * g_r), g_r

    zeros = jnp.zeros_like(x[:, 0])
    xs = tuple(
        jnp.moveaxis(a, 1, 0) for a in (dy, wl_b, wc_b, wr_b)
    )
    _, gs = jax.lax.scan(body, (zeros, zeros, zeros), xs, reverse=True)
    g = jnp.moveaxis(gs, 0, 1)  # (G, H, W)

    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    dx = lam * g
    dlam = x * g
    dwl = g * _shift_right(h_prev)
    dwc = g * h_prev
    dwr = g * _shift_left(h_prev)
    if gw_dim != g_dim:
        reps = g_dim // gw_dim
        dwl = dwl.reshape((gw_dim, reps) + dwl.shape[1:]).sum(axis=1)
        dwc = dwc.reshape((gw_dim, reps) + dwc.shape[1:]).sum(axis=1)
        dwr = dwr.reshape((gw_dim, reps) + dwr.shape[1:]).sum(axis=1)
    return dx, dwl, dwc, dwr, dlam
