# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# The one declarative launch-configuration value every call path shares
# (DESIGN.md §14).  Re-exported here so callers outside the kernel stack
# can build specs without reaching into the leaf module.
from repro.kernels.spec import ScanSpec, enumerate_specs  # noqa: F401
