"""VMEM-aware tile selection — the TPU analogue of the paper's §4.3
occupancy balancing (block size vs shared-memory footprint vs resident
blocks).  This is the SINGLE row-tile picker: every scan kernel
(``gspn_scan.py``, ``gspn_multidir.py``) routes through
:func:`pick_row_tile`; ``gspn_scan.pick_row_tile`` survives only as a
thin wrapper over it for the old call signature.

The fused scan keeps per-grid-cell working set
``(x + wl + wc + wr + lam + out) tiles + carry`` resident in VMEM.  The
tuner picks the largest power-of-two row tile that (a) divides the scan
length, (b) keeps the working set inside the VMEM budget, and (c) leaves
headroom for double-buffered pipelining (factor 2 on the streamed
operands — Pallas prefetches the next tile while the current one
computes).
"""

from __future__ import annotations

import dataclasses

# v5e-class VMEM per core; a conservative default budget leaves room for
# the compiler's own buffers.
VMEM_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TileChoice:
    row_tile: int
    working_set_bytes: int
    n_grid_steps: int


def scan_working_set(row_tile: int, w: int, dtype_bytes: int,
                     n_streams: int = 6, double_buffer: bool = True,
                     carry_dtype_bytes: int = 4,
                     pipeline_depth: int = 1) -> int:
    """Bytes resident per grid cell: n_streams streamed tiles (+ their
    prefetch copies) + the carry row.

    ``dtype_bytes`` is the STREAMED dtype (bf16 halves every tile);
    ``carry_dtype_bytes`` is the VMEM carry row's dtype, kept separate so
    the accounting stays honest under the mixed-precision policy
    (DESIGN.md §10: bf16 streams, f32 carry).

    ``pipeline_depth=2`` is the explicitly staged pipeline (DESIGN.md
    §12): every streamed tile additionally keeps an f32 staging copy
    resident — the widen-on-load input stages plus the f32 out-stage that
    is written back in one bulk downcast — so the streamed term grows by
    ``n_streams * row_tile * w * 4`` regardless of the stream dtype.  For
    bf16 streams this lands the depth-2 footprint exactly on the f32
    depth-1 footprint (2·2 + 4 = 4·2 bytes per element); for f32 streams
    the stage is a dead copy that only shrinks the admissible tile, which
    is why the tuner never emits depth 2 for 4-byte streams.
    """
    tile = row_tile * w * dtype_bytes
    mult = 2 if double_buffer else 1
    ws = n_streams * tile * mult + w * carry_dtype_bytes
    if pipeline_depth >= 2:
        ws += n_streams * row_tile * w * 4
    return ws


def pick_row_tile(h: int, w: int, dtype_bytes: int = 4,
                  vmem_budget: int = VMEM_BYTES, cap: int = 512,
                  n_streams: int = 6,
                  carry_dtype_bytes: int = 4,
                  pipeline_depth: int = 1) -> TileChoice:
    """Largest power-of-two divisor of ``h`` whose working set fits."""
    best = 1
    t = 1
    while t * 2 <= cap and h % (t * 2) == 0:
        t *= 2
        if scan_working_set(t, w, dtype_bytes, n_streams,
                            carry_dtype_bytes=carry_dtype_bytes,
                            pipeline_depth=pipeline_depth) \
                <= vmem_budget:
            best = t
    return TileChoice(row_tile=best,
                      working_set_bytes=scan_working_set(
                          best, w, dtype_bytes, n_streams,
                          carry_dtype_bytes=carry_dtype_bytes,
                          pipeline_depth=pipeline_depth),
                      n_grid_steps=h // best)


# ---------------------------------------------------------------------------
# Precision-policy routing (DESIGN.md §10/§11).
#
# Call sites must not guess byte widths: the streamed itemsize follows the
# policy's compute dtype and the carry itemsize its carry dtype.  This is
# the fix for the sites that passed dtype_bytes=4 regardless of the
# active policy (benchmarks, sp) — they now resolve a named preset here.
# ---------------------------------------------------------------------------

def policy_itemsizes(precision) -> tuple[int, int]:
    """(streamed_bytes, carry_bytes) for a ``configs.base`` precision
    preset name or Precision instance."""
    import jax.numpy as jnp

    from repro.configs.base import resolve_precision  # lazy: configs
    p = resolve_precision(precision)                  # import kernels
    return (jnp.dtype(p.compute_dtype).itemsize,
            jnp.dtype(p.carry_dtype).itemsize)


def pick_row_tile_for_policy(h: int, w: int, precision,
                             vmem_budget: int = VMEM_BYTES, cap: int = 512,
                             n_streams: int = 6,
                             pipeline_depth: int = 1) -> TileChoice:
    """``pick_row_tile`` with stream/carry bytes resolved from the
    mixed-precision policy instead of hand-passed constants.

    NOTE: the launch-site heuristic fallback caps at
    ``autotune.DEFAULT_CAP`` (256); pass ``cap=autotune.DEFAULT_CAP``
    (and the depth the launch would run at) when reporting what a
    launch's fallback would pick."""
    stream_b, carry_b = policy_itemsizes(precision)
    return pick_row_tile(h, w, stream_b, vmem_budget=vmem_budget, cap=cap,
                         n_streams=n_streams, carry_dtype_bytes=carry_b,
                         pipeline_depth=pipeline_depth)
