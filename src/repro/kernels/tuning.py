"""VMEM-aware tile selection — the TPU analogue of the paper's §4.3
occupancy balancing (block size vs shared-memory footprint vs resident
blocks).  This is the SINGLE row-tile picker: every scan kernel
(``gspn_scan.py``, ``gspn_multidir.py``) routes through
:func:`pick_row_tile`; ``gspn_scan.pick_row_tile`` survives only as a
thin wrapper over it for the old call signature.

The fused scan keeps per-grid-cell working set
``(x + wl + wc + wr + lam + out) tiles + carry`` resident in VMEM.  The
tuner picks the largest power-of-two row tile that (a) divides the scan
length, (b) keeps the working set inside the VMEM budget, and (c) leaves
headroom for double-buffered pipelining (factor 2 on the streamed
operands — Pallas prefetches the next tile while the current one
computes).
"""

from __future__ import annotations

import dataclasses

# v5e-class VMEM per core; a conservative default budget leaves room for
# the compiler's own buffers.
VMEM_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class TileChoice:
    row_tile: int
    working_set_bytes: int
    n_grid_steps: int


def scan_working_set(row_tile: int, w: int, dtype_bytes: int,
                     n_streams: int = 6, double_buffer: bool = True,
                     carry_dtype_bytes: int = 4) -> int:
    """Bytes resident per grid cell: n_streams streamed tiles (+ their
    prefetch copies) + the carry row.

    ``dtype_bytes`` is the STREAMED dtype (bf16 halves every tile);
    ``carry_dtype_bytes`` is the VMEM carry row's dtype, kept separate so
    the accounting stays honest under the mixed-precision policy
    (DESIGN.md §10: bf16 streams, f32 carry).
    """
    tile = row_tile * w * dtype_bytes
    mult = 2 if double_buffer else 1
    return n_streams * tile * mult + w * carry_dtype_bytes


def pick_row_tile(h: int, w: int, dtype_bytes: int = 4,
                  vmem_budget: int = VMEM_BYTES, cap: int = 512,
                  n_streams: int = 6,
                  carry_dtype_bytes: int = 4) -> TileChoice:
    """Largest power-of-two divisor of ``h`` whose working set fits."""
    best = 1
    t = 1
    while t * 2 <= cap and h % (t * 2) == 0:
        t *= 2
        if scan_working_set(t, w, dtype_bytes, n_streams,
                            carry_dtype_bytes=carry_dtype_bytes) \
                <= vmem_budget:
            best = t
    return TileChoice(row_tile=best,
                      working_set_bytes=scan_working_set(
                          best, w, dtype_bytes, n_streams,
                          carry_dtype_bytes=carry_dtype_bytes),
                      n_grid_steps=h // best)
