"""Mixture-of-Experts layer with expert-parallel shard_map dispatch.

Design (DESIGN.md §5):

* Expert weights are stored as **per-shard slabs** ``(M, E_loc, D, F_loc)``
  where ``M`` is the model-axis size, ``ep = min(E, M)`` expert groups are
  sharded across the axis and ``tp = M // ep`` shards split each expert's
  hidden dim (Grok-1: E=8 on a 16-way axis ⇒ ep=8, tp=2).  The slab layout
  makes the sharding a plain ``P('model', ...)`` regardless of E vs M.
* Inside ``shard_map`` every shard routes its (data-parallel-local) tokens
  with the replicated router, keeps the slots owned by its expert group,
  scatters them into an ``(E_loc, C, D)`` capacity buffer (`.at[].add` with
  ``mode='drop'`` — dropped tokens fall off the end, Switch-style), runs the
  expert SwiGLU, gathers back per slot and applies the gate; a single
  ``psum`` over the model axis assembles the full output (it simultaneously
  sums the ``tp`` hidden-dim partials and selects the owner shard's value).
* Token order is never globally sorted — ranking within an expert uses a
  local argsort, so dispatch is deterministic.

Runs unchanged on a single device (M=1, psum over nothing) for smoke tests.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.layers import DTypePolicy, DEFAULT_POLICY, dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    dim: int
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    n_shards: int = 1              # model-axis size M (static)
    capacity_factor: float = 1.25
    shared_expert_ff: int = 0      # 0 = no shared expert
    renorm_gates: bool = True      # re-normalise top-k gate values
    aux_loss_coef: float = 0.01

    @property
    def ep(self) -> int:
        return min(self.n_experts, self.n_shards)

    @property
    def tp(self) -> int:
        assert self.n_shards % self.ep == 0, (self.n_shards, self.n_experts)
        return self.n_shards // self.ep

    @property
    def e_loc(self) -> int:
        assert self.n_experts % self.ep == 0
        return self.n_experts // self.ep

    @property
    def f_loc(self) -> int:
        assert self.d_ff % self.tp == 0
        return self.d_ff // self.tp


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32):
    m, el, fl, d = cfg.n_shards, cfg.e_loc, cfg.f_loc, cfg.dim
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(cfg.d_ff)

    def slab(k, shape, scale):
        return (jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32)
                * scale).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, cfg.n_experts, jnp.float32),
        "gate_slab": slab(ks[1], (m, el, d, fl), scale_in),
        "up_slab": slab(ks[2], (m, el, d, fl), scale_in),
        "down_slab": slab(ks[3], (m, el, fl, d), scale_out),
    }
    if cfg.shared_expert_ff:
        from repro.models.layers import init_swiglu
        p["shared"] = init_swiglu(ks[4], d, cfg.shared_expert_ff, dtype)
    return p


@jax.custom_vjp
def _router_matmul(x2d, w):
    return jnp.einsum("td,de->te", x2d, w.astype(x2d.dtype),
                      preferred_element_type=jnp.float32)


def _router_matmul_fwd(x2d, w):
    return _router_matmul(x2d, w), (x2d, w)


def _router_matmul_bwd(res, dlogits):
    # Keep cotangents in the compute dtype: the default f32 dlogits would
    # contract against x in f32, and XLA hoists that into an f32 copy of
    # the whole per-layer x residual stack (7 GB/device, kimi train_4k).
    x2d, w = res
    dl = dlogits.astype(x2d.dtype)
    dx = dl @ w.astype(x2d.dtype).T
    dw = (x2d.T @ dl).astype(w.dtype)
    return dx, dw


_router_matmul.defvjp(_router_matmul_fwd, _router_matmul_bwd)


def _route(x2d, router_w, cfg: MoEConfig):
    """Router with f32 ACCUMULATION (no materialised f32 copy of x).
    x2d (T, D) -> gates (T, k), experts (T, k) int32, plus aux loss."""
    logits = _router_matmul(x2d, router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renorm_gates:
        top_v = top_v / jnp.maximum(top_v.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum(frac_tokens * frac_probs)
    onehot_top1 = jax.nn.one_hot(top_i[:, 0], cfg.n_experts, dtype=jnp.float32)
    frac_tokens = onehot_top1.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return top_v, top_i, aux


def _moe_shard_body(x, router_w, gate_slab, up_slab, down_slab,
                    cfg: MoEConfig, shard_idx, policy: DTypePolicy):
    """Per-shard MoE compute.  x: (T, D) dp-local tokens (replicated across
    the model axis); slabs: (E_loc, D, F_loc) etc (this shard's).
    Returns PARTIAL output (T, D) — caller psums over the model axis —
    and the aux loss (identical on every shard)."""
    t, d = x.shape
    k = cfg.top_k
    e_loc = cfg.e_loc
    cap = int(math.ceil(k * t / cfg.n_experts * cfg.capacity_factor))
    cap = max(cap, 1)

    gates, experts, aux = _route(x, router_w, cfg)      # (T,k)

    # Rank of each (token, slot) within its expert, computed locally and
    # identically on every shard (inputs are model-replicated).
    eflat = experts.reshape(t * k)
    order = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[order]
    counts = jnp.bincount(eflat, length=cfg.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))

    group = shard_idx // cfg.tp                          # expert group id
    e_lo = group * e_loc
    owned = (eflat >= e_lo) & (eflat < e_lo + e_loc)
    kept = owned & (rank < cap)
    e_local = jnp.where(kept, eflat - e_lo, e_loc)       # OOB => dropped
    r_local = jnp.where(kept, rank, cap)

    xc = x.astype(policy.compute_dtype)
    tok_of_slot = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buffer = jnp.zeros((e_loc, cap, d), policy.compute_dtype)
    buffer = buffer.at[e_local, r_local].add(
        xc[tok_of_slot], mode="drop")

    gs = gate_slab.astype(policy.compute_dtype)
    us = up_slab.astype(policy.compute_dtype)
    ds = down_slab.astype(policy.compute_dtype)
    h = jnp.einsum("ecd,edf->ecf", buffer, gs)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buffer, us)
    out_buf = jnp.einsum("ecf,efd->ecd", h, ds)          # partial over F

    y_slots = out_buf.at[e_local, r_local].get(
        mode="fill", fill_value=0)                       # (T*k, D)
    y_slots = y_slots * gates.reshape(t * k, 1).astype(policy.compute_dtype)
    y = y_slots.reshape(t, k, d).sum(axis=1)
    return y, aux


def apply_moe(params, x, cfg: MoEConfig, *, mesh=None,
              dp_axes=("data",), model_axis="model",
              policy: DTypePolicy = DEFAULT_POLICY):
    """x: (B, S, D) -> (y, aux_loss).

    With a mesh: runs as shard_map (manual) over all mesh axes; tokens stay
    dp-local, experts are model-sharded per the slab layout.  Without a
    mesh: single-shard local execution.
    """
    b, s, d = x.shape

    def flat_body(x3d, router_w, gslab, uslab, dslab, shard_idx):
        x2d = x3d.reshape(-1, d)
        y, aux = _moe_shard_body(x2d, router_w, gslab, uslab, dslab,
                                 cfg, shard_idx, policy)
        y = y.reshape(x3d.shape).astype(x3d.dtype)
        return y, aux

    if mesh is None or cfg.n_shards == 1:
        y, aux = flat_body(x, params["router"],
                           params["gate_slab"][0], params["up_slab"][0],
                           params["down_slab"][0], 0)
    else:
        def mapped(x3d, router_w, gslab, uslab, dslab):
            idx = jax.lax.axis_index(model_axis)
            y, aux = flat_body(x3d, router_w, gslab[0], uslab[0], dslab[0],
                               idx)
            y = jax.lax.psum(y, model_axis)
            aux = jax.lax.pmean(aux, model_axis)
            return y, aux

        y, aux = compat.shard_map(
            mapped, mesh=mesh,
            in_specs=(P(dp_axes[0] if len(dp_axes) == 1 else dp_axes,
                        None, None),
                      P(None, None),
                      P(model_axis, None, None, None),
                      P(model_axis, None, None, None),
                      P(model_axis, None, None, None)),
            out_specs=(P(dp_axes[0] if len(dp_axes) == 1 else dp_axes,
                         None, None), P()),
        )(x, params["router"], params["gate_slab"], params["up_slab"],
          params["down_slab"])

    if cfg.shared_expert_ff:
        from repro.models.layers import apply_swiglu
        y = y + apply_swiglu(params["shared"], x, policy)
    return y, aux * cfg.aux_loss_coef


def moe_param_count(cfg: MoEConfig) -> int:
    n = cfg.dim * cfg.n_experts                       # router
    n += 3 * cfg.n_experts * cfg.dim * cfg.d_ff       # experts
    if cfg.shared_expert_ff:
        n += 3 * cfg.dim * cfg.shared_expert_ff
    return n


def moe_active_param_count(cfg: MoEConfig) -> int:
    n = cfg.dim * cfg.n_experts
    n += 3 * cfg.top_k * cfg.dim * cfg.d_ff
    if cfg.shared_expert_ff:
        n += 3 * cfg.dim * cfg.shared_expert_ff
    return n
