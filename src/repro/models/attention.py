"""Grouped-query attention with chunked (flash-style) softmax and KV-cache
decode.

Three entry points:

* :func:`chunked_attention` — online-softmax attention computed over KV
  blocks via ``lax.scan`` (memory O(S·block) instead of O(S²)); used for
  training and prefill.  This is the TPU-idiomatic analogue of fusing the
  attention loop — and one of the beyond-paper memory-term optimisations
  recorded in EXPERIMENTS.md §Perf.
* :func:`full_attention` — materialised reference (small shapes / tests).
* :func:`decode_attention` — one-token query against a (possibly padded)
  KV cache with explicit length masking.

GQA layout: q (B, S, Hq, D), k/v (B, S, Hkv, D), Hq = G·Hkv.  Instead of
repeating KV heads we reshape q to (B, S, Hkv, G, D) and contract per KV
head — avoiding the materialised repeat (less HBM traffic, and XLA keeps
the sharding on the kv-head axis).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import DTypePolicy, DEFAULT_POLICY, dense_init

NEG_INF = -1e30


def _group_q(q, hkv):
    b, s, hq, d = q.shape
    g = hq // hkv
    return q.reshape(b, s, hkv, g, d)


def full_attention(q, k, v, *, causal: bool = True,
                   q_offset: int = 0, bias=None):
    """Reference attention.  q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    qg = _group_q(q, hkv).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) / math.sqrt(d)
    if causal:
        iq = jnp.arange(sq)[:, None] + q_offset
        ik = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where(ik <= iq, logits, NEG_INF)
    if bias is not None:
        logits = logits + bias
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def _fwd_blocks(qg, kb, vb, iq, causal, block_k):
    """Online-softmax forward over kv blocks.  Returns (out_unnormalised,
    m_final, l_final) with shapes (b,hkv,g,sq,d) / (b,hkv,g,sq)."""
    b, sq = qg.shape[0], qg.shape[1]
    hkv, g, d = qg.shape[2], qg.shape[3], qg.shape[4]
    nblk = kb.shape[1]

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk
        kf = k_blk.astype(jnp.float32)
        vf = v_blk.astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
        if causal:
            ik = blk_idx * block_k + jnp.arange(block_k)
            mask = ik[None, :] <= iq[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vf)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)))
    return acc, m_f, l_f


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attention(q, k, v, causal, block_k, q_offset):
    out, _ = _flash_fwd(q, k, v, causal, block_k, q_offset)
    return out


def _flash_fwd(q, k, v, causal, block_k, q_offset):
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    nblk = sk // block_k
    qg = _group_q(q, hkv).astype(jnp.float32) / math.sqrt(d)
    kb = k.reshape(b, nblk, block_k, hkv, d)
    vb = v.reshape(b, nblk, block_k, hkv, d)
    iq = jnp.arange(sq) + q_offset
    acc, m_f, l_f = _fwd_blocks(qg, kb, vb, iq, causal, block_k)
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))       # (b,hkv,g,sq)
    out_b = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, d).astype(q.dtype)
    # Residuals are force-saved across scanned layers (remat does not see
    # through custom_vjp), so every saved tensor costs an (L, B, S, D)
    # stack.  ``out`` is NOT saved — the backward recomputes it from
    # (q, k, v, lse) in a first block sweep (§Perf: one x-sized bf16 stack
    # per layer ≈ 5 GB/device on the 72B 4k train cell, for ~+25% of the
    # backward-attention FLOPs — the right trade on a memory-bound cell).
    return out_b, (q, k, v, lse)


def _flash_bwd(causal, block_k, q_offset, res, dout):
    """FlashAttention-2-style backward: recompute per-block probabilities,
    accumulate dq/dk/dv — O(S·block) memory (no stored S² tensors).
    Two sweeps: (1) recompute out from (q,k,v,lse) for delta, (2) grads."""
    q, k, v, lse = res
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    nblk = sk // block_k
    qg = _group_q(q, hkv).astype(jnp.float32) / math.sqrt(d)
    kb = k.reshape(b, nblk, block_k, hkv, d)
    vb = v.reshape(b, nblk, block_k, hkv, d)
    do = jnp.moveaxis(_group_q(dout, hkv).astype(jnp.float32),
                      (1, 2, 3), (3, 1, 2))            # (b,hkv,g,sq,d)
    iq = jnp.arange(sq) + q_offset

    # Sweep 1: delta = rowsum(dout * out) with out recomputed blockwise
    # (p = exp(logits - lse) is already normalised — no m/l tracking).
    def delta_body(acc, blk):
        k_blk, v_blk, blk_idx = blk
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            k_blk.astype(jnp.float32))
        if causal:
            ik = blk_idx * block_k + jnp.arange(block_k)
            mask = ik[None, :] <= iq[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        p = jnp.exp(logits - lse[..., None])
        acc = acc + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                               v_blk.astype(jnp.float32))
        return acc, None

    out0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    out, _ = jax.lax.scan(
        delta_body, out0,
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)))
    delta = jnp.einsum("bhgqd,bhgqd->bhgq", do, out)   # (b,hkv,g,sq)

    def body(dq_acc, blk):
        k_blk, v_blk, blk_idx = blk
        kf = k_blk.astype(jnp.float32)
        vf = v_blk.astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
        if causal:
            ik = blk_idx * block_k + jnp.arange(block_k)
            mask = ik[None, :] <= iq[:, None]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        p = jnp.exp(logits - lse[..., None])           # (b,hkv,g,sq,blk)
        dv_blk = jnp.einsum("bhgqk,bhgqd->bkhd", p, do)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", do, vf)
        ds = p * (dp - delta[..., None])
        # logits are linear in k with coefficient qg (= q/√d), so dk uses
        # qg directly; dq needs the extra 1/√d (applied after the scan).
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        body, dq0,
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)))
    dq = (dq / math.sqrt(d)).reshape(b, sq, hq, d).astype(q.dtype)
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, sk, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, sk, hkv, d).astype(v.dtype)
    return dq, dk, dv


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(q, k, v, *, causal: bool = True, block_k: int = 512,
                      q_offset: int = 0):
    """Flash-style attention: online softmax forward + recomputing custom
    backward.  Memory O(Sq·block_k) in BOTH directions (plain autodiff of
    a blocked forward would still store the S² probabilities for the
    backward — measured 170 GB/device on the 4k×256 train cell;
    see EXPERIMENTS.md §Perf)."""
    sk = k.shape[1]
    while sk % block_k != 0:
        block_k //= 2
    return _flash_attention(q, k, v, causal, block_k, q_offset)


def chunk_prefill_attention(q, k_cache, v_cache, q_offset):
    """Chunked-prefill attention (DESIGN.md §9): a T-token prompt chunk at
    absolute offset ``q_offset`` (traced scalar ok) attends over the padded
    KV cache (B,S,Hkv,D), into which the chunk's own K/V have already been
    written.  Key j is visible iff j <= q_offset + i, so the result equals
    one-shot causal prefill restricted to these T query rows.

    Dense over the padded cache, like ``decode_attention`` — the traced
    offset cannot go through ``chunked_attention`` (``q_offset`` is a
    nondiff_argnum of the flash vjp, so it would recompile per offset).
    Fine at serve-engine cache sizes; a blockwise variant along the lines
    of ``_fwd_blocks`` is the upgrade path if max_len grows."""
    b, t, hq, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    qg = _group_q(q, hkv).astype(jnp.float32) / math.sqrt(d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32))
    iq = q_offset + jnp.arange(t)
    mask = jnp.arange(s)[None, :] <= iq[:, None]
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, t, hq, d).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len):
    """One-step decode.  q (B,1,Hq,D); caches (B,S,Hkv,D); cache_len (B,)
    or scalar — number of valid cache entries (including the new token,
    which the caller must already have written)."""
    b, _, hq, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    qg = _group_q(q, hkv).astype(jnp.float32) / math.sqrt(d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    valid = jnp.arange(s)[None, :] < jnp.reshape(cache_len, (-1, 1))
    logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA layer (projections + rope + attention + output).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    dim: int
    n_heads: int
    n_kv_heads: int
    head_dim: int = 0              # 0 => dim // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None   # e.g. (16, 24, 24) for Qwen2-VL
    causal: bool = True
    block_k: int = 512
    use_chunked: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.dim // self.n_heads


def init_attention(key, cfg: AttentionConfig, dtype=jnp.float32):
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.dim, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.dim, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.dim, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.dim, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _project_qkv(params, x, cfg: AttentionConfig, policy: DTypePolicy):
    from repro.models.layers import apply_rope, apply_mrope  # local import
    p = policy.cast(params)
    xc = x.astype(policy.compute_dtype)
    b, s, _ = x.shape
    hd = cfg.hd
    q = xc @ p["wq"]
    k = xc @ p["wk"]
    v = xc @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def _apply_positions(q, k, positions, cfg: AttentionConfig):
    from repro.models.layers import apply_rope, apply_mrope
    if positions is None:
        return q, k
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:      # text-only: replicate plane ids
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def apply_attention(params, x, cfg: AttentionConfig, *, positions=None,
                    kv=None, policy: DTypePolicy = DEFAULT_POLICY):
    """Training / prefill forward.  x (B,S,D).  ``kv`` overrides K/V source
    (cross-attention: tuple of pre-projected (k, v))."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, policy)
    if kv is not None:
        k, v = kv
    else:
        q, k = _apply_positions(q, k, positions, cfg)
    if cfg.use_chunked and k.shape[1] > cfg.block_k:
        out = chunked_attention(q, k, v, causal=cfg.causal and kv is None,
                                block_k=cfg.block_k)
    else:
        out = full_attention(q, k, v, causal=cfg.causal and kv is None)
    out = out.reshape(b, s, cfg.n_heads * cfg.hd)
    p = policy.cast(params)
    return (out.astype(policy.compute_dtype) @ p["wo"]).astype(x.dtype)


def apply_attention_decode(params, x, cfg: AttentionConfig, cache, *,
                           positions=None,
                           policy: DTypePolicy = DEFAULT_POLICY):
    """One-token decode.  x (B,1,D); cache dict with k/v (B,S,Hkv,D) and
    length (B,) already-filled count.  Returns (y, new_cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg, policy)
    if positions is None:
        positions = cache["length"][:, None]
    q, k_new = _apply_positions(q, k_new, positions, cfg)
    idx = cache["length"]                                # (B,)
    # One-hot blend instead of dynamic_update_slice: DUS with a dynamic
    # index into a sharded seq dim forces an all-gather under SPMD; the
    # blend is elementwise and partitions cleanly when the KV cache is
    # sequence-sharded (32k/500k decode).  Bandwidth trade-off recorded in
    # EXPERIMENTS.md §Perf.
    oh = jax.nn.one_hot(idx, cache["k"].shape[1],
                        dtype=jnp.float32)[:, :, None, None]
    k_cache = (cache["k"].astype(jnp.float32) * (1.0 - oh)
               + k_new.astype(jnp.float32) * oh).astype(cache["k"].dtype)
    v_cache = (cache["v"].astype(jnp.float32) * (1.0 - oh)
               + v_new.astype(jnp.float32) * oh).astype(cache["v"].dtype)
    out = decode_attention(q, k_cache, v_cache, idx + 1)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd)
    p = policy.cast(params)
    y = (out.astype(policy.compute_dtype) @ p["wo"]).astype(x.dtype)
    new_cache = {"k": k_cache, "v": v_cache, "length": idx + 1}
    return y, new_cache


def init_kv_cache(batch, max_len, cfg: AttentionConfig,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }
