"""xLSTM token mixers: mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory, strictly sequential scan).

mLSTM is evaluated through the shared :func:`repro.models.ssm.chunked_gla`
core — matrix memory with per-step scalar forget decay is exactly a gated
linear recurrence.  The normaliser state n_t is carried by augmenting the
value vectors with a ones column.

Deviation from the paper (recorded in DESIGN.md §7): we use sigmoid input
gates instead of exponential gates with the running max-stabiliser in the
*chunked* mLSTM path (the stabilised exponential form is not chunk-local);
sLSTM keeps the exact exponential gating + stabiliser since it is evaluated
step-by-step anyway.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import (DTypePolicy, DEFAULT_POLICY, dense_init,
                                 init_rmsnorm, apply_rmsnorm)
from repro.models.ssm import chunked_gla, gla_decode_step


# ---------------------------------------------------------------------------
# mLSTM.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    dim: int
    n_heads: int = 4
    expand: int = 2
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.dim

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def init_mlstm(key, cfg: MLSTMConfig, dtype=jnp.float32):
    di, nh = cfg.d_inner, cfg.n_heads
    ks = jax.random.split(key, 3)
    # in_proj packs q, k, v (d_inner each), o-gate (d_inner), f & i gates (nh each)
    return {
        "in_proj": dense_init(ks[0], cfg.dim, 4 * di + 2 * nh, dtype),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": dense_init(ks[1], di, cfg.dim, dtype),
    }


def _mlstm_project(params, x, cfg: MLSTMConfig, policy):
    b, s, _ = x.shape
    di, nh, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    p = policy.cast(params)
    proj = (x.astype(policy.compute_dtype) @ p["in_proj"]).astype(jnp.float32)
    q, k, v, o, fg, ig = jnp.split(
        proj, [di, 2 * di, 3 * di, 4 * di, 4 * di + nh], axis=-1)
    q = q.reshape(b, s, nh, hd) / math.sqrt(hd)
    k = k.reshape(b, s, nh, hd)
    v = v.reshape(b, s, nh, hd)
    log_decay = jax.nn.log_sigmoid(fg)                     # (B,S,H)
    k = k * jax.nn.sigmoid(ig)[..., None]                  # input gate
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)
    return q, k, v_aug, o, log_decay


def _mlstm_output(params, y_aug, o, x, cfg: MLSTMConfig, policy):
    b, s = x.shape[:2]
    di, hd = cfg.d_inner, cfg.head_dim
    y = y_aug[..., :hd]
    n = y_aug[..., hd:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(b, s, di)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.sigmoid(o)
    p = policy.cast(params)
    return (y.astype(policy.compute_dtype) @ p["out_proj"]).astype(x.dtype)


def apply_mlstm(params, x, cfg: MLSTMConfig,
                policy: DTypePolicy = DEFAULT_POLICY):
    q, k, v_aug, o, log_decay = _mlstm_project(params, x, cfg, policy)
    y_aug, _ = chunked_gla(q, k, v_aug, log_decay, chunk=cfg.chunk)
    return _mlstm_output(params, y_aug, o, x, cfg, policy)


def apply_mlstm_prefill(params, x, cfg: MLSTMConfig,
                        policy: DTypePolicy = DEFAULT_POLICY):
    q, k, v_aug, o, log_decay = _mlstm_project(params, x, cfg, policy)
    y_aug, final_state = chunked_gla(q, k, v_aug, log_decay, chunk=cfg.chunk)
    return _mlstm_output(params, y_aug, o, x, cfg, policy), \
        {"state": final_state}


def apply_mlstm_decode(params, x, cfg: MLSTMConfig, cache,
                       policy: DTypePolicy = DEFAULT_POLICY):
    """x (B,1,D); cache {'state': (B,H,Dk,Dv+1)}."""
    q, k, v_aug, o, log_decay = _mlstm_project(params, x, cfg, policy)
    y, new_state = gla_decode_step(cache["state"], q[:, 0], k[:, 0],
                                   v_aug[:, 0], log_decay[:, 0])
    out = _mlstm_output(params, y[:, None], o, x, cfg, policy)
    return out, {"state": new_state}


def init_mlstm_cache(batch, cfg: MLSTMConfig):
    return {"state": jnp.zeros(
        (batch, cfg.n_heads, cfg.head_dim, cfg.head_dim + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (exact exponential gating with stabiliser).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    dim: int
    n_heads: int = 4

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads


def init_slstm(key, cfg: SLSTMConfig, dtype=jnp.float32):
    nh, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    r_scale = 1.0 / math.sqrt(hd)
    return {
        "w_in": dense_init(ks[0], cfg.dim, 4 * cfg.dim, dtype),   # i,f,z,o
        "r": (jax.random.normal(ks[1], (4, nh, hd, hd), jnp.float32)
              * r_scale).astype(dtype),
        "b": jnp.zeros((4 * cfg.dim,), jnp.float32),
        "out_proj": dense_init(ks[2], cfg.dim, cfg.dim, dtype),
    }


def _slstm_step(params, wx_t, carry, cfg: SLSTMConfig, policy):
    """wx_t: (B, 4D) precomputed input projection for step t."""
    h_prev, c_prev, n_prev, m_prev = carry
    b = h_prev.shape[0]
    nh, hd = cfg.n_heads, cfg.head_dim
    r = params["r"].astype(jnp.float32)
    hh = h_prev.reshape(b, nh, hd)
    rec = jnp.einsum("bhd,ghde->bghe", hh, r).reshape(b, 4 * cfg.dim)
    pre = (wx_t + rec + params["b"]).reshape(b, 4, cfg.dim)
    i_raw, f_raw, z_raw, o_raw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]

    log_f = jax.nn.log_sigmoid(f_raw)
    m_t = jnp.maximum(log_f + m_prev, i_raw)
    i_p = jnp.exp(i_raw - m_t)
    f_p = jnp.exp(log_f + m_prev - m_t)
    c_t = f_p * c_prev + i_p * jnp.tanh(z_raw)
    n_t = f_p * n_prev + i_p
    h_t = jax.nn.sigmoid(o_raw) * c_t / jnp.maximum(n_t, 1.0)
    return (h_t, c_t, n_t, m_t)


def _slstm_scan(params, x, cfg: SLSTMConfig, policy):
    b, s, d = x.shape
    p = policy.cast(params)
    wx = (x.astype(policy.compute_dtype) @ p["w_in"]).astype(jnp.float32)

    def body(carry, wx_t):
        new = _slstm_step(params, wx_t, carry, cfg, policy)
        return new, new[0]

    zeros = jnp.zeros((b, d), jnp.float32)
    m0 = jnp.full((b, d), -1e30, jnp.float32)
    carry, hs = jax.lax.scan(body, (zeros, zeros, zeros, m0),
                             jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                              # (B,S,D)
    y = (y.astype(policy.compute_dtype) @ p["out_proj"]).astype(x.dtype)
    return y, carry


def apply_slstm(params, x, cfg: SLSTMConfig,
                policy: DTypePolicy = DEFAULT_POLICY):
    return _slstm_scan(params, x, cfg, policy)[0]


def apply_slstm_prefill(params, x, cfg: SLSTMConfig,
                        policy: DTypePolicy = DEFAULT_POLICY):
    y, (h, c, n, m) = _slstm_scan(params, x, cfg, policy)
    return y, {"h": h, "c": c, "n": n, "m": m}


def apply_slstm_decode(params, x, cfg: SLSTMConfig, cache,
                       policy: DTypePolicy = DEFAULT_POLICY):
    p = policy.cast(params)
    wx = (x.astype(policy.compute_dtype) @ p["w_in"]).astype(jnp.float32)
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    new = _slstm_step(params, wx[:, 0], carry, cfg, policy)
    y = (new[0].astype(policy.compute_dtype) @ p["out_proj"])[:, None]
    return y.astype(x.dtype), {"h": new[0], "c": new[1], "n": new[2],
                               "m": new[3]}


def init_slstm_cache(batch, cfg: SLSTMConfig):
    zeros = jnp.zeros((batch, cfg.dim), jnp.float32)
    return {"h": zeros, "c": zeros, "n": zeros,
            "m": jnp.full((batch, cfg.dim), -1e30, jnp.float32)}
