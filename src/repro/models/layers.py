"""Common neural-net layers (functional, pure JAX).

Every layer follows the convention ``init_*(key, ...) -> params`` and a
matching ``apply`` function.  Params are plain pytrees of ``jnp.ndarray``;
logical sharding axes for each leaf are produced by the twin ``*_spec``
functions in :mod:`repro.parallel.sharding` (kept structurally in sync via
tests).

Mixed precision: parameters are stored in ``param_dtype`` (default f32) and
cast to ``compute_dtype`` (default bf16) at use; layernorm/softmax/losses
run in f32.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    # Recurrent/accumulator dtype (DESIGN.md §10): scan carries, boundary
    # compositions and loss reductions stay here even when params and
    # streamed compute narrow to bf16.
    carry_dtype: jnp.dtype = jnp.float32

    def cast(self, p):
        return jax.tree.map(lambda a: a.astype(self.compute_dtype), p)


DEFAULT_POLICY = DTypePolicy()


# ---------------------------------------------------------------------------
# Initialisers.
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype=jnp.float32, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * s).astype(dtype)


def embed_init(key, vocab, dim, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, dim),
                                        jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_rmsnorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x, scale, eps):
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(ms + eps)[..., None].astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(ms + eps)
    y = x * inv[..., None].astype(x.dtype) * scale.astype(x.dtype)
    return y, (x, scale, inv)


def _rmsnorm_bwd(eps, res, g):
    # All cotangent math stays in x.dtype (f32 only for the row-reductions)
    # — a plain-autodiff rmsnorm contracts f32 cotangents against x, which
    # XLA hoists into an f32 copy of the whole per-layer residual stack
    # (+7–11 GB/device at 4k×256 scale).
    x, scale, inv = res
    d = x.shape[-1]
    sc = scale.astype(x.dtype)
    inv_c = inv[..., None].astype(x.dtype)
    gs = g * sc                                           # (..., d)
    dot = jnp.einsum("...d,...d->...", gs, x,
                     preferred_element_type=jnp.float32)
    coef = (dot * (inv ** 3) / d)[..., None].astype(x.dtype)
    dx = gs * inv_c - x * coef
    dscale = jnp.einsum("...d->d" if x.ndim == 2 else "...d->d",
                        (g * x * inv_c).astype(jnp.float32))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def apply_rmsnorm(params, x, eps: float = 1e-6):
    return _rmsnorm_core(x, params["scale"], eps)


def init_layernorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_swiglu(key, dim, hidden, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, dim, hidden, dtype),
        "up": dense_init(k2, dim, hidden, dtype),
        "down": dense_init(k3, hidden, dim, dtype),
    }


def apply_swiglu(params, x, policy: DTypePolicy = DEFAULT_POLICY):
    p = policy.cast(params)
    xc = x.astype(policy.compute_dtype)
    h = jax.nn.silu(xc @ p["gate"]) * (xc @ p["up"])
    return (h @ p["down"]).astype(x.dtype)


def init_gelu_mlp(key, dim, hidden, dtype=jnp.float32, bias: bool = True):
    k1, k2 = jax.random.split(key)
    p = {"fc1": dense_init(k1, dim, hidden, dtype),
         "fc2": dense_init(k2, hidden, dim, dtype)}
    if bias:
        p["b1"] = jnp.zeros((hidden,), dtype)
        p["b2"] = jnp.zeros((dim,), dtype)
    return p


def apply_gelu_mlp(params, x, policy: DTypePolicy = DEFAULT_POLICY):
    p = policy.cast(params)
    xc = x.astype(policy.compute_dtype)
    h = xc @ p["fc1"]
    if "b1" in p:
        h = h + p["b1"]
    h = jax.nn.gelu(h)
    y = h @ p["fc2"]
    if "b2" in p:
        y = y + p["b2"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections=(16, 24, 24), theta: float = 10000.0):
    """Qwen2-VL multimodal rotary embedding.

    x: (B, S, H, D); positions_3d: (3, B, S) — temporal/height/width ids.
    ``sections`` give the number of D/2 frequency slots per axis and must
    sum to D/2.  For pure-text streams all three id planes are equal and
    M-RoPE reduces exactly to RoPE.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # Select which positional plane drives each frequency slot.
    plane = jnp.repeat(jnp.arange(3), jnp.array(sections),
                       total_repeat_length=d // 2)     # (D/2,)
    # positions per frequency slot: gather the driving plane -> (D/2, B, S)
    pos_sel = positions_3d.astype(jnp.float32)[plane]
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs         # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses / heads.
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits, labels, mask=None):
    """Token-level CE, partition-friendly for vocab-sharded logits.

    ``take_along_axis`` over a sharded vocab dim forces SPMD to replicate
    the f32 logits (~40 GB/device on the 4k×256 train cell); the one-hot
    einsum below keeps every op a plain sharded reduction instead.
    """
    v = logits.shape[-1]
    m = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits.astype(jnp.float32) - m.astype(jnp.float32))
    lse = m.astype(jnp.float32)[..., 0] + jnp.log(jnp.sum(ex, axis=-1))
    onehot = jax.nn.one_hot(labels, v, dtype=logits.dtype)
    ll = jnp.einsum("...v,...v->...", logits.astype(jnp.float32),
                    onehot.astype(jnp.float32))
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Depthwise conv (LPU for GSPN blocks; causal conv1d for Mamba).
# ---------------------------------------------------------------------------

def init_dwconv2d(key, dim, k: int = 3, dtype=jnp.float32):
    w = jax.random.normal(key, (k, k, 1, dim), jnp.float32) * (1.0 / k)
    return {"w": w.astype(dtype), "b": jnp.zeros((dim,), dtype)}


def apply_dwconv2d(params, x):
    """x: (B, H, W, C) depthwise 'same' conv."""
    w = params["w"].astype(jnp.float32)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w,
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    return (y + params["b"].astype(jnp.float32)).astype(x.dtype)


def init_causal_conv1d(key, dim, k: int = 4, dtype=jnp.float32):
    w = jax.random.normal(key, (k, 1, dim), jnp.float32) * (1.0 / math.sqrt(k))
    return {"w": w.astype(dtype), "b": jnp.zeros((dim,), dtype)}


def apply_causal_conv1d(params, x, state: Optional[jnp.ndarray] = None):
    """x: (B, S, C).  Causal depthwise conv.  If ``state`` (B, k-1, C) is
    given, runs in streaming mode and returns (y, new_state)."""
    w = params["w"].astype(jnp.float32)          # (k, 1, C)
    k = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is not None:
        xa = jnp.concatenate([state.astype(jnp.float32), xf], axis=1)
        new_state = xa[:, -(k - 1):] if k > 1 else jnp.zeros_like(state)
    else:
        xa = jnp.pad(xf, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = None
    y = jax.lax.conv_general_dilated(
        xa, w, window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    y = (y + params["b"].astype(jnp.float32)).astype(x.dtype)
    return (y, new_state) if state is not None else (y, None)
