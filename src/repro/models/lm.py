"""Unified causal language model covering every assigned architecture.

A model is described by :class:`LMConfig`:

* ``prelude`` — a list of ``(kind, n)`` stages applied once, in order
  (e.g. Kimi-K2's first dense layer);
* ``unit`` — a list of ``(kind, n)`` sub-stages forming a repeating unit;
* ``n_units`` — how many times the unit repeats.  The decoder executes
  ``prelude + unit × n_units``.
* ``shared_attn`` — Zamba2-style: one *weight-shared* attention block
  applied at the end of every unit.

Layer stacks are executed with ``lax.scan`` over stacked parameters (outer
scan over units, inner scan over each sub-stage), which keeps the HLO size
independent of depth — essential for compile times of 60–80-layer models
and for the multi-pod dry-run.

Block kinds:
  attn       pre-norm GQA attention + dense FFN
  attn_moe   pre-norm GQA attention + MoE FFN (aux loss accumulated)
  mamba      pre-norm Mamba2 mixer (no FFN, Zamba2 style)
  mlstm      pre-norm mLSTM mixer
  slstm      pre-norm sLSTM mixer
  gspn       pre-norm GSPN-2 sequence mixer (paper technique) + dense FFN
  xattn      self-attn + cross-attn + FFN (whisper decoder)

Each kind registers init / train-forward / decode-step / cache-init
functions in ``KINDS``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import gspn as gspn_core
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (DTypePolicy, dense_init,
                                 embed_init, init_rmsnorm, apply_rmsnorm,
                                 init_layernorm, apply_layernorm,
                                 init_swiglu, apply_swiglu,
                                 init_gelu_mlp, apply_gelu_mlp,
                                 cross_entropy_loss)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[tuple] = None
    norm: str = "rmsnorm"
    mlp: str = "swiglu"
    tie_embeddings: bool = False
    max_seq: int = 4096
    # structure
    prelude: tuple = ()            # ((kind, n), ...)
    unit: tuple = ()               # ((kind, n), ...)
    n_units: int = 1
    shared_attn: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / xLSTM
    ssm_state: int = 64
    ssm_head_dim: int = 64
    gla_chunk: int = 256
    # GSPN mixer
    gspn_proxy_dim: int = 8
    gspn_row_width: int = 64
    gspn_impl: str = "xla"         # "sp" shards the folded-grid scans over
    gspn_seq_axis: str = "seq"     # the mesh's seq axis (DESIGN.md §8)
    gspn_sp_strategy: str = "auto"
    # Streamed compute dtype of the GSPN mixer's scans (DESIGN.md §10).
    # Defaults to f32 independently of ``compute_dtype`` so the mixer's
    # chunked≡one-shot equivalence stays exact unless a mixed-precision
    # policy (configs.base.with_precision) opts the scans into bf16.
    gspn_compute_dtype: Any = jnp.float32
    # encoder-decoder (audio)
    encoder_layers: int = 0
    enc_len: int = 1500
    # distribution / execution
    n_model_shards: int = 1
    remat: str = "unit"            # none|unit|dots
    attn_block_k: int = 512
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # Scan-carry / accumulator dtype (DESIGN.md §10): stays f32 under the
    # default mixed-precision policy even when params/compute are bf16.
    carry_dtype: Any = jnp.float32

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def policy(self) -> DTypePolicy:
        return DTypePolicy(self.param_dtype, self.compute_dtype,
                           self.carry_dtype)

    def stages(self):
        """Flattened (where, kind, n) list: prelude then unit."""
        return [("prelude", k, n) for k, n in self.prelude] + \
               [("unit", k, n) for k, n in self.unit]

    def layer_count(self) -> int:
        n = sum(n for _, n in self.prelude)
        n += self.n_units * sum(n for _, n in self.unit)
        if self.shared_attn:
            n += self.n_units  # shared block applications (1 weight set)
        return n


@dataclasses.dataclass
class Ctx:
    """Per-call execution context threaded through apply functions."""
    mesh: Any = None
    dp_axes: tuple = ("data",)
    model_axis: str = "model"

    def anchor(self, x):
        """Constrain activations to batch-over-dp sharding.  Anchoring at
        block boundaries keeps the SPMD partitioner in the FSDP regime
        (all-gather weights) instead of unsharding the batch to satisfy
        contraction-dim weight sharding (parallel/sharding.py note)."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import sanitize_spec
        spec = P(self.dp_axes) if len(self.dp_axes) > 1 else P(self.dp_axes[0])
        spec = sanitize_spec(
            P(*(spec + (None,) * (x.ndim - 1))), x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Config helpers for sub-modules.
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: LMConfig, causal=True, cross=False):
    return attn_mod.AttentionConfig(
        dim=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        mrope_sections=None if cross else cfg.mrope_sections,
        causal=causal, block_k=cfg.attn_block_k)


def _moe_cfg(cfg: LMConfig):
    return moe_mod.MoEConfig(
        dim=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_ff=cfg.moe_d_ff or cfg.d_ff, n_shards=cfg.n_model_shards,
        capacity_factor=cfg.capacity_factor,
        shared_expert_ff=cfg.shared_expert_ff)


def _mamba_cfg(cfg: LMConfig):
    return ssm_mod.Mamba2Config(
        dim=cfg.d_model, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
        chunk=cfg.gla_chunk)


def _mlstm_cfg(cfg: LMConfig):
    return xlstm_mod.MLSTMConfig(dim=cfg.d_model, n_heads=cfg.n_heads,
                                 chunk=cfg.gla_chunk)


def _slstm_cfg(cfg: LMConfig):
    return xlstm_mod.SLSTMConfig(dim=cfg.d_model, n_heads=cfg.n_heads)


def _gspn_cfg(cfg: LMConfig):
    return gspn_core.GSPNSeqConfig(
        dim=cfg.d_model, proxy_dim=cfg.gspn_proxy_dim,
        row_width=cfg.gspn_row_width, impl=cfg.gspn_impl,
        seq_axis=cfg.gspn_seq_axis, sp_strategy=cfg.gspn_sp_strategy,
        param_dtype=cfg.param_dtype,
        compute_dtype=cfg.gspn_compute_dtype,
        carry_dtype=cfg.carry_dtype)


def _norm_init(cfg: LMConfig):
    return (init_rmsnorm if cfg.norm == "rmsnorm" else init_layernorm)(
        cfg.d_model, cfg.param_dtype)


def _norm_apply(cfg: LMConfig, p, x):
    return (apply_rmsnorm if cfg.norm == "rmsnorm" else apply_layernorm)(p, x)


def _ffn_init(key, cfg: LMConfig):
    if cfg.mlp == "swiglu":
        return init_swiglu(key, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return init_gelu_mlp(key, cfg.d_model, cfg.d_ff, cfg.param_dtype)


def _ffn_apply(cfg: LMConfig, p, x):
    if cfg.mlp == "swiglu":
        return apply_swiglu(p, x, cfg.policy)
    return apply_gelu_mlp(p, x, cfg.policy)


# ---------------------------------------------------------------------------
# Block kinds.
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: LMConfig, with_ffn=True, cross=False):
    ks = jax.random.split(key, 6)
    p = {"ln1": _norm_init(cfg),
         "attn": attn_mod.init_attention(ks[0], _attn_cfg(cfg),
                                         cfg.param_dtype)}
    if cross:
        p["ln_x"] = _norm_init(cfg)
        p["xattn"] = attn_mod.init_attention(ks[1], _attn_cfg(cfg, cross=True),
                                             cfg.param_dtype)
    if with_ffn:
        p["ln2"] = _norm_init(cfg)
        p["ffn"] = _ffn_init(ks[2], cfg)
    return p


def _apply_attn_block(p, x, cfg, ctx, positions, enc_kv=None, moe=False):
    aux = jnp.zeros((), jnp.float32)
    h = _norm_apply(cfg, p["ln1"], x)
    x = x + attn_mod.apply_attention(p["attn"], h, _attn_cfg(cfg),
                                     positions=positions, policy=cfg.policy)
    if enc_kv is not None:
        h = _norm_apply(cfg, p["ln_x"], x)
        x = x + attn_mod.apply_attention(
            p["xattn"], h, _attn_cfg(cfg, cross=True), kv=enc_kv,
            policy=cfg.policy)
    if moe:
        h = _norm_apply(cfg, p["ln2"], x)
        y, aux = moe_mod.apply_moe(p["moe"], h, _moe_cfg(cfg),
                                   mesh=ctx.mesh, dp_axes=ctx.dp_axes,
                                   model_axis=ctx.model_axis,
                                   policy=cfg.policy)
        x = x + y
    elif "ffn" in p:
        h = _norm_apply(cfg, p["ln2"], x)
        x = x + _ffn_apply(cfg, p["ffn"], h)
    return x, aux


def _apply_attn_block_decode(p, x, cfg, ctx, cache, enc_kv=None, moe=False):
    h = _norm_apply(cfg, p["ln1"], x)
    y, new_attn = attn_mod.apply_attention_decode(
        p["attn"], h, _attn_cfg(cfg), cache["attn"], policy=cfg.policy)
    x = x + y
    if enc_kv is not None:
        h = _norm_apply(cfg, p["ln_x"], x)
        x = x + attn_mod.apply_attention(
            p["xattn"], h, _attn_cfg(cfg, cross=True), kv=enc_kv,
            policy=cfg.policy)
    if moe:
        h = _norm_apply(cfg, p["ln2"], x)
        y, _ = moe_mod.apply_moe(p["moe"], h, _moe_cfg(cfg), mesh=ctx.mesh,
                                 dp_axes=ctx.dp_axes,
                                 model_axis=ctx.model_axis, policy=cfg.policy)
        x = x + y
    elif "ffn" in p:
        h = _norm_apply(cfg, p["ln2"], x)
        x = x + _ffn_apply(cfg, p["ffn"], h)
    return x, {"attn": new_attn}


class Kind:
    """Registry record for a block kind.

    ``apply_prefill_chunk`` (optional) consumes a T-token prompt chunk at
    absolute offset ``off`` against an already-initialised decode cache and
    returns (y, new_cache) — the incremental-prefill contract the serving
    engine chunks prompts through (DESIGN.md §9).  Kinds without it force
    the engine onto the one-shot prefill path.
    """

    def __init__(self, init, apply, apply_decode, cache_init,
                 apply_prefill=None, apply_prefill_chunk=None):
        self.init = init
        self.apply = apply
        self.apply_decode = apply_decode
        self.cache_init = cache_init
        self.apply_prefill = apply_prefill
        self.apply_prefill_chunk = apply_prefill_chunk


def _mk_attn_kind(moe=False, cross=False):
    def init(key, cfg):
        p = _init_attn_block(key, cfg, with_ffn=not moe, cross=cross)
        if moe:
            p["ln2"] = _norm_init(cfg)
            p["moe"] = moe_mod.init_moe(jax.random.fold_in(key, 101),
                                        _moe_cfg(cfg), cfg.param_dtype)
        return p

    def apply(p, x, cfg, ctx, positions, enc_kv=None):
        return _apply_attn_block(p, x, cfg, ctx, positions,
                                 enc_kv=enc_kv if cross else None, moe=moe)

    def apply_decode(p, x, cfg, ctx, cache, enc_kv=None):
        return _apply_attn_block_decode(p, x, cfg, ctx, cache,
                                        enc_kv=enc_kv if cross else None,
                                        moe=moe)

    def cache_init(batch, max_len, cfg):
        return {"attn": attn_mod.init_kv_cache(batch, max_len, _attn_cfg(cfg),
                                               cfg.compute_dtype)}

    def apply_prefill(p, x, cfg, ctx, positions, max_len, enc_kv=None):
        b, s, _ = x.shape
        acfg = _attn_cfg(cfg)
        h = _norm_apply(cfg, p["ln1"], x)
        q, k, v = attn_mod._project_qkv(p["attn"], h, acfg, cfg.policy)
        q, k = attn_mod._apply_positions(q, k, positions, acfg)
        if acfg.use_chunked and k.shape[1] > acfg.block_k:
            out = attn_mod.chunked_attention(q, k, v, causal=True,
                                             block_k=acfg.block_k)
        else:
            out = attn_mod.full_attention(q, k, v, causal=True)
        out = out.reshape(b, s, acfg.n_heads * acfg.hd)
        pc = cfg.policy.cast(p["attn"])
        x = x + (out.astype(cfg.policy.compute_dtype) @ pc["wo"]).astype(x.dtype)
        pad = max_len - s
        cache = {"attn": {
            "k": jnp.pad(k.astype(cfg.compute_dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v.astype(cfg.compute_dtype),
                         ((0, 0), (0, pad), (0, 0), (0, 0))),
            "length": jnp.full((b,), s, jnp.int32),
        }}
        if cross and enc_kv is not None:
            h = _norm_apply(cfg, p["ln_x"], x)
            x = x + attn_mod.apply_attention(
                p["xattn"], h, _attn_cfg(cfg, cross=True), kv=enc_kv,
                policy=cfg.policy)
        if moe:
            h = _norm_apply(cfg, p["ln2"], x)
            y, _ = moe_mod.apply_moe(p["moe"], h, _moe_cfg(cfg),
                                     mesh=ctx.mesh, dp_axes=ctx.dp_axes,
                                     model_axis=ctx.model_axis,
                                     policy=cfg.policy)
            x = x + y
        elif "ffn" in p:
            h = _norm_apply(cfg, p["ln2"], x)
            x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, cache

    def apply_prefill_chunk(p, x, cfg, ctx, cache, off, enc_kv=None):
        """Consume a (B, T) prompt chunk at offset ``off`` (traced scalar):
        write the chunk's K/V into the cache in place and attend over the
        cache with the offset causal mask — equal to one-shot prefill
        restricted to these T rows (DESIGN.md §9)."""
        b, t, _ = x.shape
        acfg = _attn_cfg(cfg)
        h = _norm_apply(cfg, p["ln1"], x)
        q, k, v = attn_mod._project_qkv(p["attn"], h, acfg, cfg.policy)
        positions = jnp.broadcast_to(
            off + jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
        q, k = attn_mod._apply_positions(q, k, positions, acfg)
        kc = jax.lax.dynamic_update_slice(
            cache["attn"]["k"], k.astype(cache["attn"]["k"].dtype),
            (0, off, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["attn"]["v"], v.astype(cache["attn"]["v"].dtype),
            (0, off, 0, 0))
        out = attn_mod.chunk_prefill_attention(q, kc, vc, off)
        out = out.reshape(b, t, acfg.n_heads * acfg.hd)
        pc = cfg.policy.cast(p["attn"])
        x = x + (out.astype(cfg.policy.compute_dtype)
                 @ pc["wo"]).astype(x.dtype)
        new_cache = {"attn": {
            "k": kc, "v": vc,
            "length": jnp.full((b,), 0, jnp.int32) + off + t,
        }}
        if moe:
            h = _norm_apply(cfg, p["ln2"], x)
            y, _ = moe_mod.apply_moe(p["moe"], h, _moe_cfg(cfg),
                                     mesh=ctx.mesh, dp_axes=ctx.dp_axes,
                                     model_axis=ctx.model_axis,
                                     policy=cfg.policy)
            x = x + y
        elif "ffn" in p:
            h = _norm_apply(cfg, p["ln2"], x)
            x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, new_cache

    return Kind(init, apply, apply_decode, cache_init, apply_prefill,
                apply_prefill_chunk=None if cross else apply_prefill_chunk)


def _mk_mixer_kind(name):
    def init(key, cfg):
        k1, k2 = jax.random.split(key)
        p = {"ln1": _norm_init(cfg)}
        if name == "mamba":
            p["mix"] = ssm_mod.init_mamba2(k1, _mamba_cfg(cfg),
                                           cfg.param_dtype)
        elif name == "mlstm":
            p["mix"] = xlstm_mod.init_mlstm(k1, _mlstm_cfg(cfg),
                                            cfg.param_dtype)
        elif name == "slstm":
            p["mix"] = xlstm_mod.init_slstm(k1, _slstm_cfg(cfg),
                                            cfg.param_dtype)
        elif name == "gspn":
            p["mix"] = gspn_core.init_gspn_seq_mixer(k1, _gspn_cfg(cfg))
            p["ln2"] = _norm_init(cfg)
            p["ffn"] = _ffn_init(k2, cfg)
        return p

    def apply(p, x, cfg, ctx, positions, enc_kv=None):
        h = _norm_apply(cfg, p["ln1"], x)
        if name == "mamba":
            x = x + ssm_mod.apply_mamba2(p["mix"], h, _mamba_cfg(cfg),
                                         cfg.policy)
        elif name == "mlstm":
            x = x + xlstm_mod.apply_mlstm(p["mix"], h, _mlstm_cfg(cfg),
                                          cfg.policy)
        elif name == "slstm":
            x = x + xlstm_mod.apply_slstm(p["mix"], h, _slstm_cfg(cfg),
                                          cfg.policy)
        elif name == "gspn":
            x = x + gspn_core.apply_gspn_seq_mixer(
                p["mix"], h, _gspn_cfg(cfg),
                mesh=ctx.mesh if ctx is not None else None)
            h = _norm_apply(cfg, p["ln2"], x)
            x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, jnp.zeros((), jnp.float32)

    def apply_decode(p, x, cfg, ctx, cache, enc_kv=None):
        h = _norm_apply(cfg, p["ln1"], x)
        if name == "mamba":
            y, new = ssm_mod.apply_mamba2_decode(p["mix"], h,
                                                 _mamba_cfg(cfg), cache,
                                                 cfg.policy)
            return x + y, new
        if name == "mlstm":
            y, new = xlstm_mod.apply_mlstm_decode(p["mix"], h,
                                                  _mlstm_cfg(cfg), cache,
                                                  cfg.policy)
            return x + y, new
        if name == "slstm":
            y, new = xlstm_mod.apply_slstm_decode(p["mix"], h,
                                                  _slstm_cfg(cfg), cache,
                                                  cfg.policy)
            return x + y, new
        if name == "gspn":
            y, new = gspn_decode_step(p["mix"], h, _gspn_cfg(cfg), cache)
            x = x + y
            h = _norm_apply(cfg, p["ln2"], x)
            x = x + _ffn_apply(cfg, p["ffn"], h)
            return x, new
        raise ValueError(name)

    def cache_init(batch, max_len, cfg):
        if name == "mamba":
            return ssm_mod.init_mamba2_cache(batch, _mamba_cfg(cfg),
                                             jnp.float32)
        if name == "mlstm":
            return xlstm_mod.init_mlstm_cache(batch, _mlstm_cfg(cfg))
        if name == "slstm":
            return xlstm_mod.init_slstm_cache(batch, _slstm_cfg(cfg))
        if name == "gspn":
            return init_gspn_decode_cache(batch, _gspn_cfg(cfg))
        raise ValueError(name)

    def apply_prefill(p, x, cfg, ctx, positions, max_len, enc_kv=None):
        h = _norm_apply(cfg, p["ln1"], x)
        if name == "mamba":
            y, cache = ssm_mod.apply_mamba2_prefill(p["mix"], h,
                                                    _mamba_cfg(cfg),
                                                    cfg.policy)
            return x + y, cache
        if name == "mlstm":
            y, cache = xlstm_mod.apply_mlstm_prefill(p["mix"], h,
                                                     _mlstm_cfg(cfg),
                                                     cfg.policy)
            return x + y, cache
        if name == "slstm":
            y, cache = xlstm_mod.apply_slstm_prefill(p["mix"], h,
                                                     _slstm_cfg(cfg),
                                                     cfg.policy)
            return x + y, cache
        if name == "gspn":
            y, cache = gspn_core.apply_gspn_seq_mixer(
                p["mix"], h, _gspn_cfg(cfg), return_cache=True,
                mesh=ctx.mesh if ctx is not None else None)
            x = x + y
            h = _norm_apply(cfg, p["ln2"], x)
            x = x + _ffn_apply(cfg, p["ffn"], h)
            return x, cache
        raise ValueError(name)

    def apply_prefill_chunk(p, x, cfg, ctx, cache, off, enc_kv=None):
        # Only the GSPN mixer has a resumable chunked scan; the other
        # mixers' prefill paths start from a zero state, so the engine
        # keeps them on one-shot prefill (supports_chunked_prefill).
        h = _norm_apply(cfg, p["ln1"], x)
        y, new = gspn_core.gspn_seq_prefill_chunk(
            p["mix"], h, _gspn_cfg(cfg), cache,
            mesh=ctx.mesh if ctx is not None else None)
        x = x + y
        h = _norm_apply(cfg, p["ln2"], x)
        x = x + _ffn_apply(cfg, p["ffn"], h)
        return x, new

    return Kind(init, apply, apply_decode, cache_init, apply_prefill,
                apply_prefill_chunk if name == "gspn" else None)


KINDS = {
    "attn": _mk_attn_kind(moe=False),
    "attn_moe": _mk_attn_kind(moe=True),
    "xattn": _mk_attn_kind(moe=False, cross=True),
    "mamba": _mk_mixer_kind("mamba"),
    "mlstm": _mk_mixer_kind("mlstm"),
    "slstm": _mk_mixer_kind("slstm"),
    "gspn": _mk_mixer_kind("gspn"),
}


# ---------------------------------------------------------------------------
# GSPN sequence-mixer decode (O(W) state — "last row" caching).
# ---------------------------------------------------------------------------

def init_gspn_decode_cache(batch, scfg: gspn_core.GSPNSeqConfig):
    w = scfg.row_width or 64
    cp = scfg.proxy_dim
    return {
        "prev_row": jnp.zeros((batch, cp, w), jnp.float32),
        "cur_row": jnp.zeros((batch, cp, w), jnp.float32),
        "row_state": jnp.zeros((batch, cp), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def gspn_decode_step(params, x, scfg: gspn_core.GSPNSeqConfig, cache):
    """One-token GSPN mixer step.  x (B,1,D).  Maintains the previous
    grid row (T→B pass) and the running within-row state — O(√L) memory."""
    b, _, d = x.shape
    cp = scfg.proxy_dim
    w = cache["prev_row"].shape[-1]
    xf = x[:, 0].astype(jnp.float32)                     # (B,D)

    x_p = xf @ params["down"].astype(jnp.float32)        # (B,Cp)
    tap_logits = xf @ params["w_taps"].astype(jnp.float32)   # (B,3)
    row_g = jax.nn.sigmoid(xf @ params["w_row"].astype(jnp.float32))  # (B,1)
    lam = jax.nn.sigmoid(xf @ params["w_lam"].astype(jnp.float32))    # (B,2Cp)
    u = xf @ params["w_u"].astype(jnp.float32)           # (B,2Cp)

    j = cache["pos"] % w                                 # (B,)
    # neighbours of column j in the previous row (boundary -> 0)
    def gather_col(rows, idx, valid):
        g = jnp.take_along_axis(
            rows, jnp.clip(idx, 0, w - 1)[:, None, None], axis=-1)[..., 0]
        return jnp.where(valid[:, None], g, 0.0)         # (B,Cp)

    h_l = gather_col(cache["prev_row"], j - 1, j - 1 >= 0)
    h_c = gather_col(cache["prev_row"], j, jnp.ones_like(j, bool))
    h_r = gather_col(cache["prev_row"], j + 1, j + 1 <= w - 1)

    # masked softmax over taps, matching normalize_taps boundary rules
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.stack([jnp.where(j == 0, neg, 0.0),
                      jnp.zeros_like(j, jnp.float32),
                      jnp.where(j == w - 1, neg, 0.0)], axis=-1)
    taps = jax.nn.softmax(tap_logits + mask, axis=-1)    # (B,3)

    h_tb = (taps[:, 0:1] * h_l + taps[:, 1:2] * h_c + taps[:, 2:3] * h_r
            + lam[:, :cp] * x_p)                         # (B,Cp)
    # within-row: reset at row start
    at_row_start = (j == 0)[:, None]
    row_prev = jnp.where(at_row_start, 0.0, cache["row_state"])
    h_row = row_g * row_prev + lam[:, cp:] * x_p

    y = u[:, :cp] * h_tb + u[:, cp:] * h_row
    y = (y @ params["up"].astype(jnp.float32))[:, None]  # (B,1,D)

    cur = jnp.where(at_row_start[..., None],
                    jnp.zeros_like(cache["cur_row"]), cache["cur_row"])
    # write column j of cur_row
    onehot = jax.nn.one_hot(j, w, dtype=jnp.float32)     # (B,W)
    cur = cur * (1.0 - onehot[:, None, :]) + h_tb[..., None] * onehot[:, None, :]
    at_row_end = (j == w - 1)[:, None, None]
    new_prev = jnp.where(at_row_end, cur, cache["prev_row"])
    new_cache = {"prev_row": new_prev, "cur_row": cur,
                 "row_state": h_row, "pos": cache["pos"] + 1}
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Whisper-style encoder (stub frontend: embeddings provided).
# ---------------------------------------------------------------------------

def _init_encoder(key, cfg: LMConfig):
    def one(k):
        p = {"ln1": _norm_init(cfg),
             "attn": attn_mod.init_attention(
                 jax.random.fold_in(k, 0), _attn_cfg(cfg, causal=False),
                 cfg.param_dtype),
             "ln2": _norm_init(cfg),
             "ffn": _ffn_init(jax.random.fold_in(k, 1), cfg)}
        return p

    keys = jax.random.split(key, cfg.encoder_layers)
    stacked = jax.vmap(one)(keys)
    k2 = jax.random.fold_in(key, 99)
    return {"layers": stacked, "ln_f": _norm_init(cfg),
            "pos_embed": embed_init(k2, cfg.enc_len, cfg.d_model,
                                    cfg.param_dtype)}


def _apply_encoder(params, frames, cfg: LMConfig):
    """frames: (B, T, D) stub frame embeddings."""
    x = frames + params["pos_embed"].astype(frames.dtype)[None, :frames.shape[1]]
    acfg = _attn_cfg(cfg, causal=False)

    def body(x, layer):
        h = _norm_apply(cfg, layer["ln1"], x)
        x = x + attn_mod.apply_attention(layer["attn"], h, acfg,
                                         policy=cfg.policy)
        h = _norm_apply(cfg, layer["ln2"], x)
        x = x + _ffn_apply(cfg, layer["ffn"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return _norm_apply(cfg, params["ln_f"], x)


# ---------------------------------------------------------------------------
# Model init.
# ---------------------------------------------------------------------------

def init_lm(key, cfg: LMConfig):
    params = {}
    k_embed, k_head, k_stage, k_enc, k_shared = jax.random.split(key, 5)
    params["embed"] = embed_init(k_embed, cfg.vocab, cfg.d_model,
                                 cfg.param_dtype)
    params["ln_f"] = _norm_init(cfg)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab,
                                    cfg.param_dtype)

    stages = {}
    for si, (where, kind, n) in enumerate(cfg.stages()):
        kf = KINDS[kind]
        base = jax.random.fold_in(k_stage, si)
        if where == "prelude":
            keys = jax.random.split(base, n)
            stacked = jax.vmap(lambda k: kf.init(k, cfg))(keys)
        else:
            keys = jax.random.split(base, cfg.n_units * n).reshape(
                cfg.n_units, n, 2)
            stacked = jax.vmap(jax.vmap(lambda k: kf.init(k, cfg)))(keys)
        stages[f"s{si}_{kind}"] = stacked
    params["stages"] = stages

    if cfg.shared_attn:
        params["shared_attn"] = KINDS["attn"].init(k_shared, cfg)
    if cfg.encoder_layers:
        params["encoder"] = _init_encoder(k_enc, cfg)
        kx = jax.random.fold_in(k_enc, 7)
        acfg = _attn_cfg(cfg)
        params["enc_kv_proj"] = {
            "wk": dense_init(kx, cfg.d_model,
                             cfg.n_kv_heads * acfg.hd, cfg.param_dtype),
            "wv": dense_init(jax.random.fold_in(kx, 1), cfg.d_model,
                             cfg.n_kv_heads * acfg.hd, cfg.param_dtype),
        }
    return params


def _encoder_kv(params, enc_out, cfg: LMConfig):
    b, t, _ = enc_out.shape
    acfg = _attn_cfg(cfg)
    pol = cfg.policy
    wk = params["enc_kv_proj"]["wk"].astype(pol.compute_dtype)
    wv = params["enc_kv_proj"]["wv"].astype(pol.compute_dtype)
    k = (enc_out.astype(pol.compute_dtype) @ wk).reshape(
        b, t, cfg.n_kv_heads, acfg.hd)
    v = (enc_out.astype(pol.compute_dtype) @ wv).reshape(
        b, t, cfg.n_kv_heads, acfg.hd)
    return (k, v)


# ---------------------------------------------------------------------------
# Forward (training / prefill).
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: LMConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def apply_lm(params, cfg: LMConfig, tokens, *, ctx: Ctx = None,
             vision_embeds=None, enc_frames=None, positions=None):
    """Forward pass producing logits (B, S, V).

    tokens: (B, S) int32.  ``vision_embeds`` (B, S_vis, D) replace the
    embeddings of the first S_vis positions (Qwen2-VL stub frontend);
    ``enc_frames`` (B, T, D) drive the audio encoder (whisper stub).
    """
    ctx = ctx or Ctx()
    pol = cfg.policy
    x = params["embed"].astype(pol.compute_dtype)[tokens]
    if vision_embeds is not None:
        sv = vision_embeds.shape[1]
        x = jnp.concatenate(
            [vision_embeds.astype(pol.compute_dtype), x[:, sv:]], axis=1)
    x = ctx.anchor(x)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    enc_kv = None
    if cfg.encoder_layers and enc_frames is not None:
        enc_out = _apply_encoder(params["encoder"], enc_frames, cfg)
        enc_kv = _encoder_kv(params, enc_out, cfg)

    aux_total = jnp.zeros((), jnp.float32)

    def stage_scan(x, aux_total, stacked, kind):
        kf = KINDS[kind]

        def body(carry, layer_params):
            h, aux = carry
            h, a = kf.apply(layer_params, ctx.anchor(h), cfg, ctx, positions,
                            enc_kv=enc_kv)
            return (ctx.anchor(h), aux + a), None

        body = _maybe_remat(cfg, body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
        return x, aux_total

    stages = cfg.stages()
    for si, (where, kind, n) in enumerate(stages):
        stacked = params["stages"][f"s{si}_{kind}"]
        if where == "prelude":
            x, aux_total = stage_scan(x, aux_total, stacked, kind)

    unit_stages = [(si, kind) for si, (w, kind, n) in enumerate(stages)
                   if w == "unit"]
    if unit_stages:
        def unit_body(carry, unit_params):
            h, aux = carry
            for si, kind in unit_stages:
                kf = KINDS[kind]

                def body(c, lp, kf=kf):
                    hh, a0 = c
                    hh, a = kf.apply(lp, ctx.anchor(hh), cfg, ctx, positions,
                                     enc_kv=enc_kv)
                    return (ctx.anchor(hh), a0 + a), None

                body = _maybe_remat(cfg, body)
                (h, aux), _ = jax.lax.scan(body, (h, aux),
                                           unit_params[f"s{si}_{kind}"])
            if cfg.shared_attn:
                h, a = KINDS["attn"].apply(params["shared_attn"], h, cfg,
                                           ctx, positions)
                aux = aux + a
            return (h, aux), None

        unit_params = {f"s{si}_{kind}": params["stages"][f"s{si}_{kind}"]
                       for si, kind in unit_stages}
        (x, aux_total), _ = jax.lax.scan(unit_body, (x, aux_total),
                                         unit_params)

    x = _norm_apply(cfg, params["ln_f"], ctx.anchor(x))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(pol.compute_dtype)
    logits = x.astype(pol.compute_dtype) @ head
    return logits, aux_total


def lm_loss(params, cfg: LMConfig, batch, ctx: Ctx = None):
    """batch: dict(tokens (B,S), labels (B,S), [mask], [vision_embeds],
    [enc_frames])."""
    logits, aux = apply_lm(params, cfg, batch["tokens"], ctx=ctx,
                           vision_embeds=batch.get("vision_embeds"),
                           enc_frames=batch.get("enc_frames"))
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill: forward over the prompt that also fills the decode caches.
# ---------------------------------------------------------------------------

def lm_prefill(params, cfg: LMConfig, tokens, max_len: int, *,
               ctx: Ctx = None, enc_frames=None, vision_embeds=None):
    """Returns (logits (B,S,V), caches, enc_kv)."""
    ctx = ctx or Ctx()
    pol = cfg.policy
    x = params["embed"].astype(pol.compute_dtype)[tokens]
    if vision_embeds is not None:
        sv = vision_embeds.shape[1]
        x = jnp.concatenate(
            [vision_embeds.astype(pol.compute_dtype), x[:, sv:]], axis=1)
    x = ctx.anchor(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    enc_kv = None
    if cfg.encoder_layers and enc_frames is not None:
        enc_out = _apply_encoder(params["encoder"], enc_frames, cfg)
        enc_kv = _encoder_kv(params, enc_out, cfg)

    caches = {}
    stages = cfg.stages()
    for si, (where, kind, n) in enumerate(stages):
        if where != "prelude":
            continue
        kf = KINDS[kind]

        def body(h, lp, kf=kf):
            h, cache = kf.apply_prefill(lp, ctx.anchor(h), cfg, ctx,
                                        positions, max_len, enc_kv=enc_kv)
            return ctx.anchor(h), cache

        x, cache = jax.lax.scan(body, x, params["stages"][f"s{si}_{kind}"])
        caches[f"s{si}_{kind}"] = cache

    unit_stages = [(si, kind) for si, (w, kind, n) in enumerate(stages)
                   if w == "unit"]
    if unit_stages:
        def unit_body(h, unit_params):
            new_unit = {}
            for si, kind in unit_stages:
                kf = KINDS[kind]

                def body(hh, lp, kf=kf):
                    hh, cache = kf.apply_prefill(lp, ctx.anchor(hh), cfg, ctx,
                                                 positions, max_len,
                                                 enc_kv=enc_kv)
                    return ctx.anchor(hh), cache

                h, cache = jax.lax.scan(body, h,
                                        unit_params[f"s{si}_{kind}"])
                new_unit[f"s{si}_{kind}"] = cache
            if cfg.shared_attn:
                h, sh_cache = KINDS["attn"].apply_prefill(
                    params["shared_attn"], h, cfg, ctx, positions, max_len)
                new_unit["shared_attn"] = sh_cache
            return h, new_unit

        unit_params = {f"s{si}_{kind}": params["stages"][f"s{si}_{kind}"]
                       for si, kind in unit_stages}
        x, unit_caches = jax.lax.scan(unit_body, x, unit_params)
        caches.update(unit_caches)

    x = _norm_apply(cfg, params["ln_f"], ctx.anchor(x))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(pol.compute_dtype)
    logits = x.astype(pol.compute_dtype) @ head
    return logits, caches, enc_kv


# ---------------------------------------------------------------------------
# Chunked prefill: consume the prompt in fixed-size chunks against live
# decode caches (DESIGN.md §9).  Shares weights with lm_prefill /
# lm_decode_step — it is the same stage walk with apply_prefill_chunk.
# ---------------------------------------------------------------------------

def supports_chunked_prefill(cfg: LMConfig) -> bool:
    """True iff every stage kind of ``cfg`` implements the incremental
    prefill contract (attention families and the GSPN mixer).  SSM/xLSTM
    mixers and encoder-decoder models fall back to one-shot prefill."""
    if cfg.encoder_layers:
        return False
    kinds = {kind for _, kind, _ in cfg.stages()}
    if cfg.shared_attn:
        kinds.add("attn")
    if any(KINDS[k].apply_prefill_chunk is None for k in kinds):
        return False
    if "gspn" in kinds and cfg.gspn_row_width <= 0:
        return False           # fold geometry must not depend on length
    return True


def prefill_chunk_alignment(cfg: LMConfig) -> int:
    """Chunk boundaries must start at GSPN grid-row boundaries, so chunk
    sizes are rounded to a multiple of the fold width when a gspn stage is
    present (gspn_seq_prefill_chunk contract); 1 otherwise."""
    if any(kind == "gspn" for _, kind, _ in cfg.stages()):
        return max(1, cfg.gspn_row_width)
    return 1


def lm_prefill_chunk(params, cfg: LMConfig, tokens, caches, off, *,
                     ctx: Ctx = None, with_logits: bool = True):
    """Consume prompt tokens (B, T) starting at absolute offset ``off``
    (scalar int32, traced — one compile per chunk LENGTH, not per offset)
    against ``caches`` shaped like :func:`init_lm_cache` output.  Returns
    (logits (B, T, V), new_caches).  Chaining chunks and then decoding is
    numerically equivalent to :func:`lm_prefill` over the whole prompt
    (pinned at 1e-5 by tests/test_serve_engine.py).

    ``with_logits=False`` (static) returns (None, new_caches), skipping
    the final norm + vocab-head matmul — only the LAST chunk's logits
    feed sampling, so intermediate chunks in the serve hot path need not
    pay an O(T·V) head projection each."""
    ctx = ctx or Ctx()
    pol = cfg.policy
    off = jnp.asarray(off, jnp.int32)
    x = ctx.anchor(params["embed"].astype(pol.compute_dtype)[tokens])
    new_caches = {}
    stages = cfg.stages()

    for si, (where, kind, n) in enumerate(stages):
        if where != "prelude":
            continue
        kf = KINDS[kind]

        def body(h, inp, kf=kf):
            lp, cache = inp
            h, new = kf.apply_prefill_chunk(lp, ctx.anchor(h), cfg, ctx,
                                            cache, off)
            return ctx.anchor(h), new

        x, new = jax.lax.scan(body, x,
                              (params["stages"][f"s{si}_{kind}"],
                               caches[f"s{si}_{kind}"]))
        new_caches[f"s{si}_{kind}"] = new

    unit_stages = [(si, kind) for si, (w, kind, n) in enumerate(stages)
                   if w == "unit"]
    if unit_stages:
        def unit_body(h, inp):
            unit_params, unit_caches = inp
            new_unit = {}
            for si, kind in unit_stages:
                kf = KINDS[kind]

                def body(hh, pc, kf=kf):
                    lp, cache = pc
                    hh, new = kf.apply_prefill_chunk(lp, ctx.anchor(hh), cfg,
                                                     ctx, cache, off)
                    return ctx.anchor(hh), new

                h, new = jax.lax.scan(
                    body, h, (unit_params[f"s{si}_{kind}"],
                              unit_caches[f"s{si}_{kind}"]))
                new_unit[f"s{si}_{kind}"] = new
            if cfg.shared_attn:
                h, new_sh = KINDS["attn"].apply_prefill_chunk(
                    params["shared_attn"], h, cfg, ctx,
                    unit_caches["shared_attn"], off)
                new_unit["shared_attn"] = new_sh
            return h, new_unit

        unit_params = {f"s{si}_{kind}": params["stages"][f"s{si}_{kind}"]
                       for si, kind in unit_stages}
        unit_caches = {k: caches[k] for k in
                       [f"s{si}_{kind}" for si, kind in unit_stages]}
        if cfg.shared_attn:
            unit_caches["shared_attn"] = caches["shared_attn"]
        x, new_unit = jax.lax.scan(unit_body, x, (unit_params, unit_caches))
        new_caches.update(new_unit)

    if not with_logits:
        return None, new_caches
    x = _norm_apply(cfg, params["ln_f"], ctx.anchor(x))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(pol.compute_dtype)
    logits = x.astype(pol.compute_dtype) @ head
    return logits, new_caches


# ---------------------------------------------------------------------------
# Decode (one token) with stacked caches mirroring the stage structure.
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: LMConfig, batch: int, max_len: int):
    caches = {}
    for si, (where, kind, n) in enumerate(cfg.stages()):
        kf = KINDS[kind]
        one = lambda: kf.cache_init(batch, max_len, cfg)
        if where == "prelude":
            caches[f"s{si}_{kind}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *([one()] * n)) if n > 1 else \
                jax.tree.map(lambda a: a[None], one())
        else:
            base = one()
            caches[f"s{si}_{kind}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (cfg.n_units, n) + a.shape).copy(), base)
    if cfg.shared_attn:
        caches["shared_attn"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.n_units,) + a.shape).copy(),
            KINDS["attn"].cache_init(batch, max_len, cfg))
    return caches


def lm_decode_step(params, cfg: LMConfig, token, caches, *, ctx: Ctx = None,
                   enc_kv=None):
    """token: (B, 1) int32.  Returns (logits (B,1,V), new_caches)."""
    ctx = ctx or Ctx()
    pol = cfg.policy
    x = ctx.anchor(params["embed"].astype(pol.compute_dtype)[token])
    new_caches = {}
    stages = cfg.stages()

    for si, (where, kind, n) in enumerate(stages):
        if where != "prelude":
            continue
        kf = KINDS[kind]

        def body(h, inp):
            lp, cache = inp
            h, new = kf.apply_decode(lp, h, cfg, ctx, cache, enc_kv=enc_kv)
            return h, new

        x, new = jax.lax.scan(body, x,
                              (params["stages"][f"s{si}_{kind}"],
                               caches[f"s{si}_{kind}"]))
        new_caches[f"s{si}_{kind}"] = new

    unit_stages = [(si, kind) for si, (w, kind, n) in enumerate(stages)
                   if w == "unit"]
    if unit_stages:
        def unit_body(h, inp):
            unit_params, unit_caches = inp
            new_unit = {}
            for si, kind in unit_stages:
                kf = KINDS[kind]

                def body(hh, pc, kf=kf):
                    lp, cache = pc
                    hh, new = kf.apply_decode(lp, hh, cfg, ctx, cache,
                                              enc_kv=enc_kv)
                    return hh, new

                h, new = jax.lax.scan(
                    body, h, (unit_params[f"s{si}_{kind}"],
                              unit_caches[f"s{si}_{kind}"]))
                new_unit[f"s{si}_{kind}"] = new
            if cfg.shared_attn:
                h, new_sh = KINDS["attn"].apply_decode(
                    params["shared_attn"], h, cfg, ctx,
                    unit_caches["shared_attn"])
                new_unit["shared_attn"] = new_sh
            return h, new_unit

        unit_params = {f"s{si}_{kind}": params["stages"][f"s{si}_{kind}"]
                       for si, kind in unit_stages}
        unit_caches = {k: caches[k] for k in
                       [f"s{si}_{kind}" for si, kind in unit_stages]}
        if cfg.shared_attn:
            unit_caches["shared_attn"] = caches["shared_attn"]
        x, new_unit = jax.lax.scan(unit_body, x, (unit_params, unit_caches))
        new_caches.update(new_unit)

    x = _norm_apply(cfg, params["ln_f"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(pol.compute_dtype)
    logits = x.astype(pol.compute_dtype) @ head
    return logits, new_caches


# ---------------------------------------------------------------------------
# Parameter counting.
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(a.size) for a in jax.tree.leaves(params))


def count_active_params(cfg: LMConfig) -> int:
    """6·N·D convention: N = active params (MoE: top-k experts only)."""
    total = 0
    d = cfg.d_model
    hd = cfg.hd
    attn_p = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    ffn_p = (3 if cfg.mlp == "swiglu" else 2) * d * cfg.d_ff

    for where, kind, n in cfg.stages():
        reps = n if where == "prelude" else n * cfg.n_units
        if kind == "attn":
            total += reps * (attn_p + ffn_p)
        elif kind == "attn_moe":
            mcfg = _moe_cfg(cfg)
            total += reps * (attn_p + moe_mod.moe_active_param_count(mcfg))
        elif kind == "xattn":
            total += reps * (2 * attn_p + ffn_p)
        elif kind == "mamba":
            mc = _mamba_cfg(cfg)
            total += reps * (d * (2 * mc.d_inner + 2 * mc.d_state
                                  + mc.n_heads) + mc.d_inner * d)
        elif kind == "mlstm":
            mc = _mlstm_cfg(cfg)
            total += reps * (d * (4 * mc.d_inner + 2 * mc.n_heads)
                             + mc.d_inner * d)
        elif kind == "slstm":
            sc = _slstm_cfg(cfg)
            total += reps * (4 * d * d + 4 * d * sc.head_dim + d * d)
        elif kind == "gspn":
            total += reps * (gspn_seq_param_count(cfg) + ffn_p)
    if cfg.shared_attn:
        total += attn_p + ffn_p          # one weight set
    total += cfg.vocab * d               # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * d
    return total


def gspn_seq_param_count(cfg: LMConfig) -> int:
    cp = cfg.gspn_proxy_dim
    d = cfg.d_model
    return d * cp + d * 3 + d + d * 2 * cp * 2 + cp * d
