"""State-space / gated-linear-attention token mixers.

:func:`chunked_gla` is the shared computational core — a chunk-parallel
evaluation of the gated linear recurrence

    S_t = a_t * S_{t-1} + k_t v_t^T          (per head; a_t scalar decay)
    y_t = q_t^T S_t

used by both Mamba2 (SSD: a_t = exp(A·dt_t)) and mLSTM (a_t = sigmoid
forget gate).  The chunked form computes intra-chunk contributions with a
masked (L×L) decay matrix and carries inter-chunk state with a short
``lax.scan`` — O(S·L) memory instead of O(S²), sequential depth S/L.

Decode-mode helpers advance the recurrent state one token at a time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (DTypePolicy, DEFAULT_POLICY, dense_init,
                                 init_rmsnorm, apply_rmsnorm,
                                 init_causal_conv1d, apply_causal_conv1d)


def chunked_gla(q, k, v, log_decay, chunk: int = 256):
    """Gated linear attention, chunk-parallel.

    q, k: (B, S, H, Dk); v: (B, S, H, Dv); log_decay: (B, S, H) (≤ 0).
    Returns y: (B, S, H, Dv) f32 and final state (B, H, Dk, Dv).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    l = min(chunk, s)
    while s % l != 0:
        l //= 2
    nc = s // l

    qf = q.astype(jnp.float32).reshape(b, nc, l, h, dk)
    kf = k.astype(jnp.float32).reshape(b, nc, l, h, dk)
    vf = v.astype(jnp.float32).reshape(b, nc, l, h, dv)
    ld = log_decay.astype(jnp.float32).reshape(b, nc, l, h)
    cum = jnp.cumsum(ld, axis=2)                      # inclusive within chunk

    # Intra-chunk: att[i,j] = (q_i·k_j) exp(cum_i - cum_j), j <= i.
    att = jnp.einsum("bnihd,bnjhd->bnhij", qf, kf)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,l_i,l_j,h)
    dec = jnp.moveaxis(dec, -1, 2)                        # (b,nc,h,l_i,l_j)
    mask = jnp.tril(jnp.ones((l, l), bool))
    att = jnp.where(mask, att * jnp.exp(jnp.where(mask, dec, 0.0)), 0.0)
    y_intra = jnp.einsum("bnhij,bnjhd->bnihd", att, vf)

    # Inter-chunk state scan: k_sc[j] = k_j * exp(cum_L - cum_j).
    k_sc = kf * jnp.exp(cum[:, :, -1:, :] - cum)[..., None]
    chunk_kv = jnp.einsum("bnjhd,bnjhe->bnhde", k_sc, vf)   # (b,nc,h,dk,dv)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (b,nc,h)

    def scan_body(state, inp):
        kv_c, dec_c = inp                                   # (b,h,dk,dv),(b,h)
        new = state * dec_c[..., None, None] + kv_c
        return new, state                                   # emit state BEFORE chunk

    s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    final_state, states_before = jax.lax.scan(
        scan_body, s0,
        (jnp.moveaxis(chunk_kv, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states_before = jnp.moveaxis(states_before, 0, 1)       # (b,nc,h,dk,dv)

    q_sc = qf * jnp.exp(cum)[..., None]                     # q_i exp(cum_i)
    y_inter = jnp.einsum("bnihd,bnhde->bnihe", q_sc, states_before)

    y = (y_intra + y_inter).reshape(b, s, h, dv)
    return y, final_state


def gla_decode_step(state, q, k, v, log_decay):
    """One-token GLA update.  state (B,H,Dk,Dv); q/k/v (B,H,D*);
    log_decay (B,H).  Returns (y (B,H,Dv), new_state)."""
    a = jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    new_state = state * a + jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block (SSD).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    dim: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_k: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.dim

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.float32):
    di, ns, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    ks = jax.random.split(key, 5)
    conv_dim = di + 2 * ns
    return {
        "in_proj": dense_init(ks[0], cfg.dim,
                              2 * di + 2 * ns + nh, dtype),
        "conv": init_causal_conv1d(ks[1], conv_dim, cfg.conv_k, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": dense_init(ks[2], di, cfg.dim, dtype),
    }


def _mamba2_inner(params, x, cfg: Mamba2Config, policy, conv_state=None,
                  ssm_state=None):
    """Shared forward. If states given -> streaming (decode) mode."""
    b, s, _ = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    p = policy.cast(params)
    proj = (x.astype(policy.compute_dtype) @ p["in_proj"]).astype(jnp.float32)
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * ns], axis=-1)
    xbc_raw = xbc
    xbc, new_conv = apply_causal_conv1d(params["conv"], xbc, conv_state)
    if conv_state is None and cfg.conv_k > 1:
        # prefill: conv tail = last k-1 raw inputs (zero-padded on the left)
        pad = max(cfg.conv_k - 1 - s, 0)
        tail = jnp.pad(xbc_raw, ((0, 0), (pad, 0), (0, 0)))
        new_conv = tail[:, -(cfg.conv_k - 1):]
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    x_ssm, bmat, cmat = jnp.split(xbc, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt_raw + params["dt_bias"])        # (B,S,H)
    log_a = -jnp.exp(params["a_log"])                        # (H,) < 0
    log_decay = log_a * dt                                   # (B,S,H)

    xh = x_ssm.reshape(b, s, nh, hd)
    v = xh * dt[..., None]                                   # fold dt into v
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, nh, ns))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, nh, ns))

    if ssm_state is None:
        y, final_state = chunked_gla(k=k, q=q, v=v, log_decay=log_decay,
                                     chunk=cfg.chunk)
    else:
        y, final_state = gla_decode_step(
            ssm_state, q[:, 0], k[:, 0], v[:, 0], log_decay[:, 0])
        y = y[:, None]

    y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = apply_rmsnorm(params["norm"], y) * jax.nn.silu(z)
    out = (y.astype(policy.compute_dtype) @ p["out_proj"]).astype(x.dtype)
    return out, new_conv, final_state


def apply_mamba2(params, x, cfg: Mamba2Config,
                 policy: DTypePolicy = DEFAULT_POLICY):
    out, _, _ = _mamba2_inner(params, x, cfg, policy)
    return out


def apply_mamba2_prefill(params, x, cfg: Mamba2Config,
                         policy: DTypePolicy = DEFAULT_POLICY):
    """Forward over the prompt, returning the streaming cache."""
    out, new_conv, final_state = _mamba2_inner(params, x, cfg, policy)
    cache = {"conv": new_conv.astype(jnp.float32), "ssm": final_state}
    return out, cache


def apply_mamba2_decode(params, x, cfg: Mamba2Config, cache,
                        policy: DTypePolicy = DEFAULT_POLICY):
    """x (B,1,D); cache {'conv': (B,k-1,conv_dim), 'ssm': (B,H,Dk,Dv)}."""
    out, new_conv, new_ssm = _mamba2_inner(
        params, x, cfg, policy, conv_state=cache["conv"],
        ssm_state=cache["ssm"])
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "ssm": new_ssm}


def init_mamba2_cache(batch, cfg: Mamba2Config, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_k - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
    }
