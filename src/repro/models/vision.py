"""GSPN-2 vision backbone (the paper's own architecture, §5.2).

Hierarchical 4-stage design: conv stem → [GSPN2 block × depth_i] with
2× downsampling between stages → pooled classifier head.  Each block is
LPU (depthwise 3×3, per CMT) → GSPN-2 attention (channel-shared taps +
compressive proxy, paper §4.2) → FFN, all pre-norm with residuals —
mirroring the paper's ImageNet configuration (C_proxy = 2, LPU at block
and FFN entry).

The attention module's four directional scans run through the fused
opposite-pair dispatch (two kernel launches per block instead of four —
DESIGN.md §2); ``GSPNVisionConfig.impl`` selects the kernel path
(``auto``/``pallas``/``multidir``/``xla``, see ``repro.kernels.ops``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import gspn as gspn_core
from repro.models.layers import (DTypePolicy, dense_init,
                                 init_layernorm, apply_layernorm,
                                 init_gelu_mlp, apply_gelu_mlp,
                                 init_dwconv2d, apply_dwconv2d)


@dataclasses.dataclass(frozen=True)
class GSPNVisionConfig:
    name: str = "gspn2-t"
    img_size: int = 224
    in_chans: int = 3
    n_classes: int = 1000
    dims: Sequence[int] = (64, 128, 320, 512)
    depths: Sequence[int] = (3, 4, 12, 5)
    proxy_dim: int = 2                 # paper ImageNet setting
    mlp_ratio: float = 4.0
    channel_shared: bool = True        # GSPN-2 compact channel propagation
    chunk: int | None = None           # GSPN-local
    impl: str = "auto"                 # "sp" shards each scan over seq_axis
    seq_axis: str = "seq"            # mesh axis for impl="sp" (DESIGN.md §8)
    sp_strategy: str = "auto"
    param_dtype: jnp.dtype = jnp.float32

    @property
    def policy(self):
        return DTypePolicy(self.param_dtype, jnp.float32)


def _conv_init(key, k, cin, cout, dtype):
    scale = 1.0 / math.sqrt(k * k * cin)
    w = jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout),
                                    jnp.float32) * scale
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def _conv_apply(p, x, stride):
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return (y + p["b"].astype(jnp.float32)).astype(x.dtype)


def _gspn_attn_cfg(cfg: GSPNVisionConfig, dim: int):
    return gspn_core.GSPNAttentionConfig(
        dim=dim, proxy_dim=cfg.proxy_dim,
        channel_shared=cfg.channel_shared, chunk=cfg.chunk, impl=cfg.impl,
        seq_axis=cfg.seq_axis, sp_strategy=cfg.sp_strategy,
        param_dtype=cfg.param_dtype)


def _init_block(key, cfg: GSPNVisionConfig, dim: int):
    ks = jax.random.split(key, 4)
    hidden = int(dim * cfg.mlp_ratio)
    return {
        "lpu": init_dwconv2d(ks[0], dim, 3, cfg.param_dtype),
        "ln1": init_layernorm(dim, cfg.param_dtype),
        "gspn": gspn_core.init_gspn_attention(ks[1], _gspn_attn_cfg(cfg, dim)),
        "lpu2": init_dwconv2d(ks[2], dim, 3, cfg.param_dtype),
        "ln2": init_layernorm(dim, cfg.param_dtype),
        "mlp": init_gelu_mlp(ks[3], dim, hidden, cfg.param_dtype),
    }


def _anchor(x, ctx):
    """Activation constraint: batch over dp AND channels over the model
    axis.  A dp-only anchor killed the 10.7 GB/step of reshard all-gathers
    but forfeited channel TP (measured 12× redundant compute on
    img_train_224); anchoring both dims keeps the partitioner in the
    batch×channel hybrid layout that matches the FFN weight sharding."""
    if ctx is None or ctx.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import sanitize_spec
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec = (dp,) + (None,) * (x.ndim - 2) + (ctx.model_axis,)
    spec = sanitize_spec(P(*spec), x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def _apply_block(p, x, cfg: GSPNVisionConfig, dim: int, ctx=None):
    x = _anchor(x, ctx)
    x = x + apply_dwconv2d(p["lpu"], x)                       # LPU
    h = apply_layernorm(p["ln1"], x)
    # impl="sp" shards every directional scan over the mesh's seq axis
    # (one boundary-column exchange per scan, DESIGN.md §8) — the path
    # that lets high-resolution grids exceed one device's memory.
    x = x + gspn_core.apply_gspn_attention(
        p["gspn"], h, _gspn_attn_cfg(cfg, dim),
        mesh=ctx.mesh if ctx is not None else None)
    x = _anchor(x, ctx)
    x = x + apply_dwconv2d(p["lpu2"], x)                      # LPU before FFN
    h = apply_layernorm(p["ln2"], x)
    b, hh, ww, c = h.shape
    y = apply_gelu_mlp(p["mlp"], h.reshape(b, hh * ww, c), cfg.policy)
    return _anchor(x + y.reshape(b, hh, ww, c), ctx)


def init_vision(key, cfg: GSPNVisionConfig):
    params = {}
    k_stem, k_stages, k_head = jax.random.split(key, 3)
    params["stem"] = _conv_init(k_stem, 4, cfg.in_chans, cfg.dims[0],
                                cfg.param_dtype)
    stages = []
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        ks = jax.random.split(jax.random.fold_in(k_stages, si), depth)
        blocks = jax.vmap(lambda k: _init_block(k, cfg, dim))(ks)
        stage = {"blocks": blocks}
        if si + 1 < len(cfg.dims):
            stage["down"] = _conv_init(jax.random.fold_in(k_stages, 100 + si),
                                       2, dim, cfg.dims[si + 1],
                                       cfg.param_dtype)
        stages.append(stage)
    params["stages"] = stages
    params["ln_f"] = init_layernorm(cfg.dims[-1], cfg.param_dtype)
    params["head"] = dense_init(k_head, cfg.dims[-1], cfg.n_classes,
                                cfg.param_dtype)
    return params


def apply_vision(params, x, cfg: GSPNVisionConfig, ctx=None):
    """x: (B, H, W, 3) -> logits (B, n_classes)."""
    x = _anchor(_conv_apply(params["stem"], x, 4), ctx)
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        stage = params["stages"][si]

        def body(h, block_params, dim=dim):
            return _apply_block(block_params, h, cfg, dim, ctx=ctx), None

        x, _ = jax.lax.scan(body, x, stage["blocks"])
        if "down" in stage:
            x = _anchor(_conv_apply(stage["down"], x, 2), ctx)
    x = apply_layernorm(params["ln_f"], x)
    x = jnp.mean(x, axis=(1, 2))
    return (x.astype(jnp.float32)
            @ params["head"].astype(jnp.float32))


def vision_loss(params, cfg: GSPNVisionConfig, batch, ctx=None):
    logits = apply_vision(params, batch["images"], cfg, ctx=ctx)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    return nll, {"ce": nll}


def vision_macs(cfg: GSPNVisionConfig) -> int:
    """Approximate multiply-accumulates for one image (Table 2 analogue)."""
    h = w = cfg.img_size // 4
    macs = (cfg.img_size // 4) ** 2 * 16 * cfg.in_chans * cfg.dims[0]
    for si, (dim, depth) in enumerate(zip(cfg.dims, cfg.depths)):
        n = h * w
        acfg = _gspn_attn_cfg(cfg, dim)
        nd = len(acfg.directions)
        cp = acfg.proxy_dim
        per_block = (
            n * dim * 9 * 2                               # two LPUs
            + n * gspn_core.gspn_attention_param_count(acfg)  # projections
            + nd * n * cp * 4                             # scan FMAs
            + 2 * n * dim * int(dim * cfg.mlp_ratio))     # MLP
        macs += depth * per_block
        if si + 1 < len(cfg.dims):
            macs += (h // 2) * (w // 2) * 4 * dim * cfg.dims[si + 1]
            h, w = h // 2, w // 2
    macs += cfg.dims[-1] * cfg.n_classes
    return macs
