"""Train-step builder: loss → grads → (optional compression) → AdamW,
with sharding-aware jit compilation.

``make_train_setup`` is the single entry point used by the launcher, the
trainer and the dry-run: it derives parameter/optimizer/batch shardings
from the rules in :mod:`repro.parallel.sharding`, builds the jitted step
with donated state, and returns everything needed to run or AOT-compile.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding as shd
from repro.parallel.collectives import quantize_int8, dequantize_int8


def build_train_step(model_cfg, opt_cfg: AdamWConfig, *, mesh=None,
                     dp_axes=("data",), grad_compression: str = "none",
                     grad_accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).  Pure.

    ``grad_accum`` > 1 splits the per-host batch into K microbatches and
    accumulates f32 gradients over a scan — the standard lever for fitting
    large activation footprints into HBM (per-layer residual stacks shrink
    by K while arithmetic intensity stays unchanged).
    """
    ctx = lm_mod.Ctx(mesh=mesh, dp_axes=dp_axes)

    def loss_fn(params, batch):
        return lm_mod.lm_loss(params, model_cfg, batch, ctx)

    def grads_of(params, batch):
        if grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        k = grad_accum

        def fold(a):
            return a.reshape((k, a.shape[0] // k) + a.shape[1:])

        micro = jax.tree.map(fold, batch)

        def acc_dtype(p):
            # bf16-param models accumulate in bf16: f32 accumulators would
            # double the parameter-gradient memory (measured +15.8 GB/dev
            # on kimi train_4k) and push the FSDP reductions to f32
            # payloads; f32-param models keep f32 accumulation.
            return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

        def body(acc, mb):
            g_acc, loss_acc, aux_acc = acc
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (g_acc, loss_acc + loss, aux_acc + metrics["aux"]), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype(p)), params)
        (g_acc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g, p: (g / k).astype(p.dtype),
                             g_acc, params)
        loss = loss_sum / k
        return (loss, {"ce": loss - aux_sum / k, "aux": aux_sum / k}), grads

    def train_step(state, batch):
        (loss, metrics), grads = grads_of(state["params"], batch)

        if grad_compression == "int8_ef":
            # Error-feedback int8 quantisation of the (already reduced)
            # gradients; the residual persists in state["errors"].  On a
            # multi-pod mesh XLA performs the cross-pod reduction in int8
            # when the quantised tree feeds the optimizer (payload cast
            # happens before the DCN hop in the scheduled HLO).
            def comp(g, e):
                q, s = quantize_int8(g.astype(jnp.float32) + e)
                gh = dequantize_int8(q, s)
                return gh.astype(g.dtype), (g.astype(jnp.float32) + e) - gh

            pairs = jax.tree.map(comp, grads, state["errors"])
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_errors = jax.tree.map(lambda p: p[1], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        else:
            new_errors = state.get("errors")

        new_params, new_opt, stats = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt}
        if new_errors is not None:
            new_state["errors"] = new_errors
        out_metrics = {"loss": loss, **metrics, **stats}
        return new_state, out_metrics

    return train_step


@dataclasses.dataclass
class TrainSetup:
    state_shardings: Any
    batch_shardings: Any
    jit_step: Any
    init_state: Any            # callable(key) -> state (sharded)
    abstract_state: Any
    mesh: Any


def make_train_setup(model_cfg, opt_cfg: AdamWConfig, batch_example, *,
                     mesh, dp_axes=("data",), grad_compression="none",
                     donate=True) -> TrainSetup:
    """Derive shardings, build the jitted step, and an init function."""
    def init_fn(key):
        params = lm_mod.init_lm(key, model_cfg)
        state = {"params": params, "opt": adamw_init(opt_cfg, params)}
        if grad_compression == "int8_ef":
            state["errors"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state

    abstract = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(abstract["params"], mesh)
    state_shardings = {"params": pshard,
                       "opt": {"m": pshard, "v": pshard,
                               "step": NamedSharding(mesh, P())}}
    if "errors" in abstract:
        state_shardings["errors"] = pshard
    bshard = shd.batch_shardings(batch_example, mesh, dp_axes)

    step = build_train_step(model_cfg, opt_cfg, mesh=mesh, dp_axes=dp_axes,
                            grad_compression=grad_compression)
    jit_step = jax.jit(
        step,
        in_shardings=(state_shardings, bshard),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else ())

    init_sharded = jax.jit(init_fn, out_shardings=state_shardings)
    return TrainSetup(state_shardings=state_shardings,
                      batch_shardings=bshard, jit_step=jit_step,
                      init_state=init_sharded, abstract_state=abstract,
                      mesh=mesh)
