"""Train-step builder: loss → grads → (optional compression) → AdamW,
with sharding-aware jit compilation.

``make_train_setup`` is the single entry point used by the launcher, the
trainer and the dry-run: it derives parameter/optimizer/batch shardings
from the rules in :mod:`repro.parallel.sharding`, builds the jitted step
with donated state, and returns everything needed to run or AOT-compile.

Mixed-precision training (DESIGN.md §10): with ``master_weights=True``
the working parameters stay in the model's ``param_dtype`` (bf16 under
the policy) while an f32 master copy lives in ``state["master"]`` — the
optimizer updates the master and the bf16 working copy is re-cast from
it each step, so repeated tiny updates never round to zero in bf16.
``loss_scaling`` adds the standard dynamic-loss-scale loop: the loss is
multiplied by a running scale before differentiation, gradients are
unscaled in f32, and a non-finite gradient anywhere skips the update and
backs the scale off; ``growth_interval`` consecutive good steps grow it
back.  bf16 shares f32's exponent range, so overflow is rarer than under
fp16 — the backoff loop is cheap insurance, not the common path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding as shd
from repro.parallel.collectives import quantize_int8, dequantize_int8


# ---------------------------------------------------------------------------
# Dynamic loss scaling (DESIGN.md §10).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LossScaleConfig:
    init_scale: float = 2.0 ** 15
    growth_interval: int = 200     # consecutive finite steps before growth
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    min_scale: float = 1.0
    max_scale: float = 2.0 ** 24


def loss_scale_init(cfg: LossScaleConfig):
    return {"scale": jnp.asarray(cfg.init_scale, jnp.float32),
            "good_steps": jnp.zeros((), jnp.int32)}


def loss_scale_update(cfg: LossScaleConfig, state, grads_finite):
    """Pure scale-state transition: backoff on overflow, growth after
    ``growth_interval`` consecutive finite steps."""
    grown = jnp.minimum(state["scale"] * cfg.growth_factor, cfg.max_scale)
    backed = jnp.maximum(state["scale"] * cfg.backoff_factor, cfg.min_scale)
    hit = state["good_steps"] + 1 >= cfg.growth_interval
    new_scale = jnp.where(grads_finite,
                          jnp.where(hit, grown, state["scale"]), backed)
    new_good = jnp.where(grads_finite & jnp.logical_not(hit),
                         state["good_steps"] + 1, 0)
    return {"scale": new_scale, "good_steps": new_good}


def tree_all_finite(tree):
    leaves = [jnp.all(jnp.isfinite(a.astype(jnp.float32)))
              for a in jax.tree.leaves(tree)]
    if not leaves:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(leaves))


def build_train_step(model_cfg, opt_cfg: AdamWConfig, *, mesh=None,
                     dp_axes=("data",), grad_compression: str = "none",
                     grad_accum: int = 1, master_weights: bool = False,
                     loss_scaling: Optional[LossScaleConfig] = None):
    """Returns train_step(state, batch) -> (state, metrics).  Pure.

    ``grad_accum`` > 1 splits the per-host batch into K microbatches and
    accumulates f32 gradients over a scan — the standard lever for fitting
    large activation footprints into HBM (per-layer residual stacks shrink
    by K while arithmetic intensity stays unchanged).

    ``master_weights`` keeps an f32 master copy in ``state["master"]``
    and treats ``state["params"]`` as the low-precision working copy;
    ``loss_scaling`` enables the dynamic loss-scale loop (both DESIGN.md
    §10; state carries ``loss_scale`` = {scale, good_steps}).
    """
    ctx = lm_mod.Ctx(mesh=mesh, dp_axes=dp_axes)

    def loss_fn(params, batch, scale=None):
        (loss, metrics) = lm_mod.lm_loss(params, model_cfg, batch, ctx)
        if scale is None:
            return loss, metrics
        # Differentiate the SCALED loss; report the unscaled one.  The
        # scale rides through the chain rule, so grads come out
        # scale-times too large and are unscaled in f32 below.
        return loss * scale, {**metrics, "unscaled_loss": loss}

    def grads_of(params, batch, scale=None):
        if grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, scale)
        k = grad_accum

        def fold(a):
            return a.reshape((k, a.shape[0] // k) + a.shape[1:])

        micro = jax.tree.map(fold, batch)

        def acc_dtype(p):
            # bf16-param models accumulate in bf16: f32 accumulators would
            # double the parameter-gradient memory (measured +15.8 GB/dev
            # on kimi train_4k) and push the FSDP reductions to f32
            # payloads; f32-param models keep f32 accumulation.
            return p.dtype if p.dtype == jnp.bfloat16 else jnp.float32

        def body(acc, mb):
            g_acc, loss_acc, aux_acc = acc
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, scale)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            # accumulate the UNSCALED loss (metrics carry it either way)
            raw = metrics["ce"] + metrics["aux"]
            return (g_acc, loss_acc + raw, aux_acc + metrics["aux"]), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype(p)), params)
        (g_acc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros(()), jnp.zeros(())), micro)
        # The master path must not round the accumulated grads back to
        # the bf16 param dtype — the f32 master exists to receive the
        # bits that cast would destroy.  (Accumulation itself may still
        # run in bf16 per acc_dtype's memory note; the mean is taken at
        # full width either way.)
        out_dtype = ((lambda p: jnp.float32) if master_weights
                     else (lambda p: p.dtype))
        grads = jax.tree.map(
            lambda g, p: (g.astype(jnp.float32) / k).astype(out_dtype(p)),
            g_acc, params)
        loss = loss_sum / k
        out = {"ce": loss - aux_sum / k, "aux": aux_sum / k}
        if scale is not None:
            out["unscaled_loss"] = loss
            loss = loss * scale
        return (loss, out), grads

    def train_step(state, batch):
        scale = state["loss_scale"]["scale"] if loss_scaling else None
        (loss, metrics), grads = grads_of(state["params"], batch, scale)

        grads_finite = None
        if loss_scaling is not None:
            loss = metrics.pop("unscaled_loss")
            # Overflow check on the RAW (still-scaled) grads, then unscale
            # in f32.  The master path keeps f32 grads all the way into
            # the optimizer — re-rounding to bf16 here would throw away
            # the very bits the master copy exists to keep.
            grads_finite = tree_all_finite(grads)
            inv = 1.0 / scale
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * inv).astype(
                    jnp.float32 if master_weights else g.dtype), grads)
        elif master_weights:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if grad_compression == "int8_ef":
            # Error-feedback int8 quantisation of the (already reduced)
            # gradients; the residual persists in state["errors"].  On a
            # multi-pod mesh XLA performs the cross-pod reduction in int8
            # when the quantised tree feeds the optimizer (payload cast
            # happens before the DCN hop in the scheduled HLO).
            def comp(g, e):
                q, s = quantize_int8(g.astype(jnp.float32) + e)
                gh = dequantize_int8(q, s)
                return gh.astype(g.dtype), (g.astype(jnp.float32) + e) - gh

            pairs = jax.tree.map(comp, grads, state["errors"])
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_errors = jax.tree.map(lambda p: p[1], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        else:
            new_errors = state.get("errors")

        # The optimizer walks the f32 master when one exists; the working
        # (low-precision) params are re-cast from it afterwards.
        opt_params = state["master"] if master_weights else state["params"]
        new_opt_params, new_opt, stats = adamw_update(
            opt_cfg, grads, state["opt"], opt_params)
        if master_weights:
            new_master = new_opt_params
            new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                                      new_master, state["params"])
        else:
            new_master = None
            new_params = new_opt_params

        if loss_scaling is not None:
            # Non-finite grads anywhere: keep params/master/opt untouched
            # (the step is skipped, not poisoned) and back the scale off.
            def keep(new, old):
                return jax.tree.map(
                    lambda a, b: jnp.where(grads_finite, a, b), new, old)

            new_params = keep(new_params, state["params"])
            new_opt = keep(new_opt, state["opt"])
            if master_weights:
                new_master = keep(new_master, state["master"])
            new_ls = loss_scale_update(loss_scaling, state["loss_scale"],
                                       grads_finite)

        new_state = {"params": new_params, "opt": new_opt}
        if new_errors is not None:
            new_state["errors"] = new_errors
        if master_weights:
            new_state["master"] = new_master
        if loss_scaling is not None:
            new_state["loss_scale"] = new_ls
            stats = {**stats,
                     "loss_scale": state["loss_scale"]["scale"],
                     "grads_finite": grads_finite.astype(jnp.float32)}
        out_metrics = {"loss": loss, **metrics, **stats}
        return new_state, out_metrics

    return train_step


@dataclasses.dataclass
class TrainSetup:
    state_shardings: Any
    batch_shardings: Any
    jit_step: Any
    init_state: Any            # callable(key) -> state (sharded)
    abstract_state: Any
    mesh: Any


def make_train_setup(model_cfg, opt_cfg: AdamWConfig, batch_example, *,
                     mesh, dp_axes=("data",), grad_compression="none",
                     donate=True, master_weights: bool = False,
                     loss_scaling: Optional[LossScaleConfig] = None
                     ) -> TrainSetup:
    """Derive shardings, build the jitted step, and an init function."""
    def init_fn(key):
        params = lm_mod.init_lm(key, model_cfg)
        state = {"params": params, "opt": adamw_init(opt_cfg, params)}
        if grad_compression == "int8_ef":
            state["errors"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if master_weights:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        if loss_scaling is not None:
            state["loss_scale"] = loss_scale_init(loss_scaling)
        return state

    abstract = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    pshard = shd.param_shardings(abstract["params"], mesh)
    state_shardings = {"params": pshard,
                       "opt": {"m": pshard, "v": pshard,
                               "step": NamedSharding(mesh, P())}}
    if "errors" in abstract:
        state_shardings["errors"] = pshard
    if "master" in abstract:
        state_shardings["master"] = pshard
    if "loss_scale" in abstract:
        state_shardings["loss_scale"] = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), abstract["loss_scale"])
    bshard = shd.batch_shardings(batch_example, mesh, dp_axes)

    step = build_train_step(model_cfg, opt_cfg, mesh=mesh, dp_axes=dp_axes,
                            grad_compression=grad_compression,
                            master_weights=master_weights,
                            loss_scaling=loss_scaling)
    jit_step = jax.jit(
        step,
        in_shardings=(state_shardings, bshard),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else ())

    init_sharded = jax.jit(init_fn, out_shardings=state_shardings)
    return TrainSetup(state_shardings=state_shardings,
                      batch_shardings=bshard, jit_step=jit_step,
                      init_state=init_sharded, abstract_state=abstract,
                      mesh=mesh)
