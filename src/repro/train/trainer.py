"""Fault-tolerant training loop.

Production behaviours implemented and tested (tests/test_trainer.py):

* **checkpoint/restart** — async atomic checkpoints every ``ckpt_every``
  steps; on any step failure the trainer restores the latest committed
  checkpoint and replays from there (the deterministic data pipeline
  regenerates the identical stream, so recovery is exactly-once).
* **straggler mitigation** — per-step wall time is tracked with an EWMA;
  steps slower than ``straggler_factor ×`` EWMA are counted and logged.
  On real multi-host deployments this signal feeds the elastic controller
  (slow host → evict + re-mesh); here it is surfaced in metrics.
* **elastic re-mesh** — ``ElasticTrainer.remesh`` rebuilds the jitted step
  for a new mesh and re-shards the state through the checkpoint manager's
  restore path (device_put with the new shardings).
* **failure injection** — ``failure_injector(step)`` hook raising mid-run
  exercises the recovery path in tests.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import compat, obs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, host_batch
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_setup, TrainSetup

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    straggler_warmup: int = 5
    max_retries: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, model_cfg, opt_cfg: AdamWConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, *, mesh, dp_axes=("data",),
                 grad_compression="none", master_weights=False,
                 loss_scaling=None,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        self.grad_compression = grad_compression
        self.master_weights = master_weights
        self.loss_scaling = loss_scaling
        self.failure_injector = failure_injector

        example = {k: jnp.asarray(v)
                   for k, v in host_batch(data_cfg, 0).items()}
        self.setup: TrainSetup = make_train_setup(
            model_cfg, opt_cfg, example, mesh=mesh, dp_axes=dp_axes,
            grad_compression=grad_compression,
            master_weights=master_weights, loss_scaling=loss_scaling)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                      host_id=data_cfg.host_id,
                                      n_hosts=data_cfg.n_hosts)
        self.state = None
        self.step = 0
        self.ewma = None
        self.stragglers = 0
        self.recoveries = 0
        self.history: list = []
        self._last_scale = None

    # -- state management ---------------------------------------------------
    def init_or_restore(self, seed: int = 0):
        latest = self.ckpt.latest_step()
        if latest is None:
            self.state = self.setup.init_state(jax.random.PRNGKey(seed))
            self.step = 0
        else:
            self._restore(latest)
        return self.step

    def _restore(self, ckpt_step=None):
        self.ckpt.wait()
        target = self.setup.abstract_state
        self.state, step = self.ckpt.restore(
            step=ckpt_step, target=target,
            shardings=self.setup.state_shardings)
        self.step = step
        log.warning("restored checkpoint at step %d", step)

    def _save(self, sync=False):
        self.ckpt.save(self.step, self.state)
        if sync:
            self.ckpt.wait()

    def _note_loss_scale(self, metrics):
        """Emit a loss-scale trace event on every scale change or
        non-finite-gradient step (DESIGN.md §13).  No-op for runs without
        dynamic loss scaling (step metrics lack the keys)."""
        if "loss_scale" not in metrics:
            return
        scale = float(metrics["loss_scale"])
        finite = float(metrics.get("grads_finite", 1.0))
        if scale != self._last_scale or finite < 1.0:
            obs.event("train.loss_scale", step=self.step, scale=scale,
                      grads_finite=finite)
            if finite < 1.0:
                obs.counter("train_nonfinite_steps_total").inc()
        self._last_scale = scale

    # -- main loop ------------------------------------------------------------
    def run(self, n_steps: int):
        if self.state is None:
            self.init_or_restore()
        end = self.step + n_steps
        retries = 0
        while self.step < end:
            with obs.trace("train.data", step=self.step):
                raw = host_batch(self.data_cfg, self.step)
                batch = {k: jax.device_put(jnp.asarray(v),
                                           self.setup.batch_shardings[k])
                         for k, v in raw.items()}
            t0 = obs.monotonic()
            try:
                if self.failure_injector is not None:
                    self.failure_injector(self.step)
                with obs.trace("train.step", step=self.step), \
                        compat.set_mesh(self.mesh):
                    new_state, metrics = self.setup.jit_step(self.state,
                                                             batch)
                    jax.block_until_ready(new_state)
            except Exception as exc:  # noqa: BLE001 — any step failure
                retries += 1
                self.recoveries += 1
                obs.counter("train_recoveries_total").inc()
                obs.event("train.recovery", step=self.step, retry=retries,
                          error=type(exc).__name__)
                log.warning("step %d failed (%s); recovering (retry %d)",
                            self.step, exc, retries)
                if retries > self.tcfg.max_retries:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    self._restore(latest)
                # else: continue with current state (failure was transient
                # and state was not consumed thanks to exception semantics)
                continue
            retries = 0
            self.state = new_state
            dt = obs.monotonic() - t0
            obs.counter("train_steps_total").inc()
            obs.histogram("train_step_seconds").observe(dt)
            self._note_loss_scale(metrics)

            if self.step > self.tcfg.straggler_warmup:
                if self.ewma is not None and dt > \
                        self.tcfg.straggler_factor * self.ewma:
                    self.stragglers += 1
                    obs.counter("train_stragglers_total").inc()
                    obs.event("train.straggler", step=self.step,
                              dt_ms=round(dt * 1e3, 3),
                              ewma_ms=round(self.ewma * 1e3, 3))
                    log.warning("straggler step %d: %.3fs vs ewma %.3fs",
                                self.step, dt, self.ewma)
                self.ewma = dt if self.ewma is None else \
                    0.9 * self.ewma + 0.1 * dt

            self.step += 1
            loss = float(metrics["loss"])
            self.history.append(loss)
            if self.step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", self.step, loss, dt)
            if self.step % self.tcfg.ckpt_every == 0:
                self._save()
        self._save(sync=True)
        return self.history


class ElasticTrainer(Trainer):
    """Trainer that can rebuild itself on a changed device set.

    ``device_monitor()`` returns the currently-healthy device list; when it
    shrinks/grows, ``maybe_remesh`` checkpoints synchronously, rebuilds the
    mesh/step for the new topology, and restores with the new shardings.
    """

    def __init__(self, *args, device_monitor=None, mesh_builder=None,
                 **kwargs):
        super().__init__(*args, **kwargs)
        self.device_monitor = device_monitor or (lambda: jax.devices())
        self.mesh_builder = mesh_builder
        self._n_devices = len(self.device_monitor())

    def maybe_remesh(self) -> bool:
        devices = self.device_monitor()
        if len(devices) == self._n_devices:
            return False
        log.warning("elastic: device count %d -> %d; re-meshing",
                    self._n_devices, len(devices))
        self._save(sync=True)
        self._n_devices = len(devices)
        new_mesh = self.mesh_builder(devices)
        self.mesh = new_mesh
        example = {k: jnp.asarray(v)
                   for k, v in host_batch(self.data_cfg, self.step).items()}
        self.setup = make_train_setup(
            self.model_cfg, self.opt_cfg, example, mesh=new_mesh,
            dp_axes=self.dp_axes, grad_compression=self.grad_compression,
            master_weights=self.master_weights,
            loss_scaling=self.loss_scaling)
        self._restore()
        return True

    def run(self, n_steps: int, remesh_every: int = 10):
        if self.state is None:
            self.init_or_restore()
        done = 0
        while done < n_steps:
            chunk = min(remesh_every, n_steps - done)
            super().run(chunk)
            done += chunk
            self.maybe_remesh()
        return self.history
