"""Typed metrics registry: counters, gauges, fixed-bucket histograms
(DESIGN.md §13).

Unlike tracing (off by default), metrics are ALWAYS on: a counter
increment is one lock acquire + one int add, cheap enough for every
scheduler tick.  The registry is a process-global name → metric map with
get-or-create semantics, exported two ways:

* :meth:`Registry.snapshot` — plain-JSON dict (the ``--metrics-out``
  artifact; pretty-printed by ``python -m repro.obs.report``);
* :meth:`Registry.prometheus` — Prometheus text exposition format
  (cumulative ``le`` buckets, ``_sum``/``_count`` series) so a real
  deployment can scrape the same registry.

Histogram semantics follow Prometheus: bucket ``i`` counts observations
``v <= edges[i]`` (upper bounds are INCLUSIVE — an exact-boundary value
lands in its edge's bucket), with one implicit overflow bucket
(``+Inf``) past the last edge.  The first bucket doubles as the
underflow bucket: every observation below ``edges[0]`` lands there.
:data:`LATENCY_BUCKETS` spans 100µs–10s logarithmically — sized for
TTFT/ITL distributions at both interpret-mode (ms) and compiled (µs–ms)
speeds.
"""

from __future__ import annotations

import bisect
import json
import math
import threading

# Log-spaced seconds, 1-2.5-5 per decade: TTFT/ITL-appropriate.
LATENCY_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

# Small-integer buckets (queue depths, batch occupancy).
DEPTH_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


class Counter:
    """Monotonically increasing count."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1):
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0.0

    def set(self, v: float):
        self._value = float(v)

    @property
    def value(self):
        return self._value


class Histogram:
    """Fixed-bucket histogram with inclusive upper-bound edges.

    ``counts[i]`` holds observations ``edges[i-1] < v <= edges[i]``
    (``counts[0]``: ``v <= edges[0]``, the underflow-inclusive bucket);
    ``counts[-1]`` is the ``+Inf`` overflow bucket.  Tracks sum, count,
    min and max alongside.
    """

    def __init__(self, name: str, buckets=LATENCY_BUCKETS, help: str = ""):
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram {name}: edges must be strictly "
                             f"increasing and non-empty, got {buckets}")
        self.name, self.help = name, help
        self.edges = edges
        self._lock = threading.Lock()
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float):
        v = float(v)
        # bisect_left: first edge >= v, so v == edge stays in edge's
        # bucket (inclusive upper bound); v > edges[-1] overflows.
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def quantile(self, q: float) -> float:
        """Approximate quantile: the upper edge of the bucket where the
        cumulative count crosses ``q`` (max observed for overflow)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(q)
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank and c:
                return self.edges[i] if i < len(self.edges) else self.max
        return self.max

    def to_dict(self) -> dict:
        return {"buckets": list(self.edges), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None}


class Registry:
    """Process-global name → metric map with get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get_or_create(self, cls, name, *args, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, buckets=LATENCY_BUCKETS,
                  help: str = "") -> Histogram:
        return self._get_or_create(Histogram, name, buckets, help)

    def get(self, name: str):
        return self._metrics.get(name)

    def reset(self):
        """Drop every metric (tests); accessors re-create lazily."""
        with self._lock:
            self._metrics.clear()

    # -- export -------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-serialisable snapshot of every registered metric."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.to_dict()
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format (cumulative le buckets)."""
        lines = []
        with self._lock:
            items = list(self._metrics.items())
        for name, m in sorted(items):
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(m)]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{name} {m.value}")
            else:
                acc = 0
                for edge, c in zip(m.edges, m.counts):
                    acc += c
                    lines.append(f'{name}_bucket{{le="{edge}"}} {acc}')
                acc += m.counts[-1]
                lines.append(f'{name}_bucket{{le="+Inf"}} {acc}')
                lines.append(f"{name}_sum {m.sum}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"


REGISTRY = Registry()


# Module-level conveniences against the global registry — the form the
# instrumented layers use (get-or-create each call, so a test-time
# ``REGISTRY.reset()`` can never leave a layer holding a dead metric).
def counter(name: str, help: str = "") -> Counter:
    return REGISTRY.counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return REGISTRY.gauge(name, help)


def histogram(name: str, buckets=LATENCY_BUCKETS, help: str = "") -> Histogram:
    return REGISTRY.histogram(name, buckets, help)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def prometheus() -> str:
    return REGISTRY.prometheus()


def save_snapshot(path) -> str:
    """Write the registry to ``path``: Prometheus text when the suffix is
    ``.prom``, JSON otherwise (the ``--metrics-out`` artifact)."""
    path = str(path)
    if path.endswith(".prom"):
        with open(path, "w") as f:
            f.write(prometheus())
    else:
        with open(path, "w") as f:
            json.dump(snapshot(), f, indent=1)
    return path
