"""Unified observability: structured tracing + metrics registry
(DESIGN.md §13).

One import surface for every instrumented layer::

    from repro import obs

    with obs.trace("serve.decode_step", batch=4) as sp:
        ...
        sp.set(plan="fwd:t128-d2")
    obs.counter("serve_decode_steps_total").inc()

Tracing is OFF by default (``obs.enable()`` / ``--trace-out`` turns it
on; disabled spans are shared no-op singletons).  Metrics are always on.
Export via :func:`save_chrome_trace` (Perfetto / chrome://tracing) and
:func:`save_metrics` (JSON or Prometheus text); pretty-print either with
``python -m repro.obs.report``.
"""

from repro.obs.metrics import (DEPTH_BUCKETS, LATENCY_BUCKETS,  # noqa: F401
                               REGISTRY, Counter, Gauge, Histogram,
                               Registry, counter, gauge, histogram,
                               prometheus, snapshot)
from repro.obs.metrics import save_snapshot as save_metrics  # noqa: F401
from repro.obs.tracing import (NOOP_SPAN, Span, async_begin,  # noqa: F401
                               async_end, chrome_trace, clear, disable,
                               enable, enabled, event, monotonic,
                               monotonic_ns, records, save_chrome_trace,
                               spans, trace)
