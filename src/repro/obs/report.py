"""Pretty-print observability artifacts (DESIGN.md §13).

    PYTHONPATH=src python -m repro.obs.report metrics.json
    PYTHONPATH=src python -m repro.obs.report trace.json

Auto-detects the artifact kind: a Chrome trace (``traceEvents`` key —
the ``--trace-out`` file) is summarised per span name (count, total,
mean, max); a metrics snapshot (``counters``/``gauges``/``histograms``
keys — the ``--metrics-out`` file) is printed as aligned tables with
p50/p90 estimates for histograms.
"""

from __future__ import annotations

import argparse
import json
import sys


def _quantile(edges, counts, total, q, vmax):
    """Bucket-walk quantile matching metrics.Histogram.quantile."""
    if not total:
        return 0.0
    rank = q * total
    acc = 0
    for i, c in enumerate(counts):
        acc += c
        if acc >= rank and c:
            return edges[i] if i < len(edges) else vmax
    return vmax


def summarize_trace(payload: dict, out=None):
    out = out if out is not None else sys.stdout
    evs = payload.get("traceEvents", [])
    by_name: dict[str, list] = {}
    n_async = n_instant = 0
    for e in evs:
        if e.get("ph") == "X":
            by_name.setdefault(e["name"], []).append(float(e.get("dur", 0.0)))
        elif e.get("ph") in ("b", "e"):
            n_async += 1
        elif e.get("ph") == "i":
            n_instant += 1
    print(f"trace: {len(evs)} events ({sum(map(len, by_name.values()))} "
          f"spans, {n_async} async, {n_instant} instant)", file=out)
    print(f"{'span':<32}{'count':>8}{'total_ms':>12}{'mean_us':>12}"
          f"{'max_us':>12}", file=out)
    for name, durs in sorted(by_name.items(),
                             key=lambda kv: -sum(kv[1])):
        total = sum(durs)
        print(f"{name:<32}{len(durs):>8}{total/1e3:>12.3f}"
              f"{total/len(durs):>12.1f}{max(durs):>12.1f}", file=out)


def summarize_metrics(payload: dict, out=None):
    out = out if out is not None else sys.stdout
    counters = payload.get("counters", {})
    gauges = payload.get("gauges", {})
    hists = payload.get("histograms", {})
    if counters:
        print("counters:", file=out)
        for name, v in sorted(counters.items()):
            print(f"  {name:<40}{v:>16}", file=out)
    if gauges:
        print("gauges:", file=out)
        for name, v in sorted(gauges.items()):
            print(f"  {name:<40}{v:>16g}", file=out)
    if hists:
        print("histograms:", file=out)
        for name, h in sorted(hists.items()):
            count = h.get("count", 0)
            mean = h["sum"] / count if count else 0.0
            vmax = h.get("max") or 0.0
            p50 = _quantile(h["buckets"], h["counts"], count, 0.5, vmax)
            p90 = _quantile(h["buckets"], h["counts"], count, 0.9, vmax)
            print(f"  {name:<40} count={count} mean={mean:.6g} "
                  f"p50<={p50:.6g} p90<={p90:.6g} max={vmax:.6g}",
                  file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs.report")
    ap.add_argument("path", help="a --trace-out or --metrics-out artifact")
    args = ap.parse_args(argv)
    try:
        with open(args.path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"[report] cannot read {args.path}: {exc}", file=sys.stderr)
        return 1
    if not isinstance(payload, dict):
        print(f"[report] {args.path}: not an observability artifact",
              file=sys.stderr)
        return 1
    if "traceEvents" in payload:
        summarize_trace(payload)
        return 0
    if {"counters", "gauges", "histograms"} & set(payload):
        summarize_metrics(payload)
        return 0
    print(f"[report] {args.path}: neither a Chrome trace nor a metrics "
          f"snapshot", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
