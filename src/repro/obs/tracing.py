"""Structured tracing: nested spans over a monotonic clock (DESIGN.md §13).

The repo-wide instrumentation primitive.  Design constraints, in order:

1. **Disabled is free.**  One module-level flag guards the fast path;
   ``trace(name)`` with tracing off returns a shared no-op singleton —
   no span object, no clock read, no buffer touch.  The overhead pin in
   ``tests/test_obs.py`` holds the per-call cost under 2% of a decode
   step even at hundreds of instrumented calls per step.
2. **Bounded.**  Finished spans land in a ring buffer (``deque`` with
   ``maxlen``); a long-running server can trace forever without growing.
3. **Thread-safe.**  Spans record the thread id of the thread that
   entered them; ``deque.append`` is atomic under the GIL, so concurrent
   threads interleave records without a lock.  Nesting is reconstructed
   from (tid, ts, dur) intervals — the Chrome trace model — so no
   explicit parent pointers are kept.
4. **Monotonic.**  All durations use ``time.perf_counter_ns``; wall
   clock (``time.time``) is reserved for timestamps in artifacts
   (checkpoint metadata), never for measuring elapsed time.  Other
   modules import :data:`monotonic` from here so the repo has exactly
   one duration clock.

Span kinds (Chrome trace-event phases, loadable in Perfetto or
``chrome://tracing`` via :func:`chrome_trace` / :func:`save_chrome_trace`):

* ``X`` complete spans — ``with trace("serve.decode_step", batch=4) as
  sp: ...; sp.set(plan=...)``.  When tracing is enabled the span also
  enters ``jax.named_scope(name)``, so spans wrapping jitted regions
  line up with XLA's own profiler timeline.
* ``i`` instant events — ``event("train.loss_scale", scale=2048.0)``.
* ``b``/``e`` async spans — ``async_begin("request", uid)`` /
  ``async_end("request", uid)``: long-lived logical operations (a serve
  request's lifecycle) that overlap many thread-local spans.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

# THE duration clock.  Everything in the repo that measures elapsed time
# (engine ticks, trainer steps, the autotune timer, benchmarks) imports
# these; time.time() is for wall-clock timestamps only.
monotonic = time.perf_counter
monotonic_ns = time.perf_counter_ns

try:  # tracing works without jax (the subsystem is dependency-free)
    from jax import named_scope as _named_scope
except Exception:  # pragma: no cover - jax is always present in this repo
    _named_scope = None

DEFAULT_RING = 65536

_ENABLED = False                     # the one fast-path guard
_BUF: deque = deque(maxlen=DEFAULT_RING)
_T0 = monotonic_ns()                 # trace epoch (set again by enable())


class _NoopSpan:
    """Returned by :func:`trace` when tracing is off.  A singleton: the
    disabled fast path allocates nothing and touches no state."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class Record:
    """One finished trace record (ring-buffer entry)."""

    __slots__ = ("ph", "name", "ts", "dur", "tid", "aid", "args")

    def __init__(self, ph, name, ts, dur=0, tid=0, aid=None, args=None):
        self.ph = ph                 # X | i | b | e  (Chrome phases)
        self.name = name
        self.ts = ts                 # ns, monotonic
        self.dur = dur               # ns (X only)
        self.tid = tid
        self.aid = aid               # async id (b/e only)
        self.args = args or {}


class Span:
    """A live ``X`` span.  ``set(**attrs)`` annotates it after creation —
    the idiom for attributes only known mid-span (the resolved kernel
    plan of a decode step)."""

    __slots__ = ("name", "args", "_t0", "_tid", "_scope")

    def __init__(self, name, args):
        self.name = name
        self.args = args

    def set(self, **attrs):
        self.args.update(attrs)
        return self

    def __enter__(self):
        self._scope = None
        if _named_scope is not None:
            # line our spans up with XLA's profiler timeline
            self._scope = _named_scope(self.name)
            self._scope.__enter__()
        self._tid = threading.get_ident()
        self._t0 = monotonic_ns()
        return self

    def __exit__(self, et, ev, tb):
        t1 = monotonic_ns()
        if self._scope is not None:
            self._scope.__exit__(et, ev, tb)
        _BUF.append(Record("X", self.name, self._t0, t1 - self._t0,
                           self._tid, None, self.args))
        return False


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def enable(ring: int = DEFAULT_RING):
    """Turn tracing on with a fresh ring buffer of ``ring`` records."""
    global _ENABLED, _BUF, _T0
    _BUF = deque(maxlen=ring)
    _T0 = monotonic_ns()
    _ENABLED = True


def disable():
    """Turn tracing off.  Recorded spans stay readable until the next
    :func:`enable` / :func:`clear`."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def clear():
    _BUF.clear()


def trace(name: str, **attrs):
    """Context manager for one span.  True no-op (shared singleton, no
    allocation beyond the call itself) when tracing is disabled."""
    if not _ENABLED:
        return NOOP_SPAN
    return Span(name, attrs)


def event(name: str, **attrs):
    """Record an instant event (Chrome ``i`` phase)."""
    if not _ENABLED:
        return
    _BUF.append(Record("i", name, monotonic_ns(), 0,
                       threading.get_ident(), None, attrs))


def async_begin(name: str, aid, **attrs):
    """Open an async span (Chrome ``b`` phase) — a logical operation that
    outlives any one stack frame (a serve request's lifecycle)."""
    if not _ENABLED:
        return
    _BUF.append(Record("b", name, monotonic_ns(), 0,
                       threading.get_ident(), aid, attrs))


def async_end(name: str, aid, **attrs):
    if not _ENABLED:
        return
    _BUF.append(Record("e", name, monotonic_ns(), 0,
                       threading.get_ident(), aid, attrs))


def records() -> list:
    """All buffered records, oldest first."""
    return list(_BUF)


def spans(name: str | None = None) -> list:
    """Finished ``X`` spans, optionally filtered by name."""
    return [r for r in _BUF if r.ph == "X"
            and (name is None or r.name == name)]


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto / chrome://tracing).
# ---------------------------------------------------------------------------

def chrome_trace() -> dict:
    """The buffered records as a Chrome trace-event JSON object.

    Timestamps are microseconds relative to the trace epoch (enable()).
    ``X``/``i`` records keep their recording thread's tid; ``b``/``e``
    async pairs carry their id and render as separate tracks that span
    the thread-local child spans they logically contain.
    """
    pid = os.getpid()
    evs = []
    for r in list(_BUF):
        e = {"ph": r.ph, "name": r.name, "pid": pid, "tid": r.tid,
             "ts": (r.ts - _T0) / 1e3, "cat": "repro"}
        if r.ph == "X":
            e["dur"] = r.dur / 1e3
        if r.ph in ("b", "e"):
            e["cat"] = "request"
            e["id"] = str(r.aid)
        if r.args:
            e["args"] = dict(r.args)
        evs.append(e)
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def save_chrome_trace(path) -> str:
    """Write :func:`chrome_trace` to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(), f, indent=1)
    return str(path)
