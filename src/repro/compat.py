"""Version-compatibility shims for jax APIs that moved between releases.

The production target is current jax (``jax.shard_map``, mesh axis types,
``jax.set_mesh``); CI containers may pin older releases (0.4.x) where the
same functionality lives under different names.  Everything
parallelism-related in this repo goes through these four helpers so the
kernels and collectives run unchanged on both.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types on meshes
    _AXIS_TYPE = jax.sharding.AxisType
except AttributeError:  # 0.4.x: meshes are untyped (all-auto)
    _AXIS_TYPE = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with all-Auto axis types where the API has them."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Unchecked-replication shard_map on both current and 0.4.x jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on current jax; on 0.4.x the legacy ``Mesh`` object is
    itself the context manager (NamedSharding-based code carries its mesh
    explicitly there, so the context is only needed for API parity).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The mesh installed by :func:`set_mesh`, or None when unset."""
    try:
        m = jax.sharding.get_abstract_mesh()
        return None if m.empty else m
    except AttributeError:
        pass
    try:  # 0.4.x legacy global mesh context
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001
        return None
