"""Version-compatibility shims for jax APIs that moved between releases.

The production target is current jax (``jax.shard_map``, mesh axis types,
``jax.set_mesh``); CI containers may pin older releases (0.4.x) where the
same functionality lives under different names.  Everything
parallelism-related in this repo goes through these four helpers so the
kernels and collectives run unchanged on both.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types on meshes
    _AXIS_TYPE = jax.sharding.AxisType
except AttributeError:  # 0.4.x: meshes are untyped (all-auto)
    _AXIS_TYPE = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with all-Auto axis types where the API has them."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Unchecked-replication shard_map on both current and 0.4.x jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on current jax; on 0.4.x the legacy ``Mesh`` object is
    itself the context manager (NamedSharding-based code carries its mesh
    explicitly there, so the context is only needed for API parity).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The mesh installed by :func:`set_mesh`, or None when unset."""
    try:
        m = jax.sharding.get_abstract_mesh()
        return None if m.empty else m
    except AttributeError:
        pass
    try:  # 0.4.x legacy global mesh context
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001
        return None


def distributed_initialize(coordinator_address: str, num_processes: int,
                           process_id: int) -> None:
    """Multi-process (multi-host) runtime init that works on CPU.

    ``jax.distributed.initialize`` alone is not enough on the CPU
    backend: without a CPU collectives implementation every cross-process
    computation fails with "Multiprocess computations aren't implemented
    on the CPU backend".  This shim selects the gloo transport first
    (where the knob exists — jax >= 0.4.34; real accelerator backends
    ignore it) and then initializes the distributed runtime, so the same
    launch code drives a CPU test fleet and a TPU pod.

    Must run BEFORE any jax computation; per-process device counts (e.g.
    ``--xla_force_host_platform_device_count``) must already be in
    XLA_FLAGS.  Raises whatever ``jax.distributed.initialize`` raises —
    callers treating multi-process support as optional should catch and
    skip.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # knob absent: rely on backend
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)


def distributed_shutdown() -> None:
    """Tear down the distributed runtime; a no-op when never initialized."""
    try:
        jax.distributed.shutdown()
    except RuntimeError:
        pass
