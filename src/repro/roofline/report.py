"""Inject generated roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.roofline.report \
        --baseline results/dryrun --optimized results/dryrun_v2
"""

from __future__ import annotations

import argparse

from repro.roofline.analysis import analyze_dir, markdown_table


def table_for(dry_dir: str) -> str:
    rows, skips, errors = analyze_dir(dry_dir, "single")
    skip_lines = [f"* skipped: {s['arch']} × {s['shape']} — "
                  f"{s.get('reason', '')[:80]}…" for s in skips]
    out = markdown_table(rows)
    out += (f"\n\n{len(rows)} cells compiled, {len(skips)} skipped by "
            f"assignment rule, {len(errors)} errors.\n")
    if skip_lines:
        out += "\n" + "\n".join(sorted(set(skip_lines))) + "\n"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="results/dryrun")
    ap.add_argument("--optimized", default="results/dryrun_v2")
    ap.add_argument("--doc", default="EXPERIMENTS.md")
    args = ap.parse_args()

    doc = open(args.doc).read()
    doc = doc.replace("<!-- BASELINE_TABLE -->", table_for(args.baseline))
    doc = doc.replace("<!-- OPTIMIZED_TABLE -->", table_for(args.optimized))
    open(args.doc, "w").write(doc)
    print(f"wrote tables into {args.doc}")


if __name__ == "__main__":
    main()
