"""HLO-text analysis: collective payload extraction for the roofline's
collective term (cost_analysis does not report collective bytes).

We scan the post-SPMD optimized HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops and sum their payload
bytes with op-specific traffic multipliers (ring algorithms):

    all-reduce         2 × payload        (reduce-scatter + all-gather)
    all-gather         1 × output bytes
    reduce-scatter     1 × input  bytes   (≈ output × shards)
    all-to-all         1 × payload
    collective-permute 1 × payload

Payload = bytes of the op's result shape(s) — for reduce-scatter we use
the operand shape parsed from the argument list when available.  These are
per-device program shapes (post-partitioning), i.e. bytes crossing this
chip's links, which is what the roofline denominator (chips × link_bw)
expects.
"""

from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _result_bytes(lhs: str) -> int:
    """Bytes of the result shape(s) on the lhs of an HLO instruction."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(lhs):
        if dtype in DTYPE_BYTES:
            total += shape_bytes(dtype, dims)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op: bytes, ..., 'total': weighted_bytes, 'count': n}."""
    per_op = defaultdict(float)
    count = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        if not line or "=" not in line:
            continue
        # e.g. "%ar = (bf16[128,1024]) all-reduce(...), replica_groups=..."
        m = re.search(
            r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        lhs, op = m.group(1), m.group(2)
        payload = _result_bytes(lhs)
        if op == "reduce-scatter":
            # input bytes ≈ output × shard count; parse operand shapes
            args = line[m.end():]
            in_bytes = _result_bytes(args.split("),", 1)[0])
            payload = max(payload, in_bytes)
        per_op[op] += payload * _MULT[op]
        count += 1
    out = dict(per_op)
    out["total"] = float(sum(per_op.values()))
    out["count"] = count
    return out


def op_census(hlo_text: str, opcodes=("fusion", "dot", "convolution",
                                      "scatter", "gather", "while")) -> dict:
    census = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9-]*)\(",
                      line.strip())
        if m and m.group(1) in opcodes:
            census[m.group(1)] += 1
    return dict(census)
