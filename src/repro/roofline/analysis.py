"""Roofline report generator (EXPERIMENTS.md §Roofline).

Reads the dry-run JSONs and derives, per (arch × shape × mesh):

    compute term    = FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(all three in seconds — the roofline execution-time lower bounds), the
dominant term, MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve) with N =
active params, the useful-compute ratio MODEL_FLOPS/HLO_FLOPs, and a
one-line recommendation for the dominant term.

FLOPs/bytes come from the trip-corrected HLO cost model
(roofline/hlo_cost.py), NOT from cost_analysis() (which counts scanned
layer bodies once).
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from repro.launch.mesh import HW


@dataclass
class Row:
    arch: str
    shape: str
    mesh: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_dev: float
    hlo_flops_dev: float
    useful_ratio: float
    fraction_of_peak: float
    note: str
    raw: dict


def model_flops(meta: dict) -> float:
    """Analytic MODEL_FLOPS (global): 6·N·D train, 2·N·D serve."""
    from repro.configs.base import get_arch
    from repro.models.lm import count_active_params
    arch, kind = meta["arch"], meta["kind"]
    if meta.get("family") == "vision":
        # 2 * MACs * batch (fwd) [* 3 for train]
        from repro.configs.gspn2_vision import VISION_CONFIGS
        from repro.models.vision import vision_macs
        import dataclasses
        vcfg = dataclasses.replace(VISION_CONFIGS[arch],
                                   img_size=meta["seq_len"])
        per_img = 2 * vision_macs(vcfg)
        mult = 3 if kind == "train" else 1
        return per_img * meta["global_batch"] * mult
    n = count_active_params(get_arch(arch).full())
    if kind == "train":
        tokens = meta["global_batch"] * meta["seq_len"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = meta["global_batch"] * meta["seq_len"]
        return 2.0 * n * tokens
    tokens = meta["global_batch"]          # one new token per sequence
    return 2.0 * n * tokens


def _note(dominant: str, row: dict) -> str:
    coll = row.get("collectives", {})
    biggest_coll = max(
        ((k, v) for k, v in coll.items()
         if k not in ("total", "count")), key=lambda kv: kv[1],
        default=("-", 0))[0]
    if dominant == "collective":
        return (f"dominant collective is {biggest_coll}; reduce via "
                "sharding that keeps the tensor local (e.g. move the "
                "reduction onto the FSDP axis / overlap with compute)")
    if dominant == "memory":
        return ("HBM-bound: shrink resident residuals (remat policy, "
                "bf16 residuals) or raise arithmetic intensity (fuse "
                "cache update with attention read)")
    return ("compute-bound: good — push useful-ratio toward 1 by "
            "trimming remat recompute and redundant casts")


def analyze_dir(dry_dir: str, mesh: str = "single"):
    rows, skips, errors = [], [], []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        if rec.get("status") != "ok":
            errors.append(rec)
            continue
        meta = rec["meta"]
        n_dev = rec["n_devices"]
        flops_dev = rec["flops"]
        # prefer the fusion-aware calibrated bytes when present
        bytes_dev = rec.get("bytes_hbm_calibrated") or rec["bytes_hbm"]
        coll_dev = rec["collectives"]["total"]
        compute_s = flops_dev / HW["peak_flops_bf16"]
        memory_s = bytes_dev / HW["hbm_bw"]
        collective_s = coll_dev / HW["ici_bw"]
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops(meta) / n_dev
        useful = mf / flops_dev if flops_dev else 0.0
        frac = compute_s / max(max(terms.values()), 1e-30)
        rows.append(Row(
            arch=meta["arch"], shape=meta["shape"], mesh=mesh,
            kind=meta["kind"], compute_s=compute_s, memory_s=memory_s,
            collective_s=collective_s, dominant=dominant,
            model_flops_dev=mf, hlo_flops_dev=flops_dev,
            useful_ratio=useful, fraction_of_peak=frac,
            note=_note(dominant, rec), raw=rec))
    return rows, skips, errors


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL_FLOPs/dev | useful | peak-frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        lines.append(
            f"| {r.arch} | {r.shape} | {fmt_s(r.compute_s)} | "
            f"{fmt_s(r.memory_s)} | {fmt_s(r.collective_s)} | "
            f"**{r.dominant}** | {r.model_flops_dev:.2e} | "
            f"{r.useful_ratio:.2f} | {r.fraction_of_peak:.2f} |")
    return hdr + "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows, skips, errors = analyze_dir(args.dir, args.mesh)
    print(markdown_table(rows))
    print(f"\n{len(rows)} cells, {len(skips)} skipped, "
          f"{len(errors)} errors")
    for r in sorted(rows, key=lambda r: r.fraction_of_peak)[:5]:
        print(f"worst: {r.arch}×{r.shape} frac={r.fraction_of_peak:.2f} "
              f"dom={r.dominant} — {r.note}")


if __name__ == "__main__":
    main()
