"""HLO-text cost model with while-loop trip-count correction.

XLA's ``compiled.cost_analysis()`` visits every instruction ONCE — a
``lax.scan`` body (our scan-over-layers, microbatch accumulation, the
flash-attention KV loop...) is counted a single time regardless of trip
count, underestimating FLOPs/bytes by up to the model depth.  This module
re-derives the three roofline inputs from the optimized HLO text:

* **flops** — dot (2·out_elems·K from resolved operand shapes and
  ``lhs_contracting_dims``) and an approximate convolution count; summed
  over every executed computation weighted by the product of enclosing
  while-loop trip counts (from ``backend_config known_trip_count``, falling
  back to the largest constant in the loop condition).
* **bytes** — operand + result bytes of top-level instructions of executed
  computations, trip-weighted.  Fusion bodies are excluded: a fusion's HBM
  traffic is its call site's operands/results (on-chip traffic is free).
  Deliberately ignores cache reuse — an upper-bound HBM model.
* **collective bytes** — payloads of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute with ring-traffic
  multipliers (all-reduce 2×), trip-weighted.

All quantities are per-device (post-SPMD per-device program).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "call", "conditional"}

_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
# lhs is matched lazily: tuple result shapes contain `/*index=N*/`
# comments (with '=') and layout annotations, so anything up to the first
# " opcode(" token is the result shape.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-z][\w\-]*)\((.*)$")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt in DTYPE_BYTES:
            total += _shape_elems(dims) * DTYPE_BYTES[dt]
    return total


def _split_args_attrs(rest: str):
    """rest = everything after 'opcode(' to line end.  Returns
    (args_text, attrs_text) by matching the closing paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


@dataclass
class Instr:
    name: str
    opcode: str
    lhs: str
    args: str
    attrs: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)      # instr name -> lhs text


def parse_computations(hlo: str):
    comps, cur, entry = {}, None, None
    for line in hlo.splitlines():
        mh = _COMP_HDR.match(line)
        if mh:
            cur = Computation(mh.group(2), is_entry=bool(mh.group(1)))
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if mi:
            name, lhs, opcode, rest = mi.groups()
            args, attrs = _split_args_attrs(rest)
            ins = Instr(name=name, opcode=opcode, lhs=lhs, args=args,
                        attrs=attrs)
            cur.instrs.append(ins)
            cur.shapes[name] = lhs
    return comps, entry


def _refs(ins: Instr) -> dict:
    out = {}
    for key in ("body", "condition", "to_apply", "calls"):
        m = re.search(rf"{key}=%?([\w.\-]+)", ins.attrs)
        if m:
            out[key] = m.group(1)
    return out


def _trip_count(ins: Instr, comps) -> int:
    m = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', ins.attrs)
    if not m:
        m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
    if m:
        return max(int(m.group(1)), 1)
    cond = comps.get(_refs(ins).get("condition"))
    best = 1
    if cond is not None:
        for ci in cond.instrs:
            for mm in re.finditer(r"constant\((\d+)\)", ci.args + ci.attrs
                                  + ci.lhs + ci.opcode):
                best = max(best, int(mm.group(1)))
            if ci.opcode == "constant":
                mm = re.search(r"s32\[\][^%]*", ci.lhs)
        # constants appear as standalone instrs: constant(7) in raw text
    return best


def _operand_names(args: str):
    return re.findall(r"%([\w.\-]+)", args)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = sum(_shape_elems(d) for t, d in _SHAPE.findall(ins.lhs)
                    if t in DTYPE_BYTES)
    ops = _operand_names(ins.args)
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0], "")
    m = _SHAPE.search(lhs_shape)
    if not m:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    k = 1
    if mc and mc.group(1):
        for idx in mc.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = sum(_shape_elems(d) for t, d in _SHAPE.findall(ins.lhs)
                    if t in DTYPE_BYTES)
    ops = _operand_names(ins.args)
    if len(ops) < 2:
        return 0.0
    kshape = comp.shapes.get(ops[1], "")
    m = _SHAPE.search(kshape)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",") if d]
    if not dims:
        return 0.0
    per_out = max(_shape_elems(m.group(2)) // max(dims[-1], 1), 1)
    return 2.0 * out_elems * per_out


def _instr_bytes(ins: Instr, comp: Computation) -> int:
    total = _shapes_bytes(ins.lhs)
    for op in _operand_names(ins.args):
        total += _shapes_bytes(comp.shapes.get(op, ""))
    return total


def analyze(hlo: str) -> dict:
    comps, entry = parse_computations(hlo)
    if entry is None and comps:
        entry = next(iter(comps))
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {"total": 0.0, "count": 0}}

    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                r = _refs(ins)
                if "calls" in r:
                    fusion_bodies.add(r["calls"])

    mult = defaultdict(float)
    mult[entry] = 1.0
    order, seen = [entry], {entry}
    trips_seen = {}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for ins in comp.instrs:
            r = _refs(ins)
            if not r:
                continue
            if ins.opcode == "while":
                trips = _trip_count(ins, comps)
                trips_seen[r.get("body", "?")] = trips
                factor = m * trips
            else:
                factor = m
            for key, target in r.items():
                mult[target] += factor
                if target not in seen:
                    seen.add(target)
                    order.append(target)

    flops = 0.0
    bytes_hbm = 0.0
    bytes_unit = 0.0     # multiplier-free (= XLA's visit-once convention)
    coll = defaultdict(float)
    coll_count = 0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        in_fusion = name in fusion_bodies
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                flops += m * _conv_flops(ins, comp)
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                payload = _shapes_bytes(ins.lhs)
                if base == "reduce-scatter":
                    for op in _operand_names(ins.args):
                        payload = max(payload,
                                      _shapes_bytes(comp.shapes.get(op, "")))
                coll[base] += m * payload * _COLL_MULT[base]
                coll_count += 1
            if not in_fusion and ins.opcode not in _SKIP_BYTES \
                    and not ins.opcode.endswith("-done"):
                b = _instr_bytes(ins, comp)
                bytes_hbm += m * b
                bytes_unit += b
    out_coll = dict(coll)
    out_coll["total"] = float(sum(coll.values()))
    out_coll["count"] = coll_count
    return {"flops": float(flops), "bytes": float(bytes_hbm),
            "bytes_unit": float(bytes_unit),
            "trip_ratio": float(bytes_hbm / bytes_unit) if bytes_unit
            else 1.0,
            "collectives": out_coll, "while_trips": trips_seen,
            "n_computations": len(comps)}
