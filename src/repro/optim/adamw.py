"""Pure-JAX AdamW with schedules, global-norm clipping and configurable
optimizer-state dtype (bf16 m/v halves the optimizer memory per device at
1T-param scale — see DESIGN.md §5).

API mirrors optax: ``init(params) -> state``; ``update(grads, state,
params) -> (new_params, new_state, stats)``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"         # cosine|linear|constant
    min_lr_ratio: float = 0.1
    state_dtype: jnp.dtype = jnp.float32   # bf16 for memory-constrained runs


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * t
    else:
        decay = jnp.array(1.0)
    return cfg.lr * warm * decay


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(a.astype(jnp.float32)))
              for a in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda a: (a.astype(jnp.float32) * scale)
                        .astype(a.dtype), tree), norm


def adamw_init(cfg: AdamWConfig, params):
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, cfg.state_dtype), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def _decay_mask(path) -> bool:
    """No weight decay for norms / biases / 1-d params."""
    name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)
    return not any(t in name for t in ("scale", "bias", "b1", "b2",
                                       "dt_bias", "a_log", "d_skip"))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        decay = cfg.weight_decay if (cfg.weight_decay and _decay_mask(path)
                                     and p.ndim >= 2) else 0.0

        def math(p, g, m, v):
            # Native-dtype update for fully-bf16 leaves: the f32 casts of
            # bf16 p/m/v get loop-hoisted by XLA into whole-stack f32
            # copies (several 5 GB buffers on kimi train_4k).  f32 leaves
            # keep exact f32 math.
            cd = jnp.float32 if jnp.float32 in (p.dtype, m.dtype) \
                else p.dtype
            gf = g.astype(cd)
            mf = m.astype(cd) * b1 + gf * (1 - b1)
            vf = v.astype(cd) * b2 + jnp.square(gf) * (1 - b2)
            upd_dir = (mf / bc1.astype(cd)) / (
                jnp.sqrt(vf / bc2.astype(cd)) + cfg.eps)
            pf = p.astype(cd)
            if decay:
                upd_dir = upd_dir + decay * pf
            new_p = (pf - lr.astype(cd) * upd_dir).astype(p.dtype)
            return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

        # Layer-stacked leaves (scan-over-layers params) are updated one
        # layer at a time: the f32 intermediates of the update shrink by
        # the stack depth (slab-sized f32 temporaries were ~5 GB/device
        # each on kimi train_4k).
        if p.ndim >= 3 and p.shape[0] >= 8 and p.size > 2 ** 24:
            return jax.lax.map(lambda a: math(*a), (p, g, m, v))
        return math(p, g, m, v)

    flat = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat[0]]
    p_leaves = [l for _, l in flat[0]]
    g_leaves = jax.tree.leaves(grads)
    m_leaves = jax.tree.leaves(state["m"])
    v_leaves = jax.tree.leaves(state["v"])
    new = [upd(pa, p, g, m, v) for pa, p, g, m, v
           in zip(paths, p_leaves, g_leaves, m_leaves, v_leaves)]
    treedef = flat[1]
    new_params = jax.tree_util.tree_unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [n[2] for n in new])
    stats = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
