"""qwen2-1.5b — GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936; tied embeddings.
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6,
        unit=(("attn", 28),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen2-reduced", family="dense",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        d_ff=128, vocab=512, qkv_bias=True, tie_embeddings=True,
        unit=(("attn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="qwen2-1.5b", family="dense", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="arXiv:2407.10671"))
