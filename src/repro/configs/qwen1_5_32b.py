"""qwen1.5-32b — MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1e6,
        unit=(("attn", 64),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen1.5-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=512, qkv_bias=True,
        unit=(("attn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="qwen1.5-32b", family="dense", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="hf:Qwen/Qwen1.5-0.5B"))
