"""Config registry and input-shape catalogue.

Every assigned architecture registers a ``full(n_model_shards)`` LMConfig
(the exact published dims) and a ``reduced()`` config of the same family
for CPU smoke tests.  ``input_specs`` builds ShapeDtypeStruct stand-ins for
every (arch × shape) dry-run cell without allocating anything.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    family: str
    full: Callable[..., LMConfig]
    reduced: Callable[[], LMConfig]
    # cells skipped per assignment rules, with reasons (DESIGN.md §4)
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""


REGISTRY: Dict[str, ArchEntry] = {}


def register(entry: ArchEntry):
    REGISTRY[entry.name] = entry
    return entry


def get_arch(name: str) -> ArchEntry:
    if name not in REGISTRY:
        import repro.configs.all_archs  # noqa: F401 — populate registry
    return REGISTRY[name]


def list_archs():
    import repro.configs.all_archs  # noqa: F401
    return sorted(REGISTRY)


FULL_ATTENTION_SKIP = (
    "full attention is quadratic in context; assignment rule: skip "
    "long_500k for pure full-attention archs (decode itself is O(L) but "
    "the rule is applied as written; see DESIGN.md §4)")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct only — no allocation).
# ---------------------------------------------------------------------------

def input_specs(cfg: LMConfig, shape: ShapeSpec, *, vision_len: int = 1024):
    """Returns (kind, kwargs-of-ShapeDtypeStructs) for the dry-run lowering."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, min(vision_len, s // 2), cfg.d_model), f32)
        if cfg.family == "audio":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_len, cfg.d_model), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, min(vision_len, s // 2), cfg.d_model), f32)
        if cfg.family == "audio":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_len, cfg.d_model), f32)
        return batch
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)
