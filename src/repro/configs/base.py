"""Config registry, input-shape catalogue, and the mixed-precision policy.

Every assigned architecture registers a ``full(n_model_shards)`` LMConfig
(the exact published dims) and a ``reduced()`` config of the same family
for CPU smoke tests.  ``input_specs`` builds ShapeDtypeStruct stand-ins for
every (arch × shape) dry-run cell without allocating anything.

The :class:`Precision` policy (DESIGN.md §10) is the single source of
truth for how dtypes thread through the stack: ``param_dtype`` (storage),
``compute_dtype`` (matmuls and streamed scan operands) and ``carry_dtype``
(scan carries / boundary compositions / accumulators).  The default
production policy is bf16/bf16/f32 — the FlashAttention-2 recipe of
low-precision streamed operands with f32 accumulators, applied to the
GSPN carry rows.  ``with_precision`` rewrites any LMConfig to a policy;
launchers accept the preset names in :data:`PRECISIONS`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Union

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig


# ---------------------------------------------------------------------------
# Mixed-precision policy (DESIGN.md §10).
# ---------------------------------------------------------------------------

DTYPES = {
    "f32": jnp.float32, "float32": jnp.float32, "fp32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
}


def resolve_dtype(name: Union[str, Any]):
    """Map a CLI/config dtype name ("f32", "bf16", ...) to a jnp dtype;
    dtype-like objects pass through."""
    if isinstance(name, str):
        try:
            return DTYPES[name.lower()]
        except KeyError:
            raise ValueError(
                f"unknown dtype {name!r}; expected one of {sorted(DTYPES)}")
    return name


@dataclasses.dataclass(frozen=True)
class Precision:
    """End-to-end dtype policy: params / streamed compute / carries.

    The default is the production mixed policy — bf16 storage and streams,
    f32 for everything that integrates over the sequence (scan carries,
    sp boundary composition, softmax/loss reductions).  Carries must not
    narrow with the streams: the scan is a long dependent product, and
    bf16's 8 mantissa bits lose the Stability–Context non-expansiveness
    guarantee to accumulated rounding (DESIGN.md §10).
    """
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    carry_dtype: Any = jnp.float32


PRECISIONS: Dict[str, Precision] = {
    # full f32 — the validation/numerics-oracle policy
    "f32": Precision(jnp.float32, jnp.float32, jnp.float32),
    # production default: bf16 streams, f32 carries
    "bf16": Precision(),
    # bf16 compute over f32 master-ish params (no train master copy
    # needed; params stay f32, casts happen at use)
    "bf16_f32params": Precision(jnp.float32, jnp.bfloat16, jnp.float32),
}


def resolve_precision(p: Union[str, Precision]) -> Precision:
    if isinstance(p, str):
        try:
            return PRECISIONS[p]
        except KeyError:
            raise ValueError(f"unknown precision preset {p!r}; "
                             f"expected one of {sorted(PRECISIONS)}")
    return p


def with_precision(cfg: LMConfig, precision: Union[str, Precision]) -> LMConfig:
    """Rewrite an LMConfig to a mixed-precision policy: parameter storage,
    attention/FFN compute, the GSPN mixer's streamed compute, and the scan
    carry dtype all follow the policy (DESIGN.md §10)."""
    p = resolve_precision(precision)
    return dataclasses.replace(
        cfg,
        param_dtype=resolve_dtype(p.param_dtype),
        compute_dtype=resolve_dtype(p.compute_dtype),
        gspn_compute_dtype=resolve_dtype(p.compute_dtype),
        carry_dtype=resolve_dtype(p.carry_dtype))


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    family: str
    full: Callable[..., LMConfig]
    reduced: Callable[[], LMConfig]
    # cells skipped per assignment rules, with reasons (DESIGN.md §4)
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""


REGISTRY: Dict[str, ArchEntry] = {}


def register(entry: ArchEntry):
    REGISTRY[entry.name] = entry
    return entry


def get_arch(name: str) -> ArchEntry:
    if name not in REGISTRY:
        import repro.configs.all_archs  # noqa: F401 — populate registry
    return REGISTRY[name]


def list_archs():
    import repro.configs.all_archs  # noqa: F401
    return sorted(REGISTRY)


FULL_ATTENTION_SKIP = (
    "full attention is quadratic in context; assignment rule: skip "
    "long_500k for pure full-attention archs (decode itself is O(L) but "
    "the rule is applied as written; see DESIGN.md §4)")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct only — no allocation).
# ---------------------------------------------------------------------------

def input_specs(cfg: LMConfig, shape: ShapeSpec, *, vision_len: int = 1024):
    """Returns (kind, kwargs-of-ShapeDtypeStructs) for the dry-run lowering."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, min(vision_len, s // 2), cfg.d_model), f32)
        if cfg.family == "audio":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_len, cfg.d_model), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, min(vision_len, s // 2), cfg.d_model), f32)
        if cfg.family == "audio":
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_len, cfg.d_model), f32)
        return batch
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(shape.kind)
