"""granite-3-2b — GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155; tied embeddings.
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=49155, tie_embeddings=True, rope_theta=1e4,
        unit=(("attn", 40),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="granite-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab=512, tie_embeddings=True,
        unit=(("attn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="granite-3-2b", family="dense", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="hf:ibm-granite/granite-3.0-2b-base"))
