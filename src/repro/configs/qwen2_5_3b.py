"""qwen2.5-3b — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936; tied embeddings.
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="qwen2.5-3b", family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6,
        unit=(("attn", 36),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen2.5-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=176, vocab=512, qkv_bias=True, tie_embeddings=True,
        unit=(("attn", 3),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="qwen2.5-3b", family="dense", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="hf:Qwen/Qwen2.5-0.5B"))
