"""qwen2-1.5b-gspn — BEYOND-PAPER variant: qwen2-1.5b dims with the
GSPN-2 sequence mixer replacing attention.

Demonstrates the paper's technique unlocking the long_500k cell for a
dense-arch configuration: the GSPN mixer is O(√L)-sequential with an
O(√L) decode cache (DESIGN.md §4).  Row width 1024 ⇒ 512×1024 grid at
524288 tokens.
"""

from repro.configs.base import ArchEntry, register
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="qwen2-1.5b-gspn", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, tie_embeddings=True,
        gspn_proxy_dim=8, gspn_row_width=1024,
        unit=(("gspn", 28),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen2-gspn-reduced", family="dense",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, tie_embeddings=True,
        gspn_proxy_dim=4, gspn_row_width=8,
        unit=(("gspn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="qwen2-1.5b-gspn", family="dense", full=full, reduced=reduced,
    skip_shapes={},   # GSPN mixer: sub-quadratic, all shapes run
    source="beyond-paper variant (this work)"))
