"""Import side-effect module: populates the architecture registry."""

# The 10 assigned architectures.
import repro.configs.xlstm_1_3b       # noqa: F401
import repro.configs.qwen1_5_32b      # noqa: F401
import repro.configs.granite_3_2b     # noqa: F401
import repro.configs.qwen2_1_5b       # noqa: F401
import repro.configs.qwen2_5_3b       # noqa: F401
import repro.configs.zamba2_2_7b      # noqa: F401
import repro.configs.qwen2_vl_72b     # noqa: F401
import repro.configs.kimi_k2_1t_a32b  # noqa: F401
import repro.configs.grok_1_314b      # noqa: F401
import repro.configs.whisper_base     # noqa: F401

# Beyond-paper GSPN-mixer variant (this work).
import repro.configs.qwen2_1_5b_gspn  # noqa: F401

ASSIGNED = [
    "xlstm-1.3b", "qwen1.5-32b", "granite-3-2b", "qwen2-1.5b",
    "qwen2.5-3b", "zamba2-2.7b", "qwen2-vl-72b", "kimi-k2-1t-a32b",
    "grok-1-314b", "whisper-base",
]

EXTRAS = ["qwen2-1.5b-gspn"]
