"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064.  The vision frontend
is a STUB per the assignment: input_specs provides precomputed patch
embeddings (B, S_vis, D) spliced into the first S_vis positions; M-RoPE
drives the backbone with 3-plane position ids (head_dim 128 → sections
16/24/24 frequency slots).
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        unit=(("attn", 80),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="qwen2-vl-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=512, qkv_bias=True, mrope_sections=(4, 2, 2),
        unit=(("attn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="qwen2-vl-72b", family="vlm", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="arXiv:2409.12191"))
