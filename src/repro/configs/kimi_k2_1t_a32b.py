"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (kv=8) d_ff=2048(expert) vocab=163840,
MoE 384 experts top-8 + 1 shared expert; first layer dense (d_ff 18432).
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432,                 # the single dense layer's FFN
        vocab=163840, rope_theta=5e4,
        n_experts=384, top_k=8, moe_d_ff=2048, shared_expert_ff=2048,
        capacity_factor=1.25,
        prelude=(("attn", 1),), unit=(("attn_moe", 60),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="kimi-reduced", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=512,
        n_experts=8, top_k=2, moe_d_ff=32, shared_expert_ff=32,
        capacity_factor=2.0,
        prelude=(("attn", 1),), unit=(("attn_moe", 2),), n_units=1,
        remat="none",
    )


register(ArchEntry(
    name="kimi-k2-1t-a32b", family="moe", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="arXiv:2501.kimi2 (unverified)"))
