"""GSPN-2 vision configs (the paper's own architecture, Table 2).

Parameter/MAC targets: T 24M/4.2G, S 50M/9.2G, B 89M/14.2G at 224².
Paper ImageNet setting: channel-shared taps, C_proxy = 2.
"""

from repro.models.vision import GSPNVisionConfig

GSPN2_T = GSPNVisionConfig(
    name="gspn2-t", img_size=224,
    dims=(80, 160, 320, 512), depths=(3, 4, 14, 5), proxy_dim=2)

GSPN2_S = GSPNVisionConfig(
    name="gspn2-s", img_size=224,
    dims=(96, 192, 432, 648), depths=(4, 6, 16, 6), proxy_dim=2)

GSPN2_B = GSPNVisionConfig(
    name="gspn2-b", img_size=224,
    dims=(128, 256, 512, 768), depths=(4, 6, 19, 8), proxy_dim=2)

# GSPN-1 algorithmic mode (per-channel propagation weights) for the
# fig-3/ablation benchmarks.
GSPN1_T = GSPNVisionConfig(
    name="gspn1-t", img_size=224,
    dims=(80, 160, 320, 512), depths=(3, 4, 14, 5), proxy_dim=8,
    channel_shared=False)

VISION_CONFIGS = {c.name: c for c in [GSPN2_T, GSPN2_S, GSPN2_B, GSPN1_T]}


def reduced_vision() -> GSPNVisionConfig:
    return GSPNVisionConfig(
        name="gspn2-reduced", img_size=32,
        dims=(16, 32, 48, 64), depths=(1, 1, 2, 1), proxy_dim=2,
        n_classes=10)
