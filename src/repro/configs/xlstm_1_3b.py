"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 vocab=50304.  xLSTM blocks carry their own
up/down projections (expand=2), so d_ff=0 (no separate FFN) is faithful.
Pattern: 7 mLSTM + 1 sLSTM per unit × 6 units = 48 layers.
"""

from repro.configs.base import ArchEntry, register
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        unit=(("mlstm", 7), ("slstm", 1)), n_units=6,
        gla_chunk=256,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="xlstm-reduced", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=512,
        unit=(("mlstm", 1), ("slstm", 1)), n_units=2,
        gla_chunk=32, remat="none",
    )


register(ArchEntry(
    name="xlstm-1.3b", family="ssm", full=full, reduced=reduced,
    skip_shapes={},   # sub-quadratic: all four shapes run
    source="arXiv:2405.04517 (unverified)"))
