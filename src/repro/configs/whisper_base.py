"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified].

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  Encoder: 6 bidirectional
layers over stub frame embeddings (enc_len=1500 ≙ 30 s); decoder: 6 layers
with cross-attention.  GELU MLP + LayerNorm per the original.  Deviations
(DESIGN.md §7): rotary instead of learned positions in the decoder; 32k/
500k decode cells far exceed Whisper's trained 448-token context and are
lowered shape-only.
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=51865, mlp="gelu", norm="layernorm",
        encoder_layers=6, enc_len=1500, rope_theta=1e4,
        unit=(("xattn", 6),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="whisper-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, mlp="gelu", norm="layernorm",
        encoder_layers=2, enc_len=32,
        unit=(("xattn", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="whisper-base", family="audio", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="arXiv:2212.04356 (unverified)"))
