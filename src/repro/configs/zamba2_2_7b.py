"""zamba2-2.7b — Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Structure: 54 Mamba2 layers; one weight-SHARED attention block applied
after every 6 Mamba layers (9 applications, 1 weight set).
"""

from repro.configs.base import ArchEntry, register
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, ssm_state=64, ssm_head_dim=64,
        unit=(("mamba", 6),), n_units=9, shared_attn=True,
        gla_chunk=256,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="zamba2-reduced", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, ssm_state=16, ssm_head_dim=16,
        unit=(("mamba", 2),), n_units=2, shared_attn=True,
        gla_chunk=32, remat="none",
    )


register(ArchEntry(
    name="zamba2-2.7b", family="hybrid", full=full, reduced=reduced,
    skip_shapes={},   # Mamba2 decode is O(1); shared-attn KV is seq-sharded
    source="arXiv:2411.15242"))
