"""grok-1-314b — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
With 8 experts on a 16-way model axis the MoE slabs use ep=8, tp=2
(each expert's hidden dim split over two shards — see moe.py).
"""

from repro.configs.base import ArchEntry, register, FULL_ATTENTION_SKIP
from repro.models.lm import LMConfig


def full(n_model_shards: int = 1) -> LMConfig:
    return LMConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072, rope_theta=1e4,
        n_experts=8, top_k=2, moe_d_ff=32768,
        capacity_factor=1.25,
        unit=(("attn_moe", 64),), n_units=1,
        n_model_shards=n_model_shards,
    )


def reduced() -> LMConfig:
    return LMConfig(
        name="grok-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        n_experts=4, top_k=2, moe_d_ff=128, capacity_factor=2.0,
        unit=(("attn_moe", 2),), n_units=1, remat="none",
    )


register(ArchEntry(
    name="grok-1-314b", family="moe", full=full, reduced=reduced,
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP},
    source="hf:xai-org/grok-1 (unverified)"))
