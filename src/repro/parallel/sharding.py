"""Sharding rules: param-path patterns → PartitionSpecs.

Parallelism layout (DESIGN.md §5):

* ``model`` axis — tensor parallelism: attention heads / FFN hidden /
  vocab / expert groups (MoE slabs are laid out per-shard, see moe.py).
* ``data`` axis — data parallelism **and** FSDP: most 2-D weights also
  shard their non-TP dim over ``data`` (ZeRO-3-style; XLA inserts the
  all-gathers on use and reduce-scatters in backward).
* ``pod`` axis — outer data parallelism across pods (DCN).  Parameters are
  replicated across pods; gradients all-reduce hierarchically.

Rules are matched by regex on the flattened parameter path; each rule
gives the spec of the *trailing* dims — leading stacked-layer dims (from
scan-over-layers) are padded with None automatically.  ``sanitize_spec``
drops any axis whose size does not divide the corresponding array dim, so
a single rule set serves every architecture/mesh combination.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (pattern, trailing-dims spec).  First match wins.  "fsdp" is substituted
# with the data axis name; "tp" with the model axis name.
#
# NOTE on FSDP placement: weights are FSDP-sharded on their *contraction*
# dim (maxtext-style).  This only stays cheap if activations are anchored
# to batch-over-data sharding with explicit constraints (lm.py `_anchor`);
# without the anchor the partitioner may instead unshard the batch to keep
# the contraction sharded (observed: 40 GB full-batch logits + a 40 GB
# all-reduce on the 4k train cell).  embed/head are vocab-sharded only —
# their gather / logits-matmul patterns interact badly with contraction
# sharding.
PARAM_RULES = [
    # --- embeddings / head ---
    (r"embed$",                ("tp", None)),
    (r"head$",                 (None, "tp")),
    (r"pos_embed$",            (None, None)),
    # --- MoE slabs: (M, E_loc, D, F_loc) laid out per model shard ---
    (r"(gate_slab|up_slab)$",  ("tp", None, "fsdp", None)),
    (r"down_slab$",            ("tp", None, None, "fsdp")),
    (r"router$",               (None, None)),
    # --- attention ---
    (r"(wq|wk|wv)$",           ("fsdp", "tp")),
    (r"wo$",                   ("tp", "fsdp")),
    (r"(bq|bk|bv)$",           ("tp",)),
    # --- dense FFN ---
    (r"(gate|up|fc1)$",        ("fsdp", "tp")),
    (r"(down|fc2)$",           ("tp", "fsdp")),
    (r"b1$",                   ("tp",)),
    (r"b2$",                   (None,)),
    # --- mixers (mamba/mlstm/slstm): column-, then row-parallel ---
    (r"(in_proj|w_in)$",       ("fsdp", "tp")),
    (r"out_proj$",             ("tp", "fsdp")),
    (r"\br$",                  (None, "tp", None, None)),   # sLSTM recurrent
    (r"conv/w$",               (None, None, "tp")),
    # --- GSPN mixer / attention generators (small): fsdp only ---
    (r"(w_taps|w_lam|w_u|w_row)$", ("fsdp", None)),
    (r"gspn/(down|up)$",       ("fsdp", None)),
    (r"mix/(down|up)$",        ("fsdp", None)),
    # --- encoder kv proj ---
    (r"enc_kv_proj/(wk|wv)$",  ("fsdp", "tp")),
    # --- vision convs / everything small: replicate ---
    (r".*",                    None),
]


def path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def sanitize_spec(spec, shape, mesh: Mesh):
    """Drop mesh axes that the mesh lacks or that don't divide the dim —
    one rule set then serves every mesh layout (incl. seq-only meshes)."""
    if spec is None:
        return P()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a not in mesh.shape for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def spec_for_param(path, leaf, mesh: Mesh, *, fsdp_axis="data",
                   tp_axis="model") -> P:
    name = path_str(path)
    for pattern, trailing in PARAM_RULES:
        if re.search(pattern, name):
            if trailing is None:
                return P()
            sub = tuple(
                fsdp_axis if t == "fsdp" else tp_axis if t == "tp" else t
                for t in trailing)
            pad = leaf.ndim - len(sub)
            spec = (None,) * pad + sub
            return sanitize_spec(spec, leaf.shape, mesh)
    return P()


def param_shardings(params, mesh: Mesh, *, fsdp_axis="data",
                    tp_axis="model"):
    """NamedSharding tree matching ``params`` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        spec = spec_for_param(path, leaf, mesh, fsdp_axis=fsdp_axis,
                              tp_axis=tp_axis)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(opt_state, param_shardings_tree, mesh: Mesh):
    """m/v mirror the param shardings; scalars replicated."""
    def build(sub):
        return jax.tree.map(lambda s: s, param_shardings_tree)

    return {
        "m": build(opt_state["m"]),
        "v": build(opt_state["v"]),
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(batch, mesh: Mesh, dp_axes=("data",)):
    """tokens/labels: batch dim over dp axes; embeds likewise."""
    def one(path, leaf):
        spec = sanitize_spec(P(dp_axes), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(caches, mesh: Mesh, dp_axes=("data",), tp_axis="model"):
    """Decode caches: batch dim over dp, head/state dims over model where
    divisible.  Caches are stacked (stage dims first); the batch dim is
    found per-leaf by matching against known layouts, so we apply a simple
    heuristic: shard the largest dim divisible by the dp size, leave the
    rest replicated except kv-head dims over model."""
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    def one(path, leaf):
        name = path_str(path)
        spec = [None] * leaf.ndim
        # kv caches: (..., B, S, Hkv, hd) — shard B on dp; Hkv on model when
        # divisible, otherwise shard the sequence dim on model (GQA models
        # with few KV heads at 500k context: the cache must not replicate).
        if re.search(r"attn/(k|v)$", name) and leaf.ndim >= 4:
            if leaf.shape[-4] % dp_size == 0:
                spec[-4] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            tp = mesh.shape[tp_axis]
            if leaf.shape[-2] % tp == 0:
                spec[-2] = tp_axis
            elif leaf.shape[-3] % tp == 0:
                spec[-3] = tp_axis
        else:
            # shard the first dim divisible by dp (usually batch)
            for i, d in enumerate(leaf.shape):
                if d % dp_size == 0 and d >= dp_size:
                    spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                    break
        return NamedSharding(mesh, sanitize_spec(P(*spec), leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint with divisibility sanitising."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, sanitize_spec(spec, x.shape, mesh)))


# ---------------------------------------------------------------------------
# Spatial sequence parallelism (DESIGN.md §8): activation specs for the
# scan dimension.  The sp scan itself (parallel/gspn_sp.py) runs as a
# shard_map over the ``seq`` axis; these helpers place the SURROUNDING
# activations so the partitioner keeps them scan-dim-sharded between
# scans instead of gathering them back per layer.
# ---------------------------------------------------------------------------

SEQ_AXIS = "seq"


def scan_dim_spec(ndim: int, scan_dim: int = -2, *, batch_dim: int | None = 0,
                  dp_axes=("data",), seq_axis: str = SEQ_AXIS) -> P:
    """PartitionSpec sharding ``scan_dim`` over the seq axis (and the
    batch dim over dp).  Works for (G, H, W) scan operands (default) and
    (B, H, W, C) vision activations (``scan_dim=1``)."""
    spec = [None] * ndim
    spec[scan_dim % ndim] = seq_axis
    if batch_dim is not None and batch_dim % ndim != scan_dim % ndim:
        spec[batch_dim % ndim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*spec)


def sp_activation_shardings(tree, mesh: Mesh, *, scan_dim: int = -2,
                            batch_dim: int | None = 0, dp_axes=("data",),
                            seq_axis: str = SEQ_AXIS):
    """NamedSharding tree for scan-dim-sharded activations (sanitised, so
    meshes without a ``seq`` axis degrade to plain dp sharding)."""
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    have_seq = seq_axis in mesh.axis_names

    def one(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2 or not (
                have_seq or dp_axes):
            return NamedSharding(mesh, P())
        spec = scan_dim_spec(leaf.ndim, scan_dim,
                             batch_dim=batch_dim if dp_axes else None,
                             dp_axes=dp_axes or ("data",),
                             seq_axis=seq_axis)
        if not have_seq:
            spec = P(*(None if s == seq_axis else s for s in spec))
        return NamedSharding(mesh, sanitize_spec(spec, leaf.shape, mesh))

    return jax.tree.map(one, tree)
