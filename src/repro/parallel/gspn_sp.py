"""Spatial sequence parallelism for the GSPN line scan (DESIGN.md §8).

PR 1 fused the multi-direction dispatch, but every scan still ran on ONE
device — the mesh axes only sharded weights, so resolution / folded
sequence length were capped by a single chip's VMEM/HBM.  This module
shards the scan dimension itself across a ``seq`` mesh axis, following the
LASP/LASP-2 observation (arXiv 2404.02882, 2502.07563) that linear
recurrences admit sequence parallelism with a SINGLE compact boundary
exchange per scan instead of any full-activation collective.

Decomposition.  The canonical recurrence (top→bottom over rows, W lanes)

    h[i] = M[i] h[i-1] + lam[i]·x[i],   M[i] tridiagonal from (wl, wc, wr)

is linear in the carry, so partitioning rows into K contiguous blocks
(one per ``seq`` shard) gives, for block k with incoming boundary
``b_k = h[first_row_k - 1]``:

    h[i] = h_loc[i] + (∏_{r=first_k..i} M[r]) · b_k

where ``h_loc`` is the block-local scan with zero incoming state.  Each
device therefore computes, fully in parallel:

  1. ``h_loc``  — the existing fused kernel on its local rows;
  2. ``T_k = ∏_{r in block k} M[r]`` — the (W, W) *boundary transfer
     operator*, one per weight group (compact mode amortises it over
     ``channels_per_weight`` channels);
  3. its outgoing uncorrected boundary ``bl_k`` (last local row of
     ``h_loc``).

Boundary composition is associative —
``(T_b, b_b) ∘ (T_a, b_a) = (T_b T_a, T_b b_a + b_b)`` — so the corrected
incoming boundaries ``b_k`` compose across blocks with ONE logical
exchange.  Two strategies (``strategy=``):

* ``"ppermute"``  — a K-1 step neighbour chain; each hop forwards one
  boundary column (G·W floats) and folds it through the local ``T_k``
  matvec.  Lowest traffic, latency linear in K: right for small meshes.
* ``"allgather"`` — one log-depth all-gather of the compact ``(T_k,
  bl_k)`` pairs; every device then folds its own prefix locally with K
  cheap matvecs.  One collective round: right for larger meshes.
* ``"pair_allgather"`` — the fused opposite-direction pair shares ONE
  all-gather of both directions' stacked ``(T, b)`` states (LASP-2,
  arXiv 2502.07563).  Only meaningful for pair calls; see below.
* ``"auto"``      — per-direction calls: ppermute for K ≤ 4, allgather
  beyond; pair calls: pair_allgather.

A final correction pass propagates ``b_k`` homogeneously through the
block (3 FMAs/element — same shape as the local scan, no extra HBM
round-trip) and adds it to ``h_loc``.

Fused pair, single collective, compute/comm overlap.  The model path
dispatches opposite directions as ONE fused pair
(``ops.gspn_scan_pair`` / ``core.gspn._multi_directional_scan``), and
:func:`gspn_scan_sp_pair` runs that pair with a single boundary
collective instead of two independent exchanges.  The key enabler is
:func:`block_boundary_states`: one cheap affine operator scan carries
``(T, b)`` jointly, producing each direction's complete exchange payload
WITHOUT the full-width local scan.  Both payloads (plus the adjoint's
edge weight rows, which previously cost a separate single-row ppermute)
are stacked into one array and all-gathered; the expensive block-local
pair scan is issued AFTER the collective but consumes nothing from it,
so XLA's latency-hiding scheduler can overlap the exchange with the
local compute.  The ``custom_vjp`` backward is itself an opposite pair
(the fwd member's adjoint runs in reverse and vice versa) and reuses the
same machinery — one more fused collective, zero ppermutes.
``SPConfig.exchange_mode`` exposes the schedule for measurement:
``"overlap"`` (production), ``"serial"`` (an optimization_barrier pins
the gather before the local scan — the exposed-exchange baseline), and
``"skip"`` (no collective — the timing floor); ``benchmarks/sp_scaling``
reports overlap efficiency = hidden / exposed exchange time from the
three.

Backward.  ``gspn_scan_sp`` is a ``custom_vjp``: the adjoint of the scan
is the SAME block-parallel engine run in reverse — adjoint taps are the
next row's weights with left/right roles transposed
(``wl~ = shift_right(wr[i+1])``, ``wc~ = wc[i+1]``,
``wr~ = shift_left(wl[i+1])``), the boundary exchange direction flips
(last block is first in scan order), and one extra single-row ppermute
fetches the neighbour block's first weight row.  Parameter/input
gradients are then purely local, using the forward incoming boundary
(saved as a residual) as the cross-block previous row.

Non-divisible scan lengths are handled by zero-padding rows at the scan
*end* (zero taps/lam ⇒ padded rows carry exact zeros through both the
forward and adjoint recurrences) and slicing the pad off outside the
shard_map, so block shapes stay static and equal.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.kernels import gspn_scan as _pk
from repro.kernels import ref as _ref
from repro.kernels.spec import ScanSpec

STRATEGIES = ("auto", "ppermute", "allgather", "pair_allgather")

# How the fused-pair exchange is scheduled against the local scan.
EXCHANGE_MODES = ("overlap", "serial", "skip")

# auto strategy: neighbour chain while the latency term (K-1 hops) stays
# small, one-shot all-gather of (T, b) pairs beyond.
PPERMUTE_MAX_BLOCKS = 4


@dataclasses.dataclass(frozen=True)
class SPConfig:
    """Static (hashable) configuration of one sharded scan call.

    Everything the block-LOCAL launch needs (inner impl, channel mode,
    dtype policy, tile/pipeline, ``boundary="sp_block_local"``) lives in
    the embedded :class:`ScanSpec` — the same object handed to the fused
    kernel and through it to the autotuner, so the sp path shares the one
    spec-keyed tuning cache (DESIGN.md §11/§14).  SPConfig itself only
    adds the cross-device legs: mesh axis, block count, exchange strategy
    and wire dtype.
    """
    axis_name: str = "seq"
    n_blocks: int = 1
    strategy: str = "auto"
    # Wire dtype of the boundary exchange (DESIGN.md §10): the (T, b)
    # payloads are cast to this before every collective hop; the
    # associative composition itself always runs in f32.  bf16 halves the
    # exchanged bytes — the one cross-device traffic of the scan.  Stays
    # OUTSIDE the spec: it shapes the exchange, not the kernel launch.
    boundary_dtype: str = "float32"
    # Fused-pair exchange schedule (EXCHANGE_MODES).  "overlap" is
    # production: the collective is issued before the local scan and
    # nothing forces it to finish first.  "serial"/"skip" exist for the
    # sp_scaling overlap rung (exposed-exchange baseline / no-exchange
    # floor); "skip" produces WRONG cross-block values by construction.
    exchange_mode: str = "overlap"
    # Block-local launch spec (impl resolved to a concrete kernel,
    # boundary="sp_block_local").
    spec: ScanSpec = ScanSpec(impl="xla", boundary="sp_block_local")

    def resolved_strategy(self, *, pair: bool = False) -> str:
        """The concrete exchange strategy for this config.

        ``pair=True`` resolves for the fused opposite-direction pair:
        ``auto`` picks the single-collective ``pair_allgather`` there,
        while an explicit per-direction strategy (``ppermute`` /
        ``allgather``) is honoured as the fallback knob.  Per-direction
        calls degrade ``pair_allgather`` to ``allgather`` (the pair
        strategy has no single-direction form).
        """
        if self.strategy != "auto":
            if not pair and self.strategy == "pair_allgather":
                return "allgather"
            return self.strategy
        if pair:
            return "pair_allgather"
        return ("ppermute" if self.n_blocks <= PPERMUTE_MAX_BLOCKS
                else "allgather")

    # Compat views over the embedded spec.
    @property
    def inner_impl(self) -> str:
        return self.spec.impl

    @property
    def channels_per_weight(self) -> int:
        return self.spec.channels_per_weight

    @property
    def carry_dtype(self) -> str:
        return self.spec.carry_dtype


def _resolve_inner(inner_impl: str) -> str:
    if inner_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if inner_impl not in ("pallas", "xla"):
        raise ValueError(f"unknown inner impl {inner_impl!r}")
    return inner_impl


def _resolve_inner_pair(inner_impl: str) -> str:
    """Block-local impl for the fused pair: the bidirectional kernel on
    TPU, the XLA oracle elsewhere ("pallas" is accepted as an alias)."""
    if inner_impl in ("auto", "pallas"):
        return "multidir" if jax.default_backend() == "tpu" else "xla"
    if inner_impl not in ("multidir", "xla"):
        raise ValueError(f"unknown pair inner impl {inner_impl!r}")
    return inner_impl


def collectives_in_jaxpr(fn, *args):
    """[(primitive_name, invar_shape, invar_dtype)] for every collective
    in ``fn``'s jaxpr, recursing into sub-jaxprs (shard_map bodies,
    scans, custom_vjp calls).

    The one shared definition of "collectives per exchange": the sp tests
    pin counts with it and ``benchmarks/sp_scaling`` reports them from
    it, so the instrument cannot drift from the contract being tested.
    """
    kinds = ("all_gather", "psum", "ppermute", "all_to_all", "pgather",
             "reduce_scatter")
    found = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            nm = eqn.primitive.name
            if any(k in nm for k in kinds):
                v = eqn.invars[0].aval
                found.append((nm, tuple(v.shape), str(v.dtype)))
            for p in eqn.params.values():
                ps = p if isinstance(p, (list, tuple)) else [p]
                for j in ps:
                    if hasattr(j, "jaxpr"):
                        walk(j.jaxpr)
                    elif hasattr(j, "eqns"):
                        walk(j)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return found


# ---------------------------------------------------------------------------
# Block-local pieces: transfer operator, boundary propagation, local scan.
# ---------------------------------------------------------------------------

def _shift_rows_down(t):
    """t[..., j, :] -> t[..., j-1, :]; row 0 becomes 0."""
    pad = [(0, 0)] * (t.ndim - 2) + [(1, 0), (0, 0)]
    return jnp.pad(t, pad)[..., :-1, :]


def _shift_rows_up(t):
    """t[..., j, :] -> t[..., j+1, :]; last row becomes 0."""
    pad = [(0, 0)] * (t.ndim - 2) + [(0, 1), (0, 0)]
    return jnp.pad(t, pad)[..., 1:, :]


def block_transfer_operator(wl, wc, wr, *, reverse: bool = False):
    """T_k = ∏ M[r] over the block's rows, composed in scan order.

    wl/wc/wr: (G_w, H_blk, W).  Returns (G_w, W, W) f32 mapping the
    incoming boundary column to the outgoing one.  ``reverse=True``
    composes bottom→top (the reverse-direction scan's operator).
    """
    gw, _, w = wl.shape

    def body(t, row):
        wl_r, wc_r, wr_r = (a.astype(jnp.float32)[..., None] for a in row)
        # (M t)[j, c] = wl[j] t[j-1, c] + wc[j] t[j, c] + wr[j] t[j+1, c]
        t = wl_r * _shift_rows_down(t) + wc_r * t + wr_r * _shift_rows_up(t)
        return t, None

    eye = jnp.broadcast_to(jnp.eye(w, dtype=jnp.float32), (gw, w, w))
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (wl, wc, wr))
    t, _ = jax.lax.scan(body, eye, xs, reverse=reverse)
    return t


def block_boundary_states(x, wl, wc, wr, lam, *, reverse: bool = False):
    """The block's complete exchange payload ``(T_k, bl_k)`` in ONE cheap
    affine scan — no full-width local scan needed.

    The recurrence is linear in the carry, so the pair (operator, state)

        T ← M[r] T                      (the (W, W) transfer operator)
        b ← M[r] b + lam[r]·x[r]        (the zero-state local boundary)

    composes jointly row by row; after the block's rows, ``T = ∏ M[r]``
    equals :func:`block_transfer_operator` and ``b`` equals the local
    scan's outgoing boundary row (``h_loc[:, -1]``, or ``h_loc[:, 0]``
    for ``reverse=True``).  Computing the payload this way is what lets
    the fused-pair path ISSUE its collective before the expensive local
    scan runs (DESIGN.md §8).

    x, lam: (G, H_blk, W); taps (G_w, H_blk, W).  Returns
    ``(t (G_w, W, W) f32, b (G, W) f32)``.
    """
    gw, _, w = wl.shape
    g = x.shape[0]
    cpw = g // gw

    def body(carry, row):
        t, b = carry
        wl_r, wc_r, wr_r, u_r = row
        wl_m, wc_m, wr_m = (a[..., None] for a in (wl_r, wc_r, wr_r))
        t = wl_m * _shift_rows_down(t) + wc_m * t + wr_m * _shift_rows_up(t)
        bg = b.reshape(gw, cpw, w)
        wl_c, wc_c, wr_c = (a[:, None, :] for a in (wl_r, wc_r, wr_r))
        bg = (wl_c * _ref._shift_right(bg) + wc_c * bg
              + wr_c * _ref._shift_left(bg))
        b = bg.reshape(g, w) + u_r
        return (t, b), None

    eye = jnp.broadcast_to(jnp.eye(w, dtype=jnp.float32), (gw, w, w))
    zero = jnp.zeros((g, w), jnp.float32)
    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (wl, wc, wr)) + (
        jnp.moveaxis(lam.astype(jnp.float32) * x.astype(jnp.float32), 1, 0),)
    (t, b), _ = jax.lax.scan(body, (eye, zero), xs, reverse=reverse)
    return t, b


def _apply_transfer(t, b, cpw: int):
    """t: (G_w, W, W) acting on boundary columns b: (G, W), G = G_w·cpw."""
    gw = t.shape[0]
    bg = b.reshape(gw, cpw, b.shape[-1])
    return jnp.einsum("gjk,gck->gcj", t, bg).reshape(b.shape)


def propagate_boundary(b, wl, wc, wr, *, reverse: bool = False):
    """Carry a boundary column homogeneously through the block.

    b: (G, W); taps (G_w, H_blk, W).  Returns (G, H_blk, W) f32 where row
    i holds (∏_{entry..i} M[r]) b — exactly the correction each local row
    needs once the true incoming boundary is known.  Cost matches one
    local scan minus the lam·x term; no (W, W) operator is materialised.
    """
    g = b.shape[0]
    wl = _ref._broadcast_w(wl, g)
    wc = _ref._broadcast_w(wc, g)
    wr = _ref._broadcast_w(wr, g)

    def body(h, row):
        wl_r, wc_r, wr_r = row
        h = (wl_r * _ref._shift_right(h) + wc_r * h
             + wr_r * _ref._shift_left(h))
        return h, h

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (wl, wc, wr))
    _, cs = jax.lax.scan(body, b.astype(jnp.float32), xs, reverse=reverse)
    return jnp.moveaxis(cs, 0, 1)


def _local_scan(cfg: SPConfig, x, wl, wc, wr, lam, *, reverse: bool):
    """Block-local scan with zero incoming state (the existing kernels)."""
    if not reverse and cfg.spec.impl == "pallas":
        return _pk.gspn_scan_fwd_pallas(x, wl, wc, wr, lam, spec=cfg.spec)
    # Reverse-direction local scans (the adjoint pass) go through the XLA
    # fused-scan oracle — same recurrence, reversed row walk.
    return _ref.gspn_scan_ref(x, wl, wc, wr, lam, reverse=reverse)


# ---------------------------------------------------------------------------
# The single logical boundary exchange.
# ---------------------------------------------------------------------------

def _exchange(t, b_last, cfg: SPConfig, *, reverse: bool):
    """Compose block boundaries across the ``seq`` axis.

    t: (G_w, W, W) local transfer operator; b_last: (G, W) local
    uncorrected outgoing boundary.  Returns the corrected INCOMING
    boundary for this block — zeros for the first block in scan order.
    This is the only cross-device communication of the scan: one logical
    exchange of boundary columns (never full activations).
    """
    k, ax, cpw = cfg.n_blocks, cfg.axis_name, cfg.channels_per_weight
    zero = jnp.zeros_like(b_last, dtype=jnp.float32)
    if k == 1:
        return zero
    wire = jnp.dtype(cfg.boundary_dtype)
    b_last = b_last.astype(jnp.float32)
    idx = jax.lax.axis_index(ax)
    # Position in scan order: the reverse pass consumes blocks last→first.
    pos = (k - 1 - idx) if reverse else idx

    if cfg.resolved_strategy() == "ppermute":
        # Neighbour chain: K-1 hops, each forwarding one boundary column.
        # At hop s the block at scan position s-1 (whose incoming boundary
        # was finalised at hop s-1) sends its corrected outgoing boundary
        # T·b_in + b_last to position s; everyone else's payload is
        # ignored by the masked update.  The payload crosses the wire in
        # cfg.boundary_dtype; the fold stays f32 (DESIGN.md §10).
        perm = ([(i, i - 1) for i in range(1, k)] if reverse
                else [(i, i + 1) for i in range(k - 1)])
        b_in = zero
        for s in range(1, k):
            # Only scan position s-1's payload is consumed at hop s: mask
            # the rest to zero so every other device ships a constant
            # instead of a fresh T·b_in + b_last matvec, and a narrow wire
            # dtype only ever quantizes the chain actually consumed.
            send = jnp.where(pos == s - 1,
                             _apply_transfer(t, b_in, cpw) + b_last,
                             zero).astype(wire)
            recv = jax.lax.ppermute(send, ax, perm).astype(jnp.float32)
            b_in = jnp.where(pos == s, recv, b_in)
        return b_in

    # allgather: ONE log-depth collective of the compact (T, b) pairs;
    # each device then folds its own prefix with K cheap matvecs (the
    # composition (T_b, b_b)∘(T_a, b_a) = (T_b T_a, T_b b_a + b_b) applied
    # left-to-right in scan order — no (W, W) matmuls needed since only
    # the boundary column, not the composed operator, is consumed).  The
    # gathered (T, b) payloads cross the wire in cfg.boundary_dtype; the
    # prefix fold composes in f32.
    tg = jax.lax.all_gather(t.astype(wire), ax)   # (K, G_w, W, W) dev order
    bg = jax.lax.all_gather(b_last.astype(wire), ax)    # (K, G, W)
    if reverse:
        tg, bg = jnp.flip(tg, 0), jnp.flip(bg, 0)   # reorder to scan order

    def fold(acc, pair):
        tj, bj = pair
        nxt = _apply_transfer(tj.astype(jnp.float32), acc, cpw) \
            + bj.astype(jnp.float32)
        return nxt, nxt

    _, prefixes = jax.lax.scan(fold, zero, (tg, bg))
    # prefixes[p] is the incoming boundary of scan position p+1.
    prefixes = jnp.concatenate([zero[None], prefixes[:-1]], axis=0)
    return jnp.take(prefixes, pos, axis=0)


def _block_scan(cfg: SPConfig, x, wl, wc, wr, lam, *, reverse: bool):
    """One block-parallel scan pass (shard-local; collectives inside).

    Returns (h, b_in): globally-corrected outputs for the local rows
    (f32) and the corrected incoming boundary (f32, (G, W)).

    The four phases are wrapped in ``jax.named_scope`` so the XLA
    profiler timeline aligns with the span names (DESIGN.md §13).
    """
    with jax.named_scope("sp.local_scan"):
        h_loc = _local_scan(cfg, x, wl, wc, wr, lam,
                            reverse=reverse).astype(jnp.float32)
    b_last = h_loc[:, 0, :] if reverse else h_loc[:, -1, :]
    with jax.named_scope("sp.transfer_operator"):
        t = block_transfer_operator(wl, wc, wr, reverse=reverse)
    with jax.named_scope("sp.exchange"):
        b_in = _exchange(t, b_last, cfg, reverse=reverse)
    with jax.named_scope("sp.correction"):
        h = h_loc + propagate_boundary(b_in, wl, wc, wr, reverse=reverse)
    return h, b_in


# ---------------------------------------------------------------------------
# custom_vjp core (runs inside shard_map).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sp_core(cfg: SPConfig, x, wl, wc, wr, lam):
    h, _ = _block_scan(cfg, x, wl, wc, wr, lam, reverse=False)
    return h.astype(x.dtype)


def _sp_core_fwd(cfg, x, wl, wc, wr, lam):
    h, b_in = _block_scan(cfg, x, wl, wc, wr, lam, reverse=False)
    return h.astype(x.dtype), (x, wl, wc, wr, lam, h, b_in)


def _sp_core_bwd(cfg, res, dy):
    x, wl, wc, wr, lam, h, b_in = res            # h, b_in already f32
    k, ax = cfg.n_blocks, cfg.axis_name
    wl32, wc32, wr32 = (a.astype(jnp.float32) for a in (wl, wc, wr))

    # Adjoint taps at row i are row i+1's weights; the last local row's
    # successor lives on the right neighbour — fetch its first weight row
    # (one single-row ppermute; the exchange direction is reversed, as is
    # the boundary composition below).  The globally-last block receives
    # zeros: g[H-1] = dy[H-1].
    w_first = jnp.stack([wl32[:, 0], wc32[:, 0], wr32[:, 0]])
    if k > 1:
        w_first = jax.lax.ppermute(
            w_first, ax, [(i + 1, i) for i in range(k - 1)])
    else:
        w_first = jnp.zeros_like(w_first)

    def rows_next(a, first_next):
        return jnp.concatenate([a[:, 1:], first_next[:, None]], axis=1)

    wl_n = rows_next(wl32, w_first[0])
    wc_n = rows_next(wc32, w_first[1])
    wr_n = rows_next(wr32, w_first[2])
    # Transposed tridiagonal: g[i,j] = dy + wr[i+1,j-1]·g[i+1,j-1]
    #                + wc[i+1,j]·g[i+1,j] + wl[i+1,j+1]·g[i+1,j+1].
    wl_adj = _ref._shift_right(wr_n)
    wc_adj = wc_n
    wr_adj = _ref._shift_left(wl_n)

    dy32 = dy.astype(jnp.float32)
    g, _ = _block_scan(cfg, dy32, wl_adj, wc_adj, wr_adj,
                       jnp.ones_like(dy32), reverse=True)

    # Parameter/input grads are local given g and the previous-row states;
    # the block's first row reads the forward incoming boundary.
    h_prev = jnp.concatenate([b_in[:, None], h[:, :-1]], axis=1)
    dx = (lam.astype(jnp.float32) * g).astype(x.dtype)
    dlam = (x.astype(jnp.float32) * g).astype(lam.dtype)
    dwl = g * _ref._shift_right(h_prev)
    dwc = g * h_prev
    dwr = g * _ref._shift_left(h_prev)
    cpw = cfg.channels_per_weight
    if cpw > 1:
        gw = x.shape[0] // cpw
        shp = (gw, cpw) + dwl.shape[1:]
        dwl = dwl.reshape(shp).sum(axis=1)
        dwc = dwc.reshape(shp).sum(axis=1)
        dwr = dwr.reshape(shp).sum(axis=1)
    return (dx, dwl.astype(wl.dtype), dwc.astype(wc.dtype),
            dwr.astype(wr.dtype), dlam)


_sp_core.defvjp(_sp_core_fwd, _sp_core_bwd)


# ---------------------------------------------------------------------------
# Fused opposite-direction pair: ONE collective, compute/comm overlap.
# ---------------------------------------------------------------------------

def _pair_payload_parts(gw: int, g: int, w: int, *, with_edges: bool):
    """Row extents of the packed per-direction payload (P axis)."""
    return gw * w, g, (3 * gw if with_edges else 0)


def _issue_pair_exchange(cfg: SPConfig, t2, b2, edge2):
    """Pack both directions' compact states into ONE array and all-gather.

    t2: (2, G_w, W, W); b2: (2, G, W); edge2: (2, 3, G_w, W) adjoint edge
    weight rows (or None on the backward pass, which needs none).  The
    packed payload is (2, P, W) with P = G_w·W + G [+ 3·G_w]; it crosses
    the wire in ``cfg.boundary_dtype``.  Returns the gathered (K, 2, P,
    W) array, or None when the exchange is skipped (timing floor).
    """
    if cfg.exchange_mode == "skip":
        return None
    _, gw, w, _ = t2.shape
    parts = [t2.reshape(2, gw * w, w), b2]
    if edge2 is not None:
        parts.append(edge2.reshape(2, 3 * gw, w))
    payload = jnp.concatenate(parts, axis=1).astype(
        jnp.dtype(cfg.boundary_dtype))
    with jax.named_scope("sp.exchange"):
        return jax.lax.all_gather(payload, cfg.axis_name)


def _fold_pair_exchange(cfg: SPConfig, gathered, gw, g, w, *,
                        with_edges: bool):
    """Unpack the gathered pair payload and fold each direction's prefix.

    Slot 0 scans in device order (scan position = idx), slot 1 in
    reversed device order.  Returns ``b_in2`` (2, G, W) f32 — each
    direction's corrected incoming boundary — plus, when ``with_edges``,
    the adjoint edge weight rows: ``w_next0`` (3, G_w, W) = the RIGHT
    neighbour's first dir-0 rows and ``w_prev1`` = the LEFT neighbour's
    last dir-1 rows (zeros at the respective grid edges).
    """
    k, ax, cpw = cfg.n_blocks, cfg.axis_name, cfg.channels_per_weight
    zero = jnp.zeros((g, w), jnp.float32)
    if gathered is None:
        b_in2 = jnp.stack([zero, zero])
        if not with_edges:
            return b_in2
        ez = jnp.zeros((3, gw, w), jnp.float32)
        return b_in2, ez, ez
    f32 = gathered.astype(jnp.float32)             # (K, 2, P, W)
    tg = f32[:, :, :gw * w, :].reshape(k, 2, gw, w, w)
    bg = f32[:, :, gw * w:gw * w + g, :]
    idx = jax.lax.axis_index(ax)

    def prefix(ts, bs, pos):
        def fold(acc, pair):
            tj, bj = pair
            nxt = _apply_transfer(tj, acc, cpw) + bj
            return nxt, nxt
        _, pre = jax.lax.scan(fold, zero, (ts, bs))
        pre = jnp.concatenate([zero[None], pre[:-1]], axis=0)
        return jnp.take(pre, pos, axis=0)

    b_in2 = jnp.stack([
        prefix(tg[:, 0], bg[:, 0], idx),
        prefix(jnp.flip(tg[:, 1], 0), jnp.flip(bg[:, 1], 0), k - 1 - idx),
    ])
    if not with_edges:
        return b_in2
    eg = f32[:, :, gw * w + g:, :].reshape(k, 2, 3, gw, w)
    w_next0 = jnp.where(
        idx < k - 1, jnp.take(eg[:, 0], jnp.minimum(idx + 1, k - 1), axis=0),
        0.0)
    w_prev1 = jnp.where(
        idx > 0, jnp.take(eg[:, 1], jnp.maximum(idx - 1, 0), axis=0), 0.0)
    return b_in2, w_next0, w_prev1


def _local_scan_pair(cfg: SPConfig, x, wl2, wc2, wr2, lam2):
    """Block-local opposite-direction pair scan with zero incoming state."""
    if cfg.spec.impl == "multidir":
        from repro.kernels import gspn_multidir as _mk
        out = _mk.gspn_scan_bidir_pallas(
            x, {"wl": wl2, "wc": wc2, "wr": wr2}, lam2, spec=cfg.spec)
        return out.astype(jnp.float32)
    fwd = _ref.gspn_scan_ref(x, wl2[0], wc2[0], wr2[0], lam2[0])
    rev = _ref.gspn_scan_ref(x, wl2[1], wc2[1], wr2[1], lam2[1],
                             reverse=True)
    return jnp.stack([fwd, rev]).astype(jnp.float32)


def _pair_forward(cfg: SPConfig, x, wl2, wc2, wr2, lam2):
    """The fused-pair forward (shard-local).  Phase order is the point:

      1. ``sp.boundary_states`` — cheap affine (T, b) scans, BOTH
         directions, producing the full exchange payload;
      2. ``sp.exchange``        — the ONE all-gather, issued now;
      3. ``sp.local_scan``      — the expensive block-local pair scan,
         data-independent of the gather → overlaps it;
      4. ``sp.fold`` / ``sp.correction`` — the only consumers of the
         gathered bytes.

    Returns ``(h2 (2, G, H_blk, W) f32, b_in2, w_next0, w_prev1)``; the
    edge rows ride the same collective for the backward pass, replacing
    the per-direction path's extra single-row ppermute.
    """
    gw = wl2.shape[1]
    g, _, w = x.shape
    x32 = x.astype(jnp.float32)
    lam32 = lam2.astype(jnp.float32)
    wl2_, wc2_, wr2_ = (a.astype(jnp.float32) for a in (wl2, wc2, wr2))

    with jax.named_scope("sp.boundary_states"):
        t0, b0 = block_boundary_states(x32, wl2_[0], wc2_[0], wr2_[0],
                                       lam32[0])
        t1, b1 = block_boundary_states(x32, wl2_[1], wc2_[1], wr2_[1],
                                       lam32[1], reverse=True)
        edge2 = jnp.stack([
            jnp.stack([wl2_[0][:, 0], wc2_[0][:, 0], wr2_[0][:, 0]]),
            jnp.stack([wl2_[1][:, -1], wc2_[1][:, -1], wr2_[1][:, -1]]),
        ])
    gathered = _issue_pair_exchange(cfg, jnp.stack([t0, t1]),
                                    jnp.stack([b0, b1]), edge2)

    if cfg.exchange_mode == "serial" and gathered is not None:
        # Exposed-exchange baseline for the overlap rung: pin the gather
        # onto the critical path ahead of the local scan.
        gathered, x32 = jax.lax.optimization_barrier((gathered, x32))

    with jax.named_scope("sp.local_scan"):
        h_loc2 = _local_scan_pair(cfg, x32, wl2_, wc2_, wr2_, lam32)

    with jax.named_scope("sp.fold"):
        b_in2, w_next0, w_prev1 = _fold_pair_exchange(
            cfg, gathered, gw, g, w, with_edges=True)

    with jax.named_scope("sp.correction"):
        h2 = jnp.stack([
            h_loc2[0] + propagate_boundary(b_in2[0], wl2_[0], wc2_[0],
                                           wr2_[0]),
            h_loc2[1] + propagate_boundary(b_in2[1], wl2_[1], wc2_[1],
                                           wr2_[1], reverse=True),
        ])
    return h2, b_in2, w_next0, w_prev1


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sp_pair_core(cfg: SPConfig, x, wl2, wc2, wr2, lam2):
    h2, _, _, _ = _pair_forward(cfg, x, wl2, wc2, wr2, lam2)
    return h2.astype(x.dtype)


def _sp_pair_core_fwd(cfg, x, wl2, wc2, wr2, lam2):
    h2, b_in2, w_next0, w_prev1 = _pair_forward(cfg, x, wl2, wc2, wr2, lam2)
    return h2.astype(x.dtype), (x, wl2, wc2, wr2, lam2, h2, b_in2,
                                w_next0, w_prev1)


def _sp_pair_core_bwd(cfg, res, dy2):
    """Adjoint of the fused pair — itself an opposite pair, so it too is
    ONE fused exchange: dir 1's adjoint scans forward (fwd slot), dir 0's
    scans in reverse.  The neighbour edge weight rows arrived on the
    FORWARD's collective (residuals), so no ppermute remains anywhere."""
    x, wl2, wc2, wr2, lam2, h2, b_in2, w_next0, w_prev1 = res
    gw = wl2.shape[1]
    g, _, w = x.shape
    wl2_, wc2_, wr2_ = (a.astype(jnp.float32) for a in (wl2, wc2, wr2))
    dy32 = dy2.astype(jnp.float32)
    ones = jnp.ones_like(dy32[0])

    # Adjoint taps: the transposed tridiagonal of the NEXT row in each
    # direction's scan order — dir 0's row-(i+1) weights (successor of
    # the block's last row = right neighbour's first, w_next0), dir 1's
    # row-(i-1) weights (left neighbour's last, w_prev1).
    def rows_next(a, nxt):
        return jnp.concatenate([a[:, 1:], nxt[:, None]], axis=1)

    def rows_prev(a, prv):
        return jnp.concatenate([prv[:, None], a[:, :-1]], axis=1)

    wl0n, wc0n, wr0n = (rows_next(a, e) for a, e in
                        zip((wl2_[0], wc2_[0], wr2_[0]), w_next0))
    a0 = (_ref._shift_right(wr0n), wc0n, _ref._shift_left(wl0n))
    wl1p, wc1p, wr1p = (rows_prev(a, e) for a, e in
                        zip((wl2_[1], wc2_[1], wr2_[1]), w_prev1))
    a1 = (_ref._shift_right(wr1p), wc1p, _ref._shift_left(wl1p))

    with jax.named_scope("sp.bwd.boundary_states"):
        t1a, b1a = block_boundary_states(dy32[1], *a1, ones)
        t0a, b0a = block_boundary_states(dy32[0], *a0, ones, reverse=True)
    gathered = _issue_pair_exchange(cfg, jnp.stack([t1a, t0a]),
                                    jnp.stack([b1a, b0a]), None)
    with jax.named_scope("sp.bwd.local_scan"):
        g1 = _ref.gspn_scan_ref(dy32[1], *a1, ones)
        g0 = _ref.gspn_scan_ref(dy32[0], *a0, ones, reverse=True)
    with jax.named_scope("sp.bwd.fold"):
        g_in2 = _fold_pair_exchange(cfg, gathered, gw, g, w,
                                    with_edges=False)
    with jax.named_scope("sp.bwd.correction"):
        g1 = g1.astype(jnp.float32) + propagate_boundary(g_in2[0], *a1)
        g0 = g0.astype(jnp.float32) + propagate_boundary(g_in2[1], *a0,
                                                         reverse=True)

    # Param/input grads are local given g and the previous-row states;
    # each direction's first row (in its own scan order) reads the saved
    # forward incoming boundary.
    x32 = x.astype(jnp.float32)
    lam32 = lam2.astype(jnp.float32)
    g2 = jnp.stack([g0, g1])
    hp2 = jnp.stack([
        jnp.concatenate([b_in2[0][:, None], h2[0][:, :-1]], axis=1),
        jnp.concatenate([h2[1][:, 1:], b_in2[1][:, None]], axis=1),
    ])
    dx = (lam32[0] * g0 + lam32[1] * g1).astype(x.dtype)
    dlam2 = (x32[None] * g2).astype(lam2.dtype)
    dwl = g2 * _ref._shift_right(hp2)
    dwc = g2 * hp2
    dwr = g2 * _ref._shift_left(hp2)
    cpw = cfg.channels_per_weight
    if cpw > 1:
        shp = (2, g // cpw, cpw) + dwl.shape[2:]
        dwl = dwl.reshape(shp).sum(axis=2)
        dwc = dwc.reshape(shp).sum(axis=2)
        dwr = dwr.reshape(shp).sum(axis=2)
    return (dx, dwl.astype(wl2.dtype), dwc.astype(wc2.dtype),
            dwr.astype(wr2.dtype), dlam2)


_sp_pair_core.defvjp(_sp_pair_core_fwd, _sp_pair_core_bwd)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------

def gspn_scan_sp(x, wl, wc, wr, lam, *, spec: ScanSpec | None = None,
                 mesh=None, axis_name: str = "seq",
                 strategy: str = "auto", inner_impl: str = "auto",
                 row_tile: int | None = None, interpret: bool = True,
                 chunk: int | None = None, batch_axes=None,
                 boundary_dtype=None, carry_dtype=None,
                 pipeline_depth: int | None = None):
    """Spatially-sharded GSPN line scan (``impl="sp"``).

    Same semantics and layout as :func:`repro.kernels.ops.gspn_scan` —
    x, lam: (G, H, W); wl/wc/wr: (G_w, H, W) — but the scan dimension H is
    partitioned into contiguous blocks over the ``axis_name`` mesh axis.
    Launch policy arrives as one :class:`ScanSpec` (``spec=``); the
    legacy loose kwargs (``inner_impl``/``row_tile``/``interpret``/
    ``carry_dtype``/``pipeline_depth``) remain accepted when no spec is
    given and are folded into one.  The block-local launch runs under
    ``spec.with_(boundary="sp_block_local", impl=<resolved inner>)``.
    ``boundary_dtype`` (default f32) is the wire dtype of the boundary
    exchange payloads; composition always runs in f32 (DESIGN.md §10).
    The spec's carry dtype follows the active precision policy rather
    than a hard-coded f32 so the tuner keys the block-local launch
    correctly (DESIGN.md §11).
    Differentiable in all tensor args (custom_vjp; the backward pass
    reverses the exchange direction).  H need not divide the axis size.

    On meshes that also carry data-parallel axes, the G dim stays
    distributed over them (``batch_axes``, default: whichever of
    ``("pod", "data")`` the mesh has, when they divide G and G_w) — the
    scan is batch-parallel, so replicating G would force the partitioner
    to all-gather activations at every layer.

    Falls back to the single-device fused path when no mesh / no
    ``axis_name`` axis / axis size 1, and for GSPN-local chunked scans
    (``chunk`` resets the carry per segment, so the chunked fused path is
    already parallel over segments and exchanges no boundary state);
    ``impl="sp"`` is therefore safe to set unconditionally in configs,
    but combining it with ``chunk`` yields no cross-device memory saving.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown sp strategy {strategy!r}")
    if spec is None:
        spec = ScanSpec(
            impl=inner_impl, row_tile=row_tile, interpret=interpret,
            carry_dtype=str(jnp.dtype(carry_dtype if carry_dtype is not None
                                      else jnp.float32)),
            pipeline_depth=pipeline_depth)
    mesh = mesh if mesh is not None else compat.ambient_mesh()
    n_seq = (mesh.shape[axis_name]
             if mesh is not None and axis_name in mesh.axis_names else 1)
    if n_seq == 1 or chunk is not None:
        # GSPN-local chunking resets the carry at segment entry — there is
        # no cross-block state to exchange, so the chunked fused path is
        # already embarrassingly parallel and sp adds nothing to it.
        from repro.kernels.ops import gspn_scan
        return gspn_scan(x, wl, wc, wr, lam, chunk=chunk,
                         spec=spec.with_(impl="auto", boundary="one_shot"))

    g, h_dim, w = x.shape
    gw = wl.shape[0]
    assert g % gw == 0, (g, gw)
    h_blk = -(-h_dim // n_seq)
    pad = h_blk * n_seq - h_dim
    if pad:
        # Zero rows at the scan end: zero taps/lam keep them exactly zero
        # through forward and adjoint, and real boundaries never cross them.
        def pad_rows(a):
            return jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        x, wl, wc, wr, lam = map(pad_rows, (x, wl, wc, wr, lam))

    # ``impl="sp"`` at this layer means "the sp wrapper itself" — the
    # block-local kernel choice falls back to auto resolution.
    inner = _resolve_inner("auto" if spec.impl in ("auto", "sp")
                           else spec.impl)
    cfg = SPConfig(axis_name=axis_name, n_blocks=n_seq, strategy=strategy,
                   boundary_dtype=str(jnp.dtype(
                       boundary_dtype if boundary_dtype is not None
                       else jnp.float32)),
                   spec=spec.with_(direction="fwd", impl=inner,
                                   channels_per_weight=g // gw,
                                   stream_dtype=str(jnp.dtype(x.dtype)),
                                   boundary="sp_block_local"))
    # Traced-launch accounting of the one boundary exchange (DESIGN.md
    # §13): analytic per-scan byte counts, recorded once per jit TRACE of
    # this call site (jit caching means executed steps reuse the trace).
    # activation_bytes is what a naive full-activation collective would
    # move — the traffic the compact exchange avoids.
    wire_bytes = jnp.dtype(cfg.boundary_dtype).itemsize
    if cfg.resolved_strategy() == "ppermute":
        n_ops = n_seq - 1
        boundary_bytes = (n_seq - 1) * g * w * wire_bytes
    else:
        n_ops = 1
        boundary_bytes = n_seq * (gw * w * w + g * w) * wire_bytes
    act_bytes = x.size * jnp.dtype(x.dtype).itemsize
    obs.counter("sp_exchanges_total").inc()
    obs.counter("sp_collective_ops_total").inc(n_ops)
    obs.counter("sp_boundary_bytes_total").inc(boundary_bytes)
    obs.counter("sp_activation_bytes_total").inc(act_bytes)
    obs.event("sp.exchange", strategy=cfg.resolved_strategy(),
              n_blocks=n_seq, collective_ops=n_ops,
              boundary_bytes=boundary_bytes, activation_bytes=act_bytes,
              wire_dtype=cfg.boundary_dtype)
    # Shard G over dp only when both G and G_w divide: G is grouped
    # (G_w, cpw)-contiguously, and gw % bsize == 0 keeps every weight
    # group whole within its shard.
    bspec = _dp_batch_spec(mesh, batch_axes, axis_name, g, gw)
    pspec = P(bspec, axis_name, None)
    out = compat.shard_map(
        functools.partial(_sp_core, cfg), mesh=mesh,
        in_specs=(pspec,) * 5, out_specs=pspec,
    )(x, wl, wc, wr, lam)
    return out[:, :h_dim] if pad else out


def _dp_batch_spec(mesh, batch_axes, axis_name, g, gw):
    """The G-dim partition entry shared by both sp entry points."""
    if batch_axes is None:
        batch_axes = ("pod", "data")
    batch_axes = tuple(a for a in batch_axes
                       if a in mesh.axis_names and a != axis_name)
    bsize = 1
    for a in batch_axes:
        bsize *= mesh.shape[a]
    if bsize > 1 and g % bsize == 0 and gw % bsize == 0:
        return batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return None


def gspn_scan_sp_pair(x, wl2, wc2, wr2, lam2, *, spec: ScanSpec | None = None,
                      mesh=None, axis_name: str = "seq",
                      strategy: str = "auto", inner_impl: str = "auto",
                      row_tile: int | None = None, interpret: bool = True,
                      chunk: int | None = None, batch_axes=None,
                      boundary_dtype=None, carry_dtype=None,
                      pipeline_depth: int | None = None,
                      exchange_mode: str = "overlap"):
    """Spatially-sharded fused opposite-direction pair (``impl="sp"``).

    Layout matches :func:`repro.kernels.ops.gspn_scan_pair`: one shared
    stream ``x`` (G, H, W); per-direction taps ``wl2/wc2/wr2``
    (2, G_w, H, W) and ``lam2`` (2, G, H, W), slot 0 scanning top→bottom
    and slot 1 bottom→top.  Under the default/auto strategy the two
    directions share ONE boundary collective — a single all-gather of the
    stacked compact ``(T, b)`` states, issued before the block-local pair
    scan so the exchange overlaps the compute (module docstring; jaxpr
    pin: 1 collective forward, 2 in the gradient, 0 ppermutes).  Forcing
    ``strategy="ppermute"``/``"allgather"`` keeps the pre-fusion
    per-direction behaviour (two independent exchanges) as a fallback
    knob.  ``exchange_mode`` ∈ ``EXCHANGE_MODES`` is the overlap-rung
    measurement knob; anything but ``"overlap"`` is for benchmarking
    only.  Differentiable in all tensor args (custom_vjp; the backward is
    the mirrored pair with its own single fused exchange).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown sp strategy {strategy!r}")
    if exchange_mode not in EXCHANGE_MODES:
        raise ValueError(f"unknown sp exchange mode {exchange_mode!r}")
    if spec is None:
        spec = ScanSpec(
            impl=inner_impl, row_tile=row_tile, interpret=interpret,
            carry_dtype=str(jnp.dtype(carry_dtype if carry_dtype is not None
                                      else jnp.float32)),
            pipeline_depth=pipeline_depth)
    mesh = mesh if mesh is not None else compat.ambient_mesh()
    n_seq = (mesh.shape[axis_name]
             if mesh is not None and axis_name in mesh.axis_names else 1)
    if n_seq == 1 or chunk is not None:
        from repro.kernels.ops import gspn_scan_pair
        return gspn_scan_pair(
            x, wl2, wc2, wr2, lam2, chunk=chunk,
            spec=spec.with_(impl="auto", direction="pair_fwd",
                            boundary="one_shot"))

    if SPConfig(n_blocks=n_seq, strategy=strategy).resolved_strategy(
            pair=True) != "pair_allgather":
        # Per-direction fallback knob: two independent exchanges, exactly
        # the pre-fusion behaviour.  Slot 1 runs through the flip
        # identity (a reverse scan is a data reversal of a forward one).
        def flip(a):
            return jnp.flip(a, axis=-2)

        kw = dict(spec=spec, mesh=mesh, axis_name=axis_name,
                  strategy=strategy, batch_axes=batch_axes,
                  boundary_dtype=boundary_dtype)
        out0 = gspn_scan_sp(x, wl2[0], wc2[0], wr2[0], lam2[0], **kw)
        out1 = flip(gspn_scan_sp(flip(x), flip(wl2[1]), flip(wc2[1]),
                                 flip(wr2[1]), flip(lam2[1]), **kw))
        return jnp.stack([out0, out1])

    g, h_dim, w = x.shape
    gw = wl2.shape[1]
    assert g % gw == 0, (g, gw)
    h_blk = -(-h_dim // n_seq)
    pad = h_blk * n_seq - h_dim
    if pad:
        # Zero rows at the ARRAY end: zero taps/lam keep them exactly
        # zero in both directions (slot 1 enters through them with a
        # zero carry — the same state the unpadded scan starts from).
        def pad_rows(a):
            width = ((0, 0),) * (a.ndim - 2) + ((0, pad), (0, 0))
            return jnp.pad(a, width)
        x, wl2, wc2, wr2, lam2 = (pad_rows(a)
                                  for a in (x, wl2, wc2, wr2, lam2))

    inner = _resolve_inner_pair("auto" if spec.impl in ("auto", "sp")
                                else spec.impl)
    cfg = SPConfig(axis_name=axis_name, n_blocks=n_seq, strategy=strategy,
                   boundary_dtype=str(jnp.dtype(
                       boundary_dtype if boundary_dtype is not None
                       else jnp.float32)),
                   exchange_mode=exchange_mode,
                   spec=spec.with_(direction="pair_fwd", impl=inner,
                                   channels_per_weight=g // gw,
                                   stream_dtype=str(jnp.dtype(x.dtype)),
                                   boundary="sp_block_local"))
    wire_bytes = jnp.dtype(cfg.boundary_dtype).itemsize
    n_ops = 0 if exchange_mode == "skip" else 1
    payload_rows = sum(_pair_payload_parts(gw, g, w, with_edges=True))
    boundary_bytes = n_ops * n_seq * 2 * payload_rows * w * wire_bytes
    act_bytes = 2 * x.size * jnp.dtype(x.dtype).itemsize
    obs.counter("sp_exchanges_total").inc()
    obs.counter("sp_pair_fused_exchanges_total").inc()
    obs.counter("sp_collective_ops_total").inc(n_ops)
    obs.counter("sp_boundary_bytes_total").inc(boundary_bytes)
    obs.counter("sp_activation_bytes_total").inc(act_bytes)
    obs.event("sp.exchange", strategy="pair_allgather", fused_pair=True,
              n_blocks=n_seq, collective_ops=n_ops,
              boundary_bytes=boundary_bytes, activation_bytes=act_bytes,
              wire_dtype=cfg.boundary_dtype, exchange_mode=exchange_mode)

    bspec = _dp_batch_spec(mesh, batch_axes, axis_name, g, gw)
    pspec = P(bspec, axis_name, None)
    pspec2 = P(None, bspec, axis_name, None)
    out = compat.shard_map(
        functools.partial(_sp_pair_core, cfg), mesh=mesh,
        in_specs=(pspec, pspec2, pspec2, pspec2, pspec2), out_specs=pspec2,
    )(x, wl2, wc2, wr2, lam2)
    return out[:, :, :h_dim] if pad else out
