"""Spatial sequence parallelism for the GSPN line scan (DESIGN.md §8).

PR 1 fused the multi-direction dispatch, but every scan still ran on ONE
device — the mesh axes only sharded weights, so resolution / folded
sequence length were capped by a single chip's VMEM/HBM.  This module
shards the scan dimension itself across a ``seq`` mesh axis, following the
LASP/LASP-2 observation (arXiv 2404.02882, 2502.07563) that linear
recurrences admit sequence parallelism with a SINGLE compact boundary
exchange per scan instead of any full-activation collective.

Decomposition.  The canonical recurrence (top→bottom over rows, W lanes)

    h[i] = M[i] h[i-1] + lam[i]·x[i],   M[i] tridiagonal from (wl, wc, wr)

is linear in the carry, so partitioning rows into K contiguous blocks
(one per ``seq`` shard) gives, for block k with incoming boundary
``b_k = h[first_row_k - 1]``:

    h[i] = h_loc[i] + (∏_{r=first_k..i} M[r]) · b_k

where ``h_loc`` is the block-local scan with zero incoming state.  Each
device therefore computes, fully in parallel:

  1. ``h_loc``  — the existing fused kernel on its local rows;
  2. ``T_k = ∏_{r in block k} M[r]`` — the (W, W) *boundary transfer
     operator*, one per weight group (compact mode amortises it over
     ``channels_per_weight`` channels);
  3. its outgoing uncorrected boundary ``bl_k`` (last local row of
     ``h_loc``).

Boundary composition is associative —
``(T_b, b_b) ∘ (T_a, b_a) = (T_b T_a, T_b b_a + b_b)`` — so the corrected
incoming boundaries ``b_k`` compose across blocks with ONE logical
exchange.  Two strategies (``strategy=``):

* ``"ppermute"``  — a K-1 step neighbour chain; each hop forwards one
  boundary column (G·W floats) and folds it through the local ``T_k``
  matvec.  Lowest traffic, latency linear in K: right for small meshes.
* ``"allgather"`` — one log-depth all-gather of the compact ``(T_k,
  bl_k)`` pairs; every device then folds its own prefix locally with K
  cheap matvecs.  One collective round: right for larger meshes.
* ``"auto"``      — ppermute for K ≤ 4, allgather beyond.

A final correction pass propagates ``b_k`` homogeneously through the
block (3 FMAs/element — same shape as the local scan, no extra HBM
round-trip) and adds it to ``h_loc``.

Backward.  ``gspn_scan_sp`` is a ``custom_vjp``: the adjoint of the scan
is the SAME block-parallel engine run in reverse — adjoint taps are the
next row's weights with left/right roles transposed
(``wl~ = shift_right(wr[i+1])``, ``wc~ = wc[i+1]``,
``wr~ = shift_left(wl[i+1])``), the boundary exchange direction flips
(last block is first in scan order), and one extra single-row ppermute
fetches the neighbour block's first weight row.  Parameter/input
gradients are then purely local, using the forward incoming boundary
(saved as a residual) as the cross-block previous row.

Non-divisible scan lengths are handled by zero-padding rows at the scan
*end* (zero taps/lam ⇒ padded rows carry exact zeros through both the
forward and adjoint recurrences) and slicing the pad off outside the
shard_map, so block shapes stay static and equal.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.kernels import gspn_scan as _pk
from repro.kernels import ref as _ref
from repro.kernels.spec import ScanSpec

STRATEGIES = ("auto", "ppermute", "allgather")

# auto strategy: neighbour chain while the latency term (K-1 hops) stays
# small, one-shot all-gather of (T, b) pairs beyond.
PPERMUTE_MAX_BLOCKS = 4


@dataclasses.dataclass(frozen=True)
class SPConfig:
    """Static (hashable) configuration of one sharded scan call.

    Everything the block-LOCAL launch needs (inner impl, channel mode,
    dtype policy, tile/pipeline, ``boundary="sp_block_local"``) lives in
    the embedded :class:`ScanSpec` — the same object handed to the fused
    kernel and through it to the autotuner, so the sp path shares the one
    spec-keyed tuning cache (DESIGN.md §11/§14).  SPConfig itself only
    adds the cross-device legs: mesh axis, block count, exchange strategy
    and wire dtype.
    """
    axis_name: str = "seq"
    n_blocks: int = 1
    strategy: str = "auto"
    # Wire dtype of the boundary exchange (DESIGN.md §10): the (T, b)
    # payloads are cast to this before every collective hop; the
    # associative composition itself always runs in f32.  bf16 halves the
    # exchanged bytes — the one cross-device traffic of the scan.  Stays
    # OUTSIDE the spec: it shapes the exchange, not the kernel launch.
    boundary_dtype: str = "float32"
    # Block-local launch spec (impl resolved to a concrete kernel,
    # boundary="sp_block_local").
    spec: ScanSpec = ScanSpec(impl="xla", boundary="sp_block_local")

    def resolved_strategy(self) -> str:
        if self.strategy != "auto":
            return self.strategy
        return ("ppermute" if self.n_blocks <= PPERMUTE_MAX_BLOCKS
                else "allgather")

    # Compat views over the embedded spec.
    @property
    def inner_impl(self) -> str:
        return self.spec.impl

    @property
    def channels_per_weight(self) -> int:
        return self.spec.channels_per_weight

    @property
    def carry_dtype(self) -> str:
        return self.spec.carry_dtype


def _resolve_inner(inner_impl: str) -> str:
    if inner_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if inner_impl not in ("pallas", "xla"):
        raise ValueError(f"unknown inner impl {inner_impl!r}")
    return inner_impl


# ---------------------------------------------------------------------------
# Block-local pieces: transfer operator, boundary propagation, local scan.
# ---------------------------------------------------------------------------

def _shift_rows_down(t):
    """t[..., j, :] -> t[..., j-1, :]; row 0 becomes 0."""
    pad = [(0, 0)] * (t.ndim - 2) + [(1, 0), (0, 0)]
    return jnp.pad(t, pad)[..., :-1, :]


def _shift_rows_up(t):
    """t[..., j, :] -> t[..., j+1, :]; last row becomes 0."""
    pad = [(0, 0)] * (t.ndim - 2) + [(0, 1), (0, 0)]
    return jnp.pad(t, pad)[..., 1:, :]


def block_transfer_operator(wl, wc, wr, *, reverse: bool = False):
    """T_k = ∏ M[r] over the block's rows, composed in scan order.

    wl/wc/wr: (G_w, H_blk, W).  Returns (G_w, W, W) f32 mapping the
    incoming boundary column to the outgoing one.  ``reverse=True``
    composes bottom→top (the reverse-direction scan's operator).
    """
    gw, _, w = wl.shape

    def body(t, row):
        wl_r, wc_r, wr_r = (a.astype(jnp.float32)[..., None] for a in row)
        # (M t)[j, c] = wl[j] t[j-1, c] + wc[j] t[j, c] + wr[j] t[j+1, c]
        t = wl_r * _shift_rows_down(t) + wc_r * t + wr_r * _shift_rows_up(t)
        return t, None

    eye = jnp.broadcast_to(jnp.eye(w, dtype=jnp.float32), (gw, w, w))
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (wl, wc, wr))
    t, _ = jax.lax.scan(body, eye, xs, reverse=reverse)
    return t


def _apply_transfer(t, b, cpw: int):
    """t: (G_w, W, W) acting on boundary columns b: (G, W), G = G_w·cpw."""
    gw = t.shape[0]
    bg = b.reshape(gw, cpw, b.shape[-1])
    return jnp.einsum("gjk,gck->gcj", t, bg).reshape(b.shape)


def propagate_boundary(b, wl, wc, wr, *, reverse: bool = False):
    """Carry a boundary column homogeneously through the block.

    b: (G, W); taps (G_w, H_blk, W).  Returns (G, H_blk, W) f32 where row
    i holds (∏_{entry..i} M[r]) b — exactly the correction each local row
    needs once the true incoming boundary is known.  Cost matches one
    local scan minus the lam·x term; no (W, W) operator is materialised.
    """
    g = b.shape[0]
    wl = _ref._broadcast_w(wl, g)
    wc = _ref._broadcast_w(wc, g)
    wr = _ref._broadcast_w(wr, g)

    def body(h, row):
        wl_r, wc_r, wr_r = row
        h = (wl_r * _ref._shift_right(h) + wc_r * h
             + wr_r * _ref._shift_left(h))
        return h, h

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (wl, wc, wr))
    _, cs = jax.lax.scan(body, b.astype(jnp.float32), xs, reverse=reverse)
    return jnp.moveaxis(cs, 0, 1)


def _local_scan(cfg: SPConfig, x, wl, wc, wr, lam, *, reverse: bool):
    """Block-local scan with zero incoming state (the existing kernels)."""
    if not reverse and cfg.spec.impl == "pallas":
        return _pk.gspn_scan_fwd_pallas(x, wl, wc, wr, lam, spec=cfg.spec)
    # Reverse-direction local scans (the adjoint pass) go through the XLA
    # fused-scan oracle — same recurrence, reversed row walk.
    return _ref.gspn_scan_ref(x, wl, wc, wr, lam, reverse=reverse)


# ---------------------------------------------------------------------------
# The single logical boundary exchange.
# ---------------------------------------------------------------------------

def _exchange(t, b_last, cfg: SPConfig, *, reverse: bool):
    """Compose block boundaries across the ``seq`` axis.

    t: (G_w, W, W) local transfer operator; b_last: (G, W) local
    uncorrected outgoing boundary.  Returns the corrected INCOMING
    boundary for this block — zeros for the first block in scan order.
    This is the only cross-device communication of the scan: one logical
    exchange of boundary columns (never full activations).
    """
    k, ax, cpw = cfg.n_blocks, cfg.axis_name, cfg.channels_per_weight
    zero = jnp.zeros_like(b_last, dtype=jnp.float32)
    if k == 1:
        return zero
    wire = jnp.dtype(cfg.boundary_dtype)
    b_last = b_last.astype(jnp.float32)
    idx = jax.lax.axis_index(ax)
    # Position in scan order: the reverse pass consumes blocks last→first.
    pos = (k - 1 - idx) if reverse else idx

    if cfg.resolved_strategy() == "ppermute":
        # Neighbour chain: K-1 hops, each forwarding one boundary column.
        # At hop s the block at scan position s-1 (whose incoming boundary
        # was finalised at hop s-1) sends its corrected outgoing boundary
        # T·b_in + b_last to position s; everyone else's payload is
        # ignored by the masked update.  The payload crosses the wire in
        # cfg.boundary_dtype; the fold stays f32 (DESIGN.md §10).
        perm = ([(i, i - 1) for i in range(1, k)] if reverse
                else [(i, i + 1) for i in range(k - 1)])
        b_in = zero
        for s in range(1, k):
            send = (_apply_transfer(t, b_in, cpw) + b_last).astype(wire)
            recv = jax.lax.ppermute(send, ax, perm).astype(jnp.float32)
            b_in = jnp.where(pos == s, recv, b_in)
        return b_in

    # allgather: ONE log-depth collective of the compact (T, b) pairs;
    # each device then folds its own prefix with K cheap matvecs (the
    # composition (T_b, b_b)∘(T_a, b_a) = (T_b T_a, T_b b_a + b_b) applied
    # left-to-right in scan order — no (W, W) matmuls needed since only
    # the boundary column, not the composed operator, is consumed).  The
    # gathered (T, b) payloads cross the wire in cfg.boundary_dtype; the
    # prefix fold composes in f32.
    tg = jax.lax.all_gather(t.astype(wire), ax)   # (K, G_w, W, W) dev order
    bg = jax.lax.all_gather(b_last.astype(wire), ax)    # (K, G, W)
    if reverse:
        tg, bg = jnp.flip(tg, 0), jnp.flip(bg, 0)   # reorder to scan order

    def fold(acc, pair):
        tj, bj = pair
        nxt = _apply_transfer(tj.astype(jnp.float32), acc, cpw) \
            + bj.astype(jnp.float32)
        return nxt, nxt

    _, prefixes = jax.lax.scan(fold, zero, (tg, bg))
    # prefixes[p] is the incoming boundary of scan position p+1.
    prefixes = jnp.concatenate([zero[None], prefixes[:-1]], axis=0)
    return jnp.take(prefixes, pos, axis=0)


def _block_scan(cfg: SPConfig, x, wl, wc, wr, lam, *, reverse: bool):
    """One block-parallel scan pass (shard-local; collectives inside).

    Returns (h, b_in): globally-corrected outputs for the local rows
    (f32) and the corrected incoming boundary (f32, (G, W)).

    The four phases are wrapped in ``jax.named_scope`` so the XLA
    profiler timeline aligns with the span names (DESIGN.md §13).
    """
    with jax.named_scope("sp.local_scan"):
        h_loc = _local_scan(cfg, x, wl, wc, wr, lam,
                            reverse=reverse).astype(jnp.float32)
    b_last = h_loc[:, 0, :] if reverse else h_loc[:, -1, :]
    with jax.named_scope("sp.transfer_operator"):
        t = block_transfer_operator(wl, wc, wr, reverse=reverse)
    with jax.named_scope("sp.exchange"):
        b_in = _exchange(t, b_last, cfg, reverse=reverse)
    with jax.named_scope("sp.correction"):
        h = h_loc + propagate_boundary(b_in, wl, wc, wr, reverse=reverse)
    return h, b_in


# ---------------------------------------------------------------------------
# custom_vjp core (runs inside shard_map).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sp_core(cfg: SPConfig, x, wl, wc, wr, lam):
    h, _ = _block_scan(cfg, x, wl, wc, wr, lam, reverse=False)
    return h.astype(x.dtype)


def _sp_core_fwd(cfg, x, wl, wc, wr, lam):
    h, b_in = _block_scan(cfg, x, wl, wc, wr, lam, reverse=False)
    return h.astype(x.dtype), (x, wl, wc, wr, lam, h, b_in)


def _sp_core_bwd(cfg, res, dy):
    x, wl, wc, wr, lam, h, b_in = res            # h, b_in already f32
    k, ax = cfg.n_blocks, cfg.axis_name
    wl32, wc32, wr32 = (a.astype(jnp.float32) for a in (wl, wc, wr))

    # Adjoint taps at row i are row i+1's weights; the last local row's
    # successor lives on the right neighbour — fetch its first weight row
    # (one single-row ppermute; the exchange direction is reversed, as is
    # the boundary composition below).  The globally-last block receives
    # zeros: g[H-1] = dy[H-1].
    w_first = jnp.stack([wl32[:, 0], wc32[:, 0], wr32[:, 0]])
    if k > 1:
        w_first = jax.lax.ppermute(
            w_first, ax, [(i + 1, i) for i in range(k - 1)])
    else:
        w_first = jnp.zeros_like(w_first)

    def rows_next(a, first_next):
        return jnp.concatenate([a[:, 1:], first_next[:, None]], axis=1)

    wl_n = rows_next(wl32, w_first[0])
    wc_n = rows_next(wc32, w_first[1])
    wr_n = rows_next(wr32, w_first[2])
    # Transposed tridiagonal: g[i,j] = dy + wr[i+1,j-1]·g[i+1,j-1]
    #                + wc[i+1,j]·g[i+1,j] + wl[i+1,j+1]·g[i+1,j+1].
    wl_adj = _ref._shift_right(wr_n)
    wc_adj = wc_n
    wr_adj = _ref._shift_left(wl_n)

    dy32 = dy.astype(jnp.float32)
    g, _ = _block_scan(cfg, dy32, wl_adj, wc_adj, wr_adj,
                       jnp.ones_like(dy32), reverse=True)

    # Parameter/input grads are local given g and the previous-row states;
    # the block's first row reads the forward incoming boundary.
    h_prev = jnp.concatenate([b_in[:, None], h[:, :-1]], axis=1)
    dx = (lam.astype(jnp.float32) * g).astype(x.dtype)
    dlam = (x.astype(jnp.float32) * g).astype(lam.dtype)
    dwl = g * _ref._shift_right(h_prev)
    dwc = g * h_prev
    dwr = g * _ref._shift_left(h_prev)
    cpw = cfg.channels_per_weight
    if cpw > 1:
        gw = x.shape[0] // cpw
        shp = (gw, cpw) + dwl.shape[1:]
        dwl = dwl.reshape(shp).sum(axis=1)
        dwc = dwc.reshape(shp).sum(axis=1)
        dwr = dwr.reshape(shp).sum(axis=1)
    return (dx, dwl.astype(wl.dtype), dwc.astype(wc.dtype),
            dwr.astype(wr.dtype), dlam)


_sp_core.defvjp(_sp_core_fwd, _sp_core_bwd)


# ---------------------------------------------------------------------------
# Public entry point.
# ---------------------------------------------------------------------------

def gspn_scan_sp(x, wl, wc, wr, lam, *, spec: ScanSpec | None = None,
                 mesh=None, axis_name: str = "seq",
                 strategy: str = "auto", inner_impl: str = "auto",
                 row_tile: int | None = None, interpret: bool = True,
                 chunk: int | None = None, batch_axes=None,
                 boundary_dtype=None, carry_dtype=None,
                 pipeline_depth: int | None = None):
    """Spatially-sharded GSPN line scan (``impl="sp"``).

    Same semantics and layout as :func:`repro.kernels.ops.gspn_scan` —
    x, lam: (G, H, W); wl/wc/wr: (G_w, H, W) — but the scan dimension H is
    partitioned into contiguous blocks over the ``axis_name`` mesh axis.
    Launch policy arrives as one :class:`ScanSpec` (``spec=``); the
    legacy loose kwargs (``inner_impl``/``row_tile``/``interpret``/
    ``carry_dtype``/``pipeline_depth``) remain accepted when no spec is
    given and are folded into one.  The block-local launch runs under
    ``spec.with_(boundary="sp_block_local", impl=<resolved inner>)``.
    ``boundary_dtype`` (default f32) is the wire dtype of the boundary
    exchange payloads; composition always runs in f32 (DESIGN.md §10).
    The spec's carry dtype follows the active precision policy rather
    than a hard-coded f32 so the tuner keys the block-local launch
    correctly (DESIGN.md §11).
    Differentiable in all tensor args (custom_vjp; the backward pass
    reverses the exchange direction).  H need not divide the axis size.

    On meshes that also carry data-parallel axes, the G dim stays
    distributed over them (``batch_axes``, default: whichever of
    ``("pod", "data")`` the mesh has, when they divide G and G_w) — the
    scan is batch-parallel, so replicating G would force the partitioner
    to all-gather activations at every layer.

    Falls back to the single-device fused path when no mesh / no
    ``axis_name`` axis / axis size 1, and for GSPN-local chunked scans
    (``chunk`` resets the carry per segment, so the chunked fused path is
    already parallel over segments and exchanges no boundary state);
    ``impl="sp"`` is therefore safe to set unconditionally in configs,
    but combining it with ``chunk`` yields no cross-device memory saving.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown sp strategy {strategy!r}")
    if spec is None:
        spec = ScanSpec(
            impl=inner_impl, row_tile=row_tile, interpret=interpret,
            carry_dtype=str(jnp.dtype(carry_dtype if carry_dtype is not None
                                      else jnp.float32)),
            pipeline_depth=pipeline_depth)
    mesh = mesh if mesh is not None else compat.ambient_mesh()
    n_seq = (mesh.shape[axis_name]
             if mesh is not None and axis_name in mesh.axis_names else 1)
    if n_seq == 1 or chunk is not None:
        # GSPN-local chunking resets the carry at segment entry — there is
        # no cross-block state to exchange, so the chunked fused path is
        # already embarrassingly parallel and sp adds nothing to it.
        from repro.kernels.ops import gspn_scan
        return gspn_scan(x, wl, wc, wr, lam, chunk=chunk,
                         spec=spec.with_(impl="auto", boundary="one_shot"))

    g, h_dim, w = x.shape
    gw = wl.shape[0]
    assert g % gw == 0, (g, gw)
    h_blk = -(-h_dim // n_seq)
    pad = h_blk * n_seq - h_dim
    if pad:
        # Zero rows at the scan end: zero taps/lam keep them exactly zero
        # through forward and adjoint, and real boundaries never cross them.
        def pad_rows(a):
            return jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        x, wl, wc, wr, lam = map(pad_rows, (x, wl, wc, wr, lam))

    # ``impl="sp"`` at this layer means "the sp wrapper itself" — the
    # block-local kernel choice falls back to auto resolution.
    inner = _resolve_inner("auto" if spec.impl in ("auto", "sp")
                           else spec.impl)
    cfg = SPConfig(axis_name=axis_name, n_blocks=n_seq, strategy=strategy,
                   boundary_dtype=str(jnp.dtype(
                       boundary_dtype if boundary_dtype is not None
                       else jnp.float32)),
                   spec=spec.with_(direction="fwd", impl=inner,
                                   channels_per_weight=g // gw,
                                   stream_dtype=str(jnp.dtype(x.dtype)),
                                   boundary="sp_block_local"))
    # Traced-launch accounting of the one boundary exchange (DESIGN.md
    # §13): analytic per-scan byte counts, recorded once per jit TRACE of
    # this call site (jit caching means executed steps reuse the trace).
    # activation_bytes is what a naive full-activation collective would
    # move — the traffic the compact exchange avoids.
    wire_bytes = jnp.dtype(cfg.boundary_dtype).itemsize
    if cfg.resolved_strategy() == "ppermute":
        n_ops = n_seq - 1
        boundary_bytes = (n_seq - 1) * g * w * wire_bytes
    else:
        n_ops = 1
        boundary_bytes = n_seq * (gw * w * w + g * w) * wire_bytes
    act_bytes = x.size * jnp.dtype(x.dtype).itemsize
    obs.counter("sp_exchanges_total").inc()
    obs.counter("sp_collective_ops_total").inc(n_ops)
    obs.counter("sp_boundary_bytes_total").inc(boundary_bytes)
    obs.counter("sp_activation_bytes_total").inc(act_bytes)
    obs.event("sp.exchange", strategy=cfg.resolved_strategy(),
              n_blocks=n_seq, collective_ops=n_ops,
              boundary_bytes=boundary_bytes, activation_bytes=act_bytes,
              wire_dtype=cfg.boundary_dtype)
    if batch_axes is None:
        batch_axes = ("pod", "data")
    batch_axes = tuple(a for a in batch_axes
                       if a in mesh.axis_names and a != axis_name)
    bsize = 1
    for a in batch_axes:
        bsize *= mesh.shape[a]
    # Shard G over dp only when both G and G_w divide: G is grouped
    # (G_w, cpw)-contiguously, and gw % bsize == 0 keeps every weight
    # group whole within its shard.
    bspec = None
    if bsize > 1 and g % bsize == 0 and gw % bsize == 0:
        bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    pspec = P(bspec, axis_name, None)
    out = compat.shard_map(
        functools.partial(_sp_core, cfg), mesh=mesh,
        in_specs=(pspec,) * 5, out_specs=pspec,
    )(x, wl, wc, wr, lam)
    return out[:, :h_dim] if pad else out
