"""Pipeline parallelism: GPipe-style microbatched stage executor over a
mesh axis, built on shard_map + collective_permute.

Each pipeline shard holds the weights of one *stage* (a contiguous slice
of layers).  The schedule runs ``n_micro + n_stages - 1`` ticks; at every
tick each shard processes one microbatch and forwards its activation to
the next shard with ``collective_permute`` (ring shift).  Bubble fraction
is the standard (S-1)/(M+S-1).

This executor is an optional alternative to the default DP×TP layout for
memory-bound depth scaling; it is validated in tests/test_pipeline.py on a
fake 4-device mesh and is wired as ``--pipeline`` in the launcher.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def pipeline_apply(mesh, axis: str, stage_fn: Callable,
                   stage_params, x_micro, n_micro: int):
    """Run a pipeline over mesh axis ``axis``.

    stage_fn(params, x) -> x       one stage's forward
    stage_params: pytree whose leaves have leading dim n_stages (sharded
                  over ``axis``).
    x_micro: (n_micro, mb, ...) microbatched input (replicated).
    Returns (n_micro, mb, ...) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]
    assert n_micro >= 1

    def body(params_local, xm):
        # params_local leaves: (1, ...) -> squeeze stage dim
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage_idx = jax.lax.axis_index(axis)
        mb_shape = xm.shape[1:]
        ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (if in range) else zeros
            inject = jnp.where(
                t < n_micro,
                xm[jnp.clip(t, 0, n_micro - 1)],
                jnp.zeros(mb_shape, xm.dtype))
            cur = jnp.where(stage_idx == 0, inject, buf)
            out = stage_fn(params_local, cur)
            # last stage writes microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            outputs = jax.lax.cond(
                (out_idx >= 0) & (stage_idx == n_stages - 1),
                lambda o: o.at[jnp.clip(out_idx, 0, n_micro - 1)].set(out),
                lambda o: o,
                outputs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(out, axis, perm)
            return (buf, outputs), None

        buf0 = jnp.zeros(mb_shape, xm.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, xm.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (buf0, outs0),
                                       jnp.arange(ticks))
        # broadcast final outputs from the last stage to all shards
        outputs = jax.lax.psum(
            jnp.where(stage_idx == n_stages - 1, outputs, 0.0), axis)
        return outputs

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x_micro)
