"""Distributed-optimization collectives: int8 error-feedback gradient
compression and hierarchical cross-pod reduction.

``compressed_allreduce`` implements the classic error-feedback scheme
(1-bit/int8 SGD lineage): each shard quantises ``g + e`` to int8 with a
per-tensor scale, psums the int8 payload (8× less DCN traffic than f32,
4x less than bf16), dequantises, and keeps the quantisation residual in
``e`` for the next step.  Convergence-safe because the residual is
re-injected (error feedback), unlike plain stochastic rounding.

``hierarchical_grad_reduce`` composes: reduce-scatter inside the pod
(cheap ICI) → compressed all-reduce across pods (expensive DCN) →
all-gather inside the pod.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def quantize_int8(x):
    """Per-tensor symmetric int8 quantisation.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _compress_one(g, e, axis_name):
    gf = g.astype(jnp.float32) + e
    # Shared scale across shards (one scalar all-reduce) so the int32 psum
    # of payloads reconstructs the exact sum of quantised values.
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    # All-zero gradient across every shard (frozen params, masked losses,
    # loss-scale underflow): dividing by a denormal-floored scale amplifies
    # by ~1e14 and a zero scale would NaN the dequantise.  Pin the scale to
    # a safe constant instead — q, psum, and the residual are then exact
    # zeros.
    scale = jnp.where(amax > 0.0, amax, 1.0) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    g_hat = q_sum.astype(jnp.float32) * scale / n         # mean gradient
    new_e = gf - q.astype(jnp.float32) * scale            # local residual
    return g_hat.astype(g.dtype), new_e


def compressed_psum_tree(grads, errors, axis_name: str):
    """Apply int8 error-feedback mean-allreduce over ``axis_name`` to every
    leaf.  Must run inside shard_map with ``axis_name`` manual.
    Returns (reduced_grads, new_errors)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(errors)[0]
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        gh, ne = _compress_one(g, e, axis_name)
        out_g.append(gh)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_state(grads_shape):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)


def compressed_allreduce(mesh, axis_name: str):
    """Build a shard_map'd compressed all-reduce over one mesh axis.

    Returned fn: (grads, errors) -> (mean_grads, new_errors).  Arrays are
    assumed replicated over ``axis_name`` is NOT required — each shard
    holds its local contribution; output is the compressed mean.
    """
    def fn(grads, errors):
        # Leaves carry a leading per-shard dim (axis size); each shard's
        # slice is its local gradient.  Callers already inside a shard_map
        # should use compressed_psum_tree directly instead.
        def body(g, e):
            g = jax.tree.map(lambda a: a[0], g)     # drop local shard dim
            e = jax.tree.map(lambda a: a[0], e)
            gh, ne = compressed_psum_tree(g, e, axis_name)
            return gh, jax.tree.map(lambda a: a[None], ne)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(), P(axis_name)),
        )(grads, errors)

    return fn
