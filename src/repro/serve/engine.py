"""Batched serving engine: prefill + decode with continuous batching.

Slots model vLLM-style continuous batching at request granularity: the
engine keeps ``batch_size`` decode slots; finished slots are immediately
refilled from the waiting queue via a single-prompt prefill whose caches
are scattered into the slot (``update_cache_slots``).  The decode step for
the whole batch is one jitted function, so throughput is independent of
request mix.

Works for every architecture family — caches are whatever the block kinds
define (KV for attention, SSM states for Mamba/xLSTM, the O(√L) row cache
for the GSPN mixer).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm as lm_mod


def update_cache_slots(cfg, caches, new_caches, slots):
    """Scatter ``new_caches`` (batch = len(slots)) into ``caches`` at the
    given slot indices.  Batch-axis position depends on the stage kind:
    prelude/shared stages stack (n, B, ...), unit stages (n_units, n, B...)."""
    slots = jnp.asarray(slots, jnp.int32)

    def upd(axis):
        def f(big, new):
            bigm = jnp.moveaxis(big, axis, 0)
            newm = jnp.moveaxis(new, axis, 0)
            return jnp.moveaxis(bigm.at[slots].set(newm.astype(bigm.dtype)),
                                0, axis)
        return f

    prelude_keys = {f"s{si}_{kind}" for si, (w, kind, n)
                    in enumerate(cfg.stages()) if w == "prelude"}
    out = {}
    for key, sub in caches.items():
        if key in prelude_keys or key == "shared_attn":
            axis = 1
        else:
            axis = 2
        out[key] = jax.tree.map(upd(axis), sub, new_caches[key])
    return out


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list


class ServeEngine:
    def __init__(self, params, cfg, *, batch_size: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 top_k: int = 0, eos_id: Optional[int] = None,
                 seed: int = 0, ctx=None):
        self.params = params
        self.cfg = cfg
        self.bs = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.eos_id = eos_id
        self.ctx = ctx or lm_mod.Ctx()
        self.rng = jax.random.PRNGKey(seed)

        self.caches = lm_mod.init_lm_cache(cfg, batch_size, max_len)
        self.queue: deque = deque()
        self.slot_req = [None] * batch_size          # type: list
        self.slot_tokens: list = [[] for _ in range(batch_size)]
        self.last_token = jnp.zeros((batch_size, 1), jnp.int32)
        self.active = np.zeros((batch_size,), bool)
        self.results: dict = {}

        self._prefill = jax.jit(
            lambda p, toks: lm_mod.lm_prefill(p, cfg, toks, max_len,
                                              ctx=self.ctx)[:2])
        self._decode = jax.jit(self._decode_fn)

    # -- jitted decode+sample --------------------------------------------
    def _decode_fn(self, params, token, caches, rng):
        logits, new_caches = lm_mod.lm_decode_step(params, self.cfg, token,
                                                   caches, ctx=self.ctx)
        logits = logits[:, 0].astype(jnp.float32)
        if self.temperature <= 0.0:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            logits = logits / self.temperature
            if self.top_k:
                vals, _ = jax.lax.top_k(logits, self.top_k)
                thresh = vals[:, -1:]
                logits = jnp.where(logits < thresh, -1e30, logits)
            nxt = jax.random.categorical(rng, logits, axis=-1)
        return nxt.astype(jnp.int32), new_caches

    # -- request management ------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self):
        return [i for i in range(self.bs) if not self.active[i]]

    def _fill_slots(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, new_caches = self._prefill(self.params, prompt)
            first = int(jnp.argmax(logits[0, -1]))
            self.caches = update_cache_slots(self.cfg, self.caches,
                                             new_caches, [slot])
            self.slot_req[slot] = req
            self.slot_tokens[slot] = [first]
            self.last_token = self.last_token.at[slot, 0].set(first)
            self.active[slot] = True

    def _retire(self, slot):
        req = self.slot_req[slot]
        self.results[req.uid] = Result(req.uid, list(self.slot_tokens[slot]))
        self.slot_req[slot] = None
        self.active[slot] = False

    # -- main loop ----------------------------------------------------------
    def step(self):
        """One decode step for the whole batch."""
        self.rng, sub = jax.random.split(self.rng)
        nxt, self.caches = self._decode(self.params, self.last_token,
                                        self.caches, sub)
        nxt_host = np.asarray(nxt)
        self.last_token = nxt[:, None]
        for slot in range(self.bs):
            if not self.active[slot]:
                continue
            tok = int(nxt_host[slot])
            self.slot_tokens[slot].append(tok)
            req = self.slot_req[slot]
            done = (self.eos_id is not None and tok == self.eos_id) or \
                len(self.slot_tokens[slot]) >= req.max_new_tokens
            if done:
                self._retire(slot)

    def run(self):
        """Run until all submitted requests complete.  Returns results."""
        while self.queue or self.active.any():
            self._fill_slots()
            if self.active.any():
                self.step()
        return self.results
