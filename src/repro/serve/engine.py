"""Continuous-batching serving engine with chunked GSPN prefill.

Architecture (DESIGN.md §9).  The engine is a slot-based scheduler over a
:class:`~repro.serve.cache.StateCachePool`: requests move through

    QUEUED --admit--> PREFILL(chunk k/N) --commit--> DECODE --> FINISHED

``tick()`` is the scheduling quantum: it admits waiting requests into free
pool slots (``scheduler="fcfs"`` or ``"sjf"``), advances the in-flight
prefill by at most ONE chunk, and runs ONE batched decode step for every
active slot — so a long prompt never stalls the decode batch by more than
one ``prefill_chunk`` of work.  Chunks run through the fused GSPN scan via
``lm_prefill_chunk`` (offset-aware attention KV writes + boundary-seeded
GSPN grid resume); prompts no longer than one chunk, and architectures
without an incremental prefill path (SSM/xLSTM mixers, encoder-decoder),
take the one-shot ``lm_prefill`` fast path inside the admission tick.

Slot/cache lifecycle contract: a slot id is claimed from the pool at
admission, receives exactly one committed prefill state, is decoded as one
batch row until retirement (EOS or token budget), and returns to the pool
— reuse must be clean because ``commit`` rewrites every cache leaf's slot
row.  The decode step for the whole batch is one jitted function, so
throughput is independent of request mix; works for every architecture
family (KV for attention, SSM states for Mamba/xLSTM, the O(√L) row cache
for the GSPN mixer).

Observability (DESIGN.md §13): per-request TTFT / queue delay /
inter-token latencies and a streaming ``stream(uid, token)`` callback;
engine-level counters and latency histograms in the process-global
``repro.obs`` registry (``serve_*`` metrics), with ``ServeEngine.metrics``
kept as a per-engine compat view (the historical dict keys plus a derived
``queue_depth_mean``).  With tracing enabled the engine emits the request
lifecycle as spans: one async ``request`` span per uid
(queued → admitted → finished) enclosing the engine thread's
``serve.prefill_chunk`` / ``serve.decode_step`` child spans, the latter
annotated with the autotuner-resolved kernel plan.

Submission surface: ``submit`` returns a :class:`RequestHandle`
(uid / status / ``result()`` accessor) — the one object a caller, the
router tier (serve.router), and a failure re-route all share.  Batch
drivers may still collect ``run()``'s results dict; the pre-handle
``on_finish`` callback survives as a deprecated shim.  When the engine
serves behind a router, a shared ``prefix_cache``
(serve.cache.PrefixStateCache) lets chunked prefill resume from a cached
fold-boundary state instead of recomputing a shared prompt prefix
(DESIGN.md §15).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm as lm_mod
from repro.serve.cache import (StateCachePool, narrow_state,
                               update_cache_slots)  # noqa: F401
# update_cache_slots is re-exported: it moved to serve.cache (the pool owns
# the scatter) but long-standing callers import it from here.


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32


def _serve_metrics():
    """Engine-level metrics in the process-global registry (get-or-create
    per access, so a test-time registry reset can never strand the
    engine on dead metric objects)."""
    return {
        "ticks": obs.counter("serve_ticks_total", "scheduler quanta run"),
        "decode": obs.counter("serve_decode_steps_total",
                              "batched decode steps"),
        "chunks": obs.counter("serve_prefill_chunks_total",
                              "prefill chunks advanced"),
        "submitted": obs.counter("serve_requests_submitted_total",
                                 "requests accepted by submit()"),
        "finished": obs.counter("serve_requests_finished_total",
                                "requests retired (eos or length)"),
        "qdepth": obs.gauge("serve_queue_depth",
                            "admission-queue depth after the last admit"),
        "ttft": obs.histogram("serve_ttft_seconds",
                              help="submit -> first token"),
        "qdelay": obs.histogram("serve_queue_delay_seconds",
                                help="submit -> admission"),
        "itl": obs.histogram("serve_itl_seconds",
                             help="inter-token latency"),
        "qdepth_hist": obs.histogram("serve_queue_depth_ticks",
                                     buckets=obs.DEPTH_BUCKETS,
                                     help="queue depth sampled per tick"),
        "chunk_s": obs.histogram("serve_prefill_chunk_seconds",
                                 help="wall seconds per prefill chunk "
                                      "(the TTFT predictor's cost model)"),
    }


def _kernel_plan_summary() -> str:
    """Compact string of every (row_tile, pipeline_depth) plan the
    autotuner has resolved in this process — the decode-step span
    annotation (DESIGN.md §11/§13)."""
    from repro.kernels import autotune
    return autotune.plans_summary()


def sample_tokens(logits, rng, temperature: float, top_k: int):
    """The engine-wide logits -> token policy (greedy when temperature<=0,
    else temperature + optional top-k).  logits (B, V) -> (B,) int32.
    One definition serves both the jitted batched decode step and the
    host-side first-token draw, so the two can never drift."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[:, -1:], -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)


def drive(engine, requests, arrivals, *, idle_sleep: float = 0.002):
    """Open-loop arrival driver shared by examples and benchmarks: submit
    each request at its arrival time (seconds relative to the call), tick
    the engine in between, and return ``(elapsed_seconds, handles)`` once
    the engine drains — ``handles`` parallel to ``requests``, each
    finished, so callers read results through the handle API.  Works
    against a single :class:`ServeEngine` or a router (anything with
    ``submit``/``tick``/``idle``).  Open-loop means arrivals never wait
    for completions — queueing shows up in the metrics instead of being
    hidden."""
    t0 = obs.monotonic()
    nxt = 0
    handles = []
    while nxt < len(requests) or not engine.idle:
        now = obs.monotonic() - t0
        while nxt < len(requests) and arrivals[nxt] <= now:
            handles.append(engine.submit(requests[nxt]))
            nxt += 1
        if engine.idle and nxt < len(requests):
            time.sleep(min(arrivals[nxt] - now, idle_sleep))
            continue
        engine.tick()
    return obs.monotonic() - t0, handles


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list
    ttft: float = 0.0               # submit -> first token (s)
    queue_delay: float = 0.0        # submit -> admission (s)
    itl: list = dataclasses.field(default_factory=list)  # inter-token (s)
    prefill_chunks: int = 0         # 0 == one-shot prefill
    finish_reason: str = ""         # "eos" | "length"
    t_submit: float = 0.0           # obs.monotonic() at submit
    t_finish: float = 0.0           # obs.monotonic() at retirement
    cached_tokens: int = 0          # prompt tokens resumed from the
    #                                 prefix cache instead of recomputed


@dataclasses.dataclass
class RequestHandle:
    """What :meth:`ServeEngine.submit` returns: the caller's view of one
    request's lifecycle.  ``status`` moves queued → running → finished;
    ``result()`` is the accessor for the finished :class:`Result` (raises
    until then — poll ``done`` or drive the engine first).  The routing
    tier reuses ONE handle across re-submissions (replica failure drains
    a queue back through the router), so the object a caller holds stays
    valid wherever the request lands; ``replica`` records the current
    placement."""

    uid: int
    status: str = "queued"          # "queued" | "running" | "finished"
    replica: Optional[int] = None   # owning replica id (router tier)
    _result: Optional[Result] = dataclasses.field(default=None, repr=False)

    @property
    def done(self) -> bool:
        return self.status == "finished"

    def result(self) -> Result:
        if self._result is None:
            raise RuntimeError(f"request {self.uid} is {self.status}; "
                               "result() is only available once finished")
        return self._result

    def _finish(self, res: Result):
        self._result = res
        self.status = "finished"


# Warn-once latch for the legacy ``on_finish`` callback surface.
_on_finish_warned = False


class ServeEngine:
    def __init__(self, params, cfg, *, batch_size: int = 4,
                 max_len: int = 512, temperature: float = 0.0,
                 top_k: int = 0, eos_id: Optional[int] = None,
                 seed: int = 0, ctx=None, prefill_chunk: int = 0,
                 scheduler: str = "fcfs", state_dtype=None,
                 stream: Optional[Callable[[int, int], None]] = None,
                 on_finish: Optional[Callable[[Result], None]] = None,
                 prefix_cache=None):
        if scheduler not in ("fcfs", "sjf"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.params = params
        self.cfg = cfg
        self.bs = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        self.eos_id = eos_id
        self.ctx = ctx or lm_mod.Ctx()
        self.scheduler = scheduler
        # At-rest dtype of the pooled propagation state (DESIGN.md §10):
        # bf16 halves pool bytes → ~2× decode batch at fixed memory.
        self.state_dtype = (None if state_dtype is None
                            else jnp.dtype(state_dtype))
        self.stream = stream
        # Internal finish hook for the routing tier (Replica installs it);
        # distinct from the DEPRECATED user-facing ``on_finish`` so the
        # two can never shadow each other.
        self._finish_hook: Optional[Callable[[Result], None]] = None
        self._on_finish = None
        self.on_finish = on_finish       # property: warns once if not None
        # Shared prefix/state cache (serve.cache.PrefixStateCache); None
        # disables the probe.  Router tiers pass ONE cache to every
        # replica so a prefix prefilled anywhere is reusable everywhere.
        self.prefix_cache = prefix_cache
        self.rng = jax.random.PRNGKey(seed)
        self._seed = seed

        # Chunked prefill is only engaged when the architecture has an
        # incremental prefill path; chunk sizes snap to the GSPN fold
        # width so chunks start at grid-row boundaries (lm.py contract).
        if prefill_chunk > 0 and lm_mod.supports_chunked_prefill(cfg):
            align = lm_mod.prefill_chunk_alignment(cfg)
            self.prefill_chunk = max(align, (prefill_chunk // align) * align)
        else:
            self.prefill_chunk = 0

        self.pool = StateCachePool(cfg, batch_size, max_len,
                                   state_dtype=self.state_dtype)
        self._reset_state()

        self._prefill = jax.jit(
            lambda p, toks: lm_mod.lm_prefill(p, cfg, toks, max_len,
                                              ctx=self.ctx)[:2])
        self._prefill_chunk_fn = jax.jit(
            lambda p, toks, caches, off, with_logits: lm_mod.lm_prefill_chunk(
                p, cfg, toks, caches, off, ctx=self.ctx,
                with_logits=with_logits),
            static_argnums=4)
        self._decode = jax.jit(self._decode_fn)

    @property
    def on_finish(self):
        """DEPRECATED side-channel result delivery — ``submit`` returns a
        :class:`RequestHandle` now; read results through it.  Kept as a
        shim (warns once per process) for pre-handle callers."""
        return self._on_finish

    @on_finish.setter
    def on_finish(self, fn):
        global _on_finish_warned
        if fn is not None and not _on_finish_warned:
            _on_finish_warned = True
            import warnings
            warnings.warn(
                "ServeEngine(on_finish=...) is deprecated; submit() "
                "returns a RequestHandle — read results through it",
                DeprecationWarning, stacklevel=3)
        self._on_finish = fn

    def _reset_state(self):
        self.waiting: list = []              # [(Request, t_submit)]
        self._handles: dict = {}             # uid -> unfinished handle
        self._inflight = None                # chunked prefill in progress
        self.slot_req = [None] * self.bs
        self._slot_res: list = [None] * self.bs
        self._slot_t_last = [0.0] * self.bs
        self.last_token = jnp.zeros((self.bs, 1), jnp.int32)
        self.active = np.zeros((self.bs,), bool)
        self.results: dict = {}
        self._m = {"ticks": 0, "decode_steps": 0, "prefill_chunks": 0,
                   "queue_depth_max": 0, "queue_depth_sum": 0,
                   "depth_samples": 0,
                   # bounded: a long-running server must not grow a
                   # per-request list without limit
                   "admission_order": collections.deque(maxlen=1024)}

    @property
    def metrics(self) -> dict:
        """Per-engine compat view of the historical counter dict, plus
        ``queue_depth_mean`` derived ONCE here at snapshot time (callers
        used to recompute ``queue_depth_sum / depth_samples`` by hand).
        The same counters also feed the process-global ``repro.obs``
        registry (``serve_*``) for JSON/Prometheus export."""
        m = dict(self._m)
        m["queue_depth_mean"] = (m["queue_depth_sum"] / m["depth_samples"]
                                 if m["depth_samples"] else 0.0)
        return m

    def reset(self):
        """Clear all scheduling state (fresh pool pages included) but keep
        the compiled functions (benchmark rungs reuse one engine to avoid
        re-jitting)."""
        self.pool = StateCachePool(self.cfg, self.bs, self.max_len,
                                   state_dtype=self.state_dtype)
        self.rng = jax.random.PRNGKey(self._seed)
        self._reset_state()

    # -- jitted decode+sample --------------------------------------------
    def _decode_fn(self, params, token, caches, rng):
        logits, new_caches = lm_mod.lm_decode_step(params, self.cfg, token,
                                                   caches, ctx=self.ctx)
        # narrow inside the jitted step so the cast fuses with the cache
        # writes instead of costing a separate device pass
        new_caches = narrow_state(new_caches, self.state_dtype)
        nxt = sample_tokens(logits[:, 0], rng, self.temperature, self.top_k)
        return nxt, new_caches

    # -- request management -------------------------------------------------
    def check_fits(self, req: Request):
        """Reject oversized requests at the door: past max_len the chunked
        prefill would silently clamp its KV writes and the decode step
        silently drops K/V (the one_hot blend writes nothing) — wrong
        tokens, no error.  Decode writes cache rows up to
        prompt + max_new − 2 (the final token is never written).  Pure
        check (thread-safe) so the router can validate before handing the
        request to a replica worker thread."""
        need = len(req.prompt) + max(req.max_new_tokens, 1) - 1
        if need > self.max_len:
            raise ValueError(
                f"prompt ({len(req.prompt)}) + max_new_tokens "
                f"({req.max_new_tokens}) needs {need} cache rows, exceeding "
                f"the per-slot capacity max_len={self.max_len}")

    def submit(self, req: Request, *,
               handle: Optional[RequestHandle] = None) -> RequestHandle:
        """Queue a request; returns its :class:`RequestHandle`.  ``handle``
        lets the routing tier re-submit a drained request under the handle
        the caller already holds (replica-failure path)."""
        self.check_fits(req)
        if handle is None:
            handle = RequestHandle(uid=req.uid)
        handle.status = "queued"
        self._handles[req.uid] = handle
        self.waiting.append((req, obs.monotonic()))
        _serve_metrics()["submitted"].inc()
        obs.async_begin("request", req.uid, prompt_tokens=len(req.prompt),
                        max_new_tokens=req.max_new_tokens)
        obs.event("request.queued", uid=req.uid)
        return handle

    def _pop_next(self):
        if self.scheduler == "sjf":
            i = min(range(len(self.waiting)),
                    key=lambda i: len(self.waiting[i][0].prompt))
        else:
            i = 0
        return self.waiting.pop(i)

    def _sample_first(self, logits_row):
        """Draw a request's first token (from the last prefill logits)
        under the SAME policy as decode (sample_tokens)."""
        if self.temperature <= 0.0:
            sub = self.rng                   # unused; keep the stream fixed
        else:
            self.rng, sub = jax.random.split(self.rng)
        return int(sample_tokens(logits_row[None], sub,
                                 self.temperature, self.top_k)[0])

    @property
    def idle(self) -> bool:
        """True when nothing is queued, prefilling, or decoding."""
        return (not self.waiting and self._inflight is None
                and not self.active.any())

    @property
    def queue_depth(self) -> int:
        """Admission-queue depth: requests waiting for a slot.  The
        in-flight chunked prefill is already admitted (its queue_delay
        has ended) and is deliberately NOT counted — this is the
        backpressure signal, not an occupancy count."""
        return len(self.waiting)

    @property
    def pending_chunks(self) -> int:
        """Prefill chunks of work queued ahead of a new arrival: the
        in-flight request's remaining chunks plus an estimate for every
        waiting prompt.  The TTFT-predictive router policy multiplies
        this by the measured per-chunk latency (DESIGN.md §15)."""
        n = 0
        if self._inflight is not None:
            st = self._inflight
            left = len(st["toks"]) - st["off"]
            n += -(-left // self.prefill_chunk)
        if self.prefill_chunk:
            for req, _t in self.waiting:
                n += max(-(-len(req.prompt) // self.prefill_chunk), 1)
        else:
            n += len(self.waiting)
        return n

    def drain(self) -> list:
        """Evacuate every unfinished request — the replica-failure path.
        Returns ``[(Request, RequestHandle), ...]`` (admitted requests
        first, then the in-flight prefill, then the waiting queue) with
        each handle reset to ``queued`` so the router can re-submit it to
        a survivor under the SAME handle the caller holds.  Partial decode
        progress is discarded (restart semantics); all scheduling state is
        reset, compiled functions kept."""
        reqs = [self.slot_req[s] for s in range(self.bs) if self.active[s]]
        if self._inflight is not None:
            reqs.append(self._inflight["req"])
        reqs.extend(r for r, _t in self.waiting)
        out = []
        for req in reqs:
            h = self._handles.pop(req.uid, None)
            if h is None:
                h = RequestHandle(uid=req.uid)
            h.status = "queued"
            h._result = None
            obs.async_end("request", req.uid, finish_reason="evacuated")
            out.append((req, h))
        self.reset()
        return out

    # -- prefill ------------------------------------------------------------
    def _admit(self):
        while self.waiting:
            if self._inflight is not None:
                break                        # one chunked prefill at a time
            slot = self.pool.alloc()
            if slot is None:
                break                        # backpressure: batch is full
            req, t_submit = self._pop_next()
            t_admit = obs.monotonic()
            self._m["admission_order"].append(req.uid)
            obs.event("request.admitted", uid=req.uid, slot=slot)
            if self.prefill_chunk and len(req.prompt) > self.prefill_chunk:
                toks = np.asarray(req.prompt, np.int32)
                # Prefix/state probe (DESIGN.md §15): a hit hands back the
                # full boundary-state cache at a chunk-aligned offset k —
                # prefill resumes at k via the same chunk_resume path a
                # cold chunk chain uses, so reuse is a lookup, not a new
                # numeric mode.
                off, cache = 0, None
                if self.prefix_cache is not None:
                    hit = self.prefix_cache.lookup(toks, self.prefill_chunk)
                    if hit is not None:
                        off, cache = hit
                        obs.event("request.prefix_hit", uid=req.uid,
                                  cached_tokens=off)
                if cache is None:
                    # A fresh zeroed batch-1 cache per admission (once per
                    # request, not per chunk).  Reusing a persistent
                    # scratch would need leaf-selective resets — a stale
                    # GSPN prev_row corrupts the seeded scan — for one
                    # saved zero-fill; not worth the foot-gun.
                    cache = lm_mod.init_lm_cache(self.cfg, 1, self.max_len)
                self._inflight = {
                    "req": req, "slot": slot, "off": off, "chunks": 0,
                    "cached": off, "toks": toks, "cache": cache,
                    "t_submit": t_submit, "t_admit": t_admit,
                }
            else:
                with obs.trace("serve.prefill", uid=req.uid,
                               prompt_tokens=len(req.prompt)):
                    prompt = jnp.asarray(req.prompt, jnp.int32)[None]
                    logits, new_caches = self._prefill(self.params, prompt)
                    first = self._sample_first(logits[0, -1])
                    self.pool.commit(slot, new_caches)
                self._activate(req, slot, first, t_submit, t_admit, 0)

    def _advance_prefill(self):
        """Run at most one prompt chunk of the in-flight prefill."""
        st = self._inflight
        if st is None:
            return
        off = st["off"]
        end = min(off + self.prefill_chunk, len(st["toks"]))
        last = end == len(st["toks"])
        t0 = obs.monotonic()
        with obs.trace("serve.prefill_chunk", uid=st["req"].uid,
                       index=st["chunks"], offset=off, tokens=end - off):
            chunk = jnp.asarray(st["toks"][off:end], jnp.int32)[None]
            # only the final chunk's logits feed sampling; intermediate
            # chunks skip the vocab-head projection entirely
            logits, st["cache"] = self._prefill_chunk_fn(
                self.params, chunk, st["cache"], jnp.asarray(off, jnp.int32),
                last)
            # Block so the chunk histogram measures device time, not
            # dispatch: the per-chunk latency is the TTFT predictor's
            # cost model (DESIGN.md §15), and the very next tick would
            # block on this state anyway.
            jax.block_until_ready(st["cache"])
        _serve_metrics()["chunk_s"].observe(obs.monotonic() - t0)
        st["off"] = end
        st["chunks"] += 1
        self._m["prefill_chunks"] += 1
        _serve_metrics()["chunks"].inc()
        if (self.prefix_cache is not None
                and end % self.prefill_chunk == 0 and end > st["cached"]):
            # Every freshly computed chunk boundary is a reusable prefix
            # state: chunk offsets are alignment-snapped, so `end` sits on
            # a GSPN fold-row boundary (the resumable-state contract).
            self.prefix_cache.insert(st["toks"][:end], st["cache"])
        if last:
            first = self._sample_first(logits[0, -1])
            self.pool.commit(st["slot"], st["cache"])
            self._activate(st["req"], st["slot"], first,
                           st["t_submit"], st["t_admit"], st["chunks"],
                           cached=st["cached"])
            self._inflight = None

    def _activate(self, req, slot, first, t_submit, t_admit, chunks,
                  cached: int = 0):
        now = obs.monotonic()
        res = Result(uid=req.uid, tokens=[first], ttft=now - t_submit,
                     queue_delay=t_admit - t_submit, prefill_chunks=chunks,
                     t_submit=t_submit, cached_tokens=cached)
        h = self._handles.get(req.uid)
        if h is not None:
            h.status = "running"
        sm = _serve_metrics()
        sm["ttft"].observe(res.ttft)
        sm["qdelay"].observe(res.queue_delay)
        obs.event("request.first_token", uid=req.uid,
                  ttft_ms=round(res.ttft * 1e3, 3))
        self.slot_req[slot] = req
        self._slot_res[slot] = res
        self._slot_t_last[slot] = now
        self.last_token = self.last_token.at[slot, 0].set(first)
        self.active[slot] = True
        if self.stream:
            self.stream(req.uid, first)
        if self.eos_id is not None and first == self.eos_id:
            self._retire(slot, "eos")
        elif req.max_new_tokens <= 1:
            self._retire(slot, "length")

    # -- decode / retirement ------------------------------------------------
    def _retire(self, slot, reason: str):
        res = self._slot_res[slot]
        res.finish_reason = reason
        res.t_finish = obs.monotonic()
        _serve_metrics()["finished"].inc()
        obs.async_end("request", res.uid, finish_reason=reason,
                      tokens=len(res.tokens))
        h = self._handles.pop(res.uid, None)
        if h is not None:
            h._finish(res)
        if self._finish_hook is not None:
            # routing tier: the replica/router observes the finish; the
            # handle already carries the result, so nothing is retained
            # engine-side and state stays bounded
            self._finish_hook(res)
        elif self.on_finish is not None:
            # deprecated front-end callback (pre-handle shim); nothing is
            # retained engine-side
            self.on_finish(res)
        else:
            self.results[res.uid] = res
        self.slot_req[slot] = None
        self._slot_res[slot] = None
        self.active[slot] = False
        self.pool.free(slot)

    def _decode_step(self):
        """One decode step for the whole batch."""
        sm = _serve_metrics()
        with obs.trace("serve.decode_step",
                       batch=int(self.active.sum())) as sp:
            self.rng, sub = jax.random.split(self.rng)
            nxt, new_caches = self._decode(self.params, self.last_token,
                                           self.pool.caches, sub)
            self.pool.update(new_caches)
            self._m["decode_steps"] += 1
            sm["decode"].inc()
            nxt_host = np.asarray(nxt)
            if obs.enabled():
                # annotate with the autotuner-resolved (row_tile, depth)
                # plans the launches inside this step funnelled through
                sp.set(plan=_kernel_plan_summary())
            self.last_token = nxt[:, None]
            now = obs.monotonic()
            for slot in range(self.bs):
                if not self.active[slot]:
                    continue
                tok = int(nxt_host[slot])
                res = self._slot_res[slot]
                res.tokens.append(tok)
                res.itl.append(now - self._slot_t_last[slot])
                sm["itl"].observe(now - self._slot_t_last[slot])
                self._slot_t_last[slot] = now
                if self.stream:
                    self.stream(res.uid, tok)
                req = self.slot_req[slot]
                if self.eos_id is not None and tok == self.eos_id:
                    self._retire(slot, "eos")
                elif len(res.tokens) >= req.max_new_tokens:
                    self._retire(slot, "length")

    # -- main loop ----------------------------------------------------------
    def tick(self):
        """One scheduling quantum: admit, one prefill chunk, one decode
        step.  Drivers interleave ``submit``/``tick`` to model arrivals."""
        with obs.trace("serve.tick"):
            sm = _serve_metrics()
            self._m["ticks"] += 1
            sm["ticks"].inc()
            self._admit()
            # Depth is sampled AFTER admission: requests that found a free
            # slot this very tick never waited it out, so counting them
            # (the old pre-admit sample) double-counted depth on every
            # tick that retired a request and admitted its replacement.
            # What remains in `waiting` here is true backpressure.
            depth = self.queue_depth
            self._m["queue_depth_max"] = max(
                self._m["queue_depth_max"], depth)
            self._m["queue_depth_sum"] += depth
            self._m["depth_samples"] += 1
            sm["qdepth"].set(depth)
            sm["qdepth_hist"].observe(depth)
            self._advance_prefill()
            if self.active.any():
                self._decode_step()

    # kept as an alias of the scheduling quantum for older callers
    step = tick

    def run(self):
        """Run until all submitted requests complete.  Returns results."""
        while not self.idle:
            self.tick()
        return self.results
