"""One :class:`~repro.serve.engine.ServeEngine` behind the router
(DESIGN.md §15).

A replica owns its engine exclusively.  The router hands requests over
through a locked inbox; the engine itself is only ever touched by the
replica's scheduling context — either the caller's thread (sync mode,
``tick()``), or the replica's worker thread (``start()``), which loops
admit→prefill→decode quanta until stopped.  That single-owner rule is
what makes the tier safe without locking the engine: jitted computation
releases the GIL, so on multi-core hosts N replica workers overlap their
device work — the QPS-scaling mechanism the replica rung of
``benchmarks/serve_load.py`` measures.

Failure semantics: ``fail()`` stops the worker, evacuates every
unfinished request (inbox + engine queue + admitted slots) and returns
``[(Request, RequestHandle), ...]`` with handles reset to ``queued`` —
the router re-submits them to survivors under the SAME handles, so a
caller's handle survives the replica it was first placed on.  Decode
progress on the failed replica is discarded (restart semantics).
"""

from __future__ import annotations

import collections
import threading
import time

from repro import obs


class Replica:
    """A routed serving replica: engine + inbox + optional worker thread."""

    def __init__(self, rid: int, engine):
        self.rid = rid
        self.engine = engine
        self.alive = True
        self.error: Exception | None = None
        self.on_result = None            # router installs: fn(rid, Result)
        self._inbox = collections.deque()  # [(Request, RequestHandle)]
        self._lock = threading.Lock()
        self._thread = None
        self._running = False
        self._busy = False               # mid-tick (see ``idle``)
        engine._finish_hook = self._finished

    def _finished(self, res):
        if self.on_result is not None:
            self.on_result(self.rid, res)

    # -- routing-side surface (any thread) ----------------------------------
    def submit(self, req, handle):
        """Hand a request over.  Validation runs here, synchronously, so
        an oversized request raises at the submitter — not inside the
        worker thread where the error would be orphaned."""
        self.engine.check_fits(req)
        handle.replica = self.rid
        with self._lock:
            self._inbox.append((req, handle))

    @property
    def load(self) -> int:
        """Requests on this replica in any pre-finished state: inbox +
        admission queue + in-flight prefill + active decode slots.  The
        least-loaded policy's signal."""
        eng = self.engine
        with self._lock:
            n = len(self._inbox)
        n += eng.queue_depth + int(eng.active.sum())
        if eng._inflight is not None:
            n += 1
        return n

    @property
    def pending_chunks(self) -> int:
        """Prefill chunks of work ahead of a new arrival (engine estimate
        plus the not-yet-drained inbox) — the TTFT-predictive policy's
        work signal."""
        chunk = self.engine.prefill_chunk or 1
        n = self.engine.pending_chunks
        with self._lock:
            for req, _h in self._inbox:
                n += max(-(-len(req.prompt) // chunk), 1)
        return n

    @property
    def idle(self) -> bool:
        """False while anything is queued, in flight, or mid-tick.  The
        ``_busy`` leg matters in threaded mode: the engine's own ``idle``
        flickers true inside a tick (a request popped from the queue is
        not yet marked active until its prefill returns), and a driver
        polling from another thread must not mistake that for drained."""
        if self._busy:
            return False
        with self._lock:
            if self._inbox:
                return False
        return self.engine.idle

    # -- scheduling (owner context only) ------------------------------------
    def _drain_inbox(self):
        while True:
            with self._lock:
                if not self._inbox:
                    return
                req, h = self._inbox.popleft()
                # submit under the lock: ``idle`` must never observe the
                # gap between popping the inbox and queuing on the engine
                # (a driver polling idle would call the drain done early)
                self.engine.submit(req, handle=h)

    def tick(self):
        """One replica quantum: drain the inbox, run one engine tick."""
        self._busy = True
        try:
            self._drain_inbox()
            if not self.engine.idle:
                self.engine.tick()
        finally:
            self._busy = False

    # -- threaded mode -------------------------------------------------------
    def start(self):
        """Run the scheduling loop on a worker thread.  Device compute in
        the tick releases the GIL, so replicas started this way overlap on
        multi-core hosts."""
        if self._thread is not None:
            return
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name=f"replica-{self.rid}", daemon=True)
        self._thread.start()

    def _loop(self):
        while self._running:
            if self.idle:
                time.sleep(0.0005)
                continue
            try:
                self.tick()
            except Exception as exc:  # noqa: BLE001 — surfaced via .error
                # An orphaned worker exception must not vanish: record it,
                # mark the replica dead, and let the router's next tick
                # drain this replica to survivors.
                self.error = exc
                self.alive = False
                obs.event("replica.error", rid=self.rid, error=repr(exc))
                return

    def stop(self):
        self._running = False
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    # -- failure -------------------------------------------------------------
    def fail(self) -> list:
        """Kill this replica and evacuate everything unfinished.  Returns
        ``[(Request, RequestHandle), ...]`` — inbox arrivals after the
        engine's own drain order (admitted first, then queued) so the
        earliest-placed work is re-routed first."""
        self.alive = False
        self.stop()
        with self._lock:
            inbox = list(self._inbox)
            self._inbox.clear()
        obs.event("replica.failed", rid=self.rid,
                  evacuated=len(inbox))
        return self.engine.drain() + inbox
