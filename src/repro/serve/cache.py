"""Paged per-request propagation-state pool for the serving engine.

Slot/cache lifecycle contract (DESIGN.md §9): the pool owns ONE batched
cache pytree (`init_lm_cache(cfg, n_slots, max_len)`) whose batch axis is
the slot id.  A request's life cycle against the pool is

    slot = pool.alloc()          # admission — None when the batch is full
    pool.commit(slot, cache_1)   # scatter a finished (batch-1) prefill in
    pool.caches / pool.update()  # batched decode reads + writes all slots
    pool.free(slot)              # retirement — slot id returns to the pool

``alloc`` after ``free`` MUST be clean: ``commit`` overwrites every cache
leaf's slot row, so a reused slot never observes its previous occupant's
state (pinned by tests/test_serve_engine.py::test_cache_pool_*).  Unlike a
KV cache, the GSPN/SSM leaves are O(1) in sequence length — paging a
request in or out moves a compact recurrent state, not an O(L) history —
which is what makes per-request admission/retirement cheap (LASP-2
observation, PAPERS.md).

`update_cache_slots` lives here (moved from ``serve.engine``, which
re-exports it for compatibility): it is the scatter primitive ``commit``
is built on, and is layout-aware — prelude/shared stages stack caches as
(n, B, ...), unit stages as (n_units, n, B, ...).
"""

from __future__ import annotations

import collections
import hashlib
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm as lm_mod


def narrow_state(tree, state_dtype):
    """Cast every floating leaf of a cache pytree to ``state_dtype``
    (DESIGN.md §10); integer leaves (lengths, positions) pass through.
    The single definition of the at-rest narrowing rule — the pool's
    init/update and the engine's jitted decode all route through it."""
    if state_dtype is None:
        return tree
    return jax.tree.map(
        lambda a: a.astype(state_dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def update_cache_slots(cfg, caches, new_caches, slots):
    """Scatter ``new_caches`` (batch = len(slots)) into ``caches`` at the
    given slot indices.  Batch-axis position depends on the stage kind:
    prelude/shared stages stack (n, B, ...), unit stages (n_units, n, B...)."""
    slots = jnp.asarray(slots, jnp.int32)

    def upd(axis):
        def f(big, new):
            bigm = jnp.moveaxis(big, axis, 0)
            newm = jnp.moveaxis(new, axis, 0)
            return jnp.moveaxis(bigm.at[slots].set(newm.astype(bigm.dtype)),
                                0, axis)
        return f

    prelude_keys = {f"s{si}_{kind}" for si, (w, kind, n)
                    in enumerate(cfg.stages()) if w == "prelude"}
    out = {}
    for key, sub in caches.items():
        if key in prelude_keys or key == "shared_attn":
            axis = 1
        else:
            axis = 2
        out[key] = jax.tree.map(upd(axis), sub, new_caches[key])
    return out


def _prefix_metrics():
    """Prefix-cache counters in the process-global registry (get-or-create
    per access, mirroring the engine's ``_serve_metrics`` pattern)."""
    return {
        "hits": obs.counter("serve_prefix_hits_total",
                            "prefill admissions resumed from a cached "
                            "prefix state"),
        "misses": obs.counter("serve_prefix_misses_total",
                              "prefill admissions with no usable prefix"),
        "reused": obs.counter("serve_prefix_tokens_reused_total",
                              "prompt tokens served from cached state "
                              "instead of recomputed"),
        "evicted": obs.counter("serve_prefix_evictions_total",
                               "prefix entries dropped by the LRU bound"),
    }


class PrefixStateCache:
    """Token-prefix → boundary-state cache for chunked prefill
    (DESIGN.md §15).

    The GSPN propagation state at a fold-row boundary is O(W) and
    resumable (PR 3 proved chunk-chain ≡ one-shot), so a prompt-prefix
    cache needs no new numerics: store the engine's in-flight batch-1
    cache pytree at a chunk-aligned offset ``k`` (chunk offsets are
    snapped to the fold width, so ``k`` always sits on a grid-row
    boundary), and a later prompt sharing those ``k`` tokens re-enters
    ``lm_prefill_chunk`` at offset ``k`` through the exact
    ``boundary=chunk_resume`` path a cold chain uses.

    Keys are the SHA-1 of the prefix's int32 token bytes; the exact
    token array is stored alongside and verified on lookup, so a hash
    collision degrades to a miss, never to wrong state.  Entries hold
    jax arrays (immutable — sharing with an in-flight prefill is safe);
    ``lookup`` returns a fresh *container* copy so the engine's dict
    bookkeeping never aliases the stored entry.  Bounded LRU: entries
    are full per-slot cache pytrees (O(max_len) attention KV), so the
    default capacity is deliberately small.  Thread-safe — router tiers
    share ONE instance across replica worker threads.
    """

    def __init__(self, capacity: int = 8):
        self.capacity = capacity
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()

    @staticmethod
    def _key(tokens: np.ndarray) -> str:
        return hashlib.sha1(
            np.ascontiguousarray(tokens, np.int32).tobytes()).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        """Total bytes of every cached state pytree (capacity planning)."""
        with self._lock:
            return sum(int(a.size) * a.dtype.itemsize
                       for _toks, tree in self._entries.values()
                       for a in jax.tree.leaves(tree))

    def insert(self, prefix_tokens, cache_tree):
        """Store ``cache_tree`` (the engine's batch-1 prefill cache after
        consuming exactly ``prefix_tokens``).  The caller guarantees the
        offset is chunk-aligned; re-inserting an existing prefix just
        refreshes its LRU position."""
        toks = np.ascontiguousarray(prefix_tokens, np.int32)
        key = self._key(toks)
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return
            self._entries[key] = (toks, cache_tree)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                _prefix_metrics()["evicted"].inc()

    def lookup(self, prompt, chunk: int):
        """Longest cached chunk-aligned proper prefix of ``prompt``.
        Returns ``(k, cache_tree_copy)`` or None.  ``k`` is capped at
        ``len(prompt) - 1`` so at least one prompt token remains to
        prefill — the final chunk must produce the first-token logits."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        m = _prefix_metrics()
        k = ((len(prompt) - 1) // chunk) * chunk
        while k >= chunk:
            with self._lock:
                ent = self._entries.get(self._key(prompt[:k]))
                if ent is not None and np.array_equal(ent[0], prompt[:k]):
                    self._entries.move_to_end(self._key(prompt[:k]))
                    m["hits"].inc()
                    m["reused"].inc(k)
                    # fresh containers, shared (immutable) leaves
                    return k, jax.tree.map(lambda a: a, ent[1])
            k -= chunk
        m["misses"].inc()
        return None


class StateCachePool:
    """Fixed-capacity pool of per-request propagation-state pages.

    One page == one batch row of the engine-wide cache pytree.  The free
    list is LIFO so tests can pin reuse; ``alloc`` returns ``None`` on
    exhaustion (the scheduler's backpressure signal — requests then wait
    in the admission queue).

    ``state_dtype`` (DESIGN.md §10) narrows every floating cache leaf —
    attention KV pages and GSPN/SSM propagation state alike — to the
    given dtype at rest (integer leaves such as lengths/positions are
    untouched).  bf16 halves the pool's bytes, which doubles the decode
    batch that fits a fixed memory budget; ``commit``/``update`` casts on
    scatter, and every consumer already lifts state back to f32 compute
    at use, so narrowing is a storage decision, not a compute one.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, state_dtype=None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.state_dtype = (None if state_dtype is None
                            else jnp.dtype(state_dtype))
        self.caches = narrow_state(
            lm_mod.init_lm_cache(cfg, n_slots, max_len), self.state_dtype)
        self._free = list(range(n_slots - 1, -1, -1))   # pop() yields slot 0
        self._used = set()

    @property
    def nbytes(self) -> int:
        """Total bytes of the pooled cache pytree (the serve-memory
        number the dtype ladder reports)."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree.leaves(self.caches))

    # -- allocation ---------------------------------------------------------
    def alloc(self):
        """Claim a free slot id, or None when every slot is in use."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._used.add(slot)
        return slot

    def free(self, slot: int):
        """Return a slot to the pool.  Double-free is a scheduler bug and
        raises instead of silently corrupting the free list."""
        if slot not in self._used:
            raise ValueError(f"free of slot {slot} not in use")
        self._used.remove(slot)
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._used)

    # -- state movement -----------------------------------------------------
    def commit(self, slot: int, new_caches):
        """Scatter a finished batch-1 prefill cache into ``slot``."""
        self.caches = update_cache_slots(self.cfg, self.caches,
                                         new_caches, [slot])

    def update(self, caches):
        """Install the post-decode batched caches (all slots at once),
        re-narrowing floating leaves to ``state_dtype`` — decode steps
        hand back f32/compute-dtype state (they compute in f32 and the
        attention path preserves its cache dtype), and the pool must not
        silently widen after the first tick."""
        self.caches = narrow_state(caches, self.state_dtype)
