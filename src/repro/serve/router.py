"""SLO-aware data-parallel serving router (DESIGN.md §15).

N engine replicas behind one submission surface.  The router speaks only
the handle API: ``submit`` places a request on a replica chosen by the
admission policy and returns the :class:`~repro.serve.engine.RequestHandle`;
the handle stays valid across re-placements (replica failure drains to
survivors under the same handles).

Admission policies (pluggable via ``POLICIES`` or a callable):

* ``least_loaded`` — fewest requests in any pre-finished state;
* ``ttft`` — TTFT-predictive: estimated first-token latency per replica
  = (chunks of prefill work ahead + the request's own chunks) × the
  measured per-chunk latency (the live ``serve_prefill_chunk_seconds``
  histogram mean).  The prediction also powers the SLO awareness: when
  even the best replica's predicted TTFT exceeds ``slo_ttft``, the
  router counts the admission as at-risk (``router_slo_at_risk_total``)
  and emits an event — the fleet-is-too-small signal an autoscaler
  would act on.

Observability: router-level counters/gauges (requests routed, requeues,
replica failures, replicas-alive) plus a predicted-TTFT histogram, and
one async ``router.request`` span per uid that nests over the owning
engine's ``request`` span, so a trace shows placement and execution as
two levels of the same timeline.

Scheduling modes: sync (``tick()`` round-robins every alive replica —
deterministic, what the tests drive) and threaded (``start()`` gives
each replica its own worker; ``tick()`` becomes a short sleep so the
same ``drive()`` loop works unchanged).  A shared
:class:`~repro.serve.cache.PrefixStateCache` can be passed to every
replica's engine so a prefix prefilled anywhere is reusable everywhere.
"""

from __future__ import annotations

import time
from typing import Optional

from repro import obs
from repro.serve.engine import Request, RequestHandle
from repro.serve.replica import Replica


def _router_metrics():
    """Router-level metrics in the process-global registry (get-or-create
    per access, same pattern as the engine's ``_serve_metrics``)."""
    return {
        "routed": obs.counter("router_requests_routed_total",
                              "requests placed on a replica"),
        "requeued": obs.counter("router_requeued_total",
                                "requests re-routed off a failed replica"),
        "failures": obs.counter("router_replica_failures_total",
                                "replica failures handled"),
        "alive": obs.gauge("router_replicas_alive",
                           "replicas currently accepting requests"),
        "pttft": obs.histogram("router_predicted_ttft_seconds",
                               help="admission-time predicted TTFT of the "
                                    "chosen replica (ttft policy)"),
        "slo_risk": obs.counter("router_slo_at_risk_total",
                                "admissions whose predicted TTFT exceeded "
                                "the SLO on every alive replica"),
    }


def _request_chunks(req: Request, replica: Replica) -> int:
    chunk = replica.engine.prefill_chunk or 1
    return max(-(-len(req.prompt) // chunk), 1)


def _mean_chunk_seconds() -> float:
    """Live mean of the engine-measured per-chunk prefill latency — the
    TTFT predictor's cost model.  0.0 until the first chunk has run (the
    predictor then degrades to pure work-ahead counting, which preserves
    the argmin)."""
    h = obs.histogram("serve_prefill_chunk_seconds")
    return h.sum / h.count if h.count else 0.0


def _pick_least_loaded(req, replicas):
    r = min(replicas, key=lambda r: (r.load, r.rid))
    return r, None


def _pick_ttft(req, replicas):
    per_chunk = _mean_chunk_seconds()

    def predicted(r):
        return (r.pending_chunks + _request_chunks(req, r)) * per_chunk \
            if per_chunk else float(r.pending_chunks + _request_chunks(req, r))

    r = min(replicas, key=lambda r: (predicted(r), r.rid))
    return r, (predicted(r) if per_chunk else None)


POLICIES = {
    "least_loaded": _pick_least_loaded,
    "ttft": _pick_ttft,
}


class Router:
    """N replicas behind an SLO-aware admission policy."""

    def __init__(self, engines, *, policy="least_loaded",
                 slo_ttft: float = 0.5, threaded: bool = False):
        if not engines:
            raise ValueError("router needs at least one engine")
        if callable(policy):
            self._pick = policy
            self.policy = getattr(policy, "__name__", "custom")
        else:
            if policy not in POLICIES:
                raise ValueError(f"unknown router policy {policy!r}; "
                                 f"expected one of {sorted(POLICIES)} "
                                 "or a callable")
            self._pick = POLICIES[policy]
            self.policy = policy
        self.slo_ttft = slo_ttft
        self.threaded = threaded
        self.replicas = [Replica(rid, eng) for rid, eng in enumerate(engines)]
        for r in self.replicas:
            r.on_result = self._on_result
        _router_metrics()["alive"].set(len(self.replicas))
        self._started = False

    # -- placement -----------------------------------------------------------
    def _alive(self):
        return [r for r in self.replicas if r.alive]

    def submit(self, req: Request,
               handle: Optional[RequestHandle] = None) -> RequestHandle:
        """Place ``req`` on the policy-chosen replica; returns its handle
        (a re-route passes the existing one)."""
        alive = self._alive()
        if not alive:
            raise RuntimeError("no alive replicas")
        m = _router_metrics()
        replica, predicted = self._pick(req, alive)
        if predicted is not None:
            m["pttft"].observe(predicted)
            if predicted > self.slo_ttft:
                # even the best placement is predicted to miss the SLO:
                # the router admits anyway (shedding is a policy layered
                # above) but makes the capacity shortfall observable
                m["slo_risk"].inc()
                obs.event("router.slo_at_risk", uid=req.uid,
                          predicted_ttft_ms=round(predicted * 1e3, 3),
                          slo_ms=round(self.slo_ttft * 1e3, 3))
        if handle is None:
            handle = RequestHandle(uid=req.uid)
            obs.async_begin("router.request", req.uid,
                            policy=self.policy, replica=replica.rid)
        replica.submit(req, handle)
        m["routed"].inc()
        obs.event("router.routed", uid=req.uid, replica=replica.rid,
                  policy=self.policy)
        return handle

    def _on_result(self, rid, res):
        obs.async_end("router.request", res.uid, replica=rid,
                      finish_reason=res.finish_reason)

    # -- scheduling ----------------------------------------------------------
    def tick(self):
        """Sync mode: detect dead replicas (worker errors), then give every
        alive replica one quantum.  Threaded mode: the workers are already
        ticking — yield briefly so ``drive()`` loops don't spin."""
        for r in self.replicas:
            if not r.alive and r.error is not None:
                self.fail_replica(r.rid)
        if self._started:
            time.sleep(0.0005)
            return
        for r in self._alive():
            r.tick()

    @property
    def idle(self) -> bool:
        return all(r.idle for r in self._alive())

    def start(self):
        """Threaded mode: one worker per replica."""
        self._started = True
        for r in self._alive():
            r.start()

    def stop(self):
        for r in self.replicas:
            r.stop()
        self._started = False

    # -- failure -------------------------------------------------------------
    def fail_replica(self, rid: int):
        """Kill replica ``rid`` and re-route everything it held to the
        survivors under the callers' existing handles.  Raises if it was
        the last replica alive (requests would be dropped otherwise)."""
        replica = self.replicas[rid]
        evacuated = replica.fail()
        replica.error = None             # handled; don't re-fail on tick
        m = _router_metrics()
        m["failures"].inc()
        m["alive"].set(len(self._alive()))
        if evacuated and not self._alive():
            raise RuntimeError(
                f"replica {rid} failed with {len(evacuated)} unfinished "
                "requests and no survivors to drain to")
        for req, handle in evacuated:
            self.submit(req, handle=handle)
            m["requeued"].inc()
        obs.event("router.replica_failed", rid=rid,
                  requeued=len(evacuated))
        return len(evacuated)

    # -- draining ------------------------------------------------------------
    def run(self):
        """Tick until every replica drains (sync-mode convenience)."""
        while not self.idle:
            self.tick()
