"""Cell builders for the multi-pod dry-run: one (architecture × input
shape × mesh) combination → a jitted function + abstract args + shardings,
ready for ``.lower().compile()``.

Covers the three shape kinds (train / prefill / decode) for all LM
architectures plus the paper's own GSPN-2 vision backbone (extra cells).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_arch, input_specs, SHAPES, ShapeSpec
from repro.launch.mesh import dp_axes_for
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.parallel import sharding as shd
from repro.train.step import build_train_step

# Archs whose params+Adam state exceed HBM in f32: store bf16 (DESIGN §5).
BF16_PARAM_ARCHS = {"kimi-k2-1t-a32b", "grok-1-314b", "qwen2-vl-72b"}


@dataclasses.dataclass
class Cell:
    name: str
    fn: Any
    args: tuple
    jit_kwargs: dict
    meta: dict


def _count(tree) -> int:
    import numpy as np
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def build_lm_cell(arch: str, shape_name: str, mesh, *,
                  remat: str | None = None, grad_accum: int | None = None,
                  extra_overrides: dict | None = None) -> Cell:
    entry = get_arch(arch)
    shape = SHAPES[shape_name]
    tp = mesh.shape["model"]
    dp_axes = dp_axes_for(mesh)
    cfg = entry.full(n_model_shards=tp)
    overrides = {"max_seq": shape.seq_len}
    if arch in BF16_PARAM_ARCHS:
        overrides["param_dtype"] = jnp.bfloat16
    if remat is not None:
        overrides["remat"] = remat
    if extra_overrides:
        overrides.update(extra_overrides)
    cfg = dataclasses.replace(cfg, **overrides)
    ctx = lm_mod.Ctx(mesh=mesh, dp_axes=dp_axes)

    abstract_params = jax.eval_shape(
        lambda k: lm_mod.init_lm(k, cfg), jax.random.PRNGKey(0))
    pshard = shd.param_shardings(abstract_params, mesh)
    n_params = _count(abstract_params)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "n_params": n_params, "family": cfg.family,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch}

    if shape.kind == "train":
        ocfg = AdamWConfig(
            state_dtype=jnp.bfloat16 if n_params > 5e10 else jnp.float32)
        abstract_state = jax.eval_shape(
            lambda p: {"params": p, "opt": adamw_init(ocfg, p)},
            abstract_params)
        state_shardings = {"params": pshard,
                           "opt": {"m": pshard, "v": pshard,
                                   "step": NamedSharding(mesh, P())}}
        batch = input_specs(cfg, shape)
        bshard = shd.batch_shardings(batch, mesh, dp_axes)
        # Microbatch so per-microbatch activation stacks fit HBM:
        # target ≤ ~25M token·feature elements per device per microbatch.
        dp = 1
        for a in dp_axes:
            dp *= mesh.shape[a]
        b_loc = max(shape.global_batch // dp, 1)
        tokens_feat = b_loc * shape.seq_len * cfg.d_model
        if grad_accum is None:
            grad_accum = 1
            # MoE: FSDP weight-gather traffic scales with K — cap at 8
            # (measured: kimi K=16→8 cuts collectives 19→10.8 TB/dev for
            # +9% temp; EXPERIMENTS.md §Perf).
            k_cap = 8 if cfg.n_experts else b_loc
            while (tokens_feat // grad_accum > 25e6
                   and grad_accum < min(b_loc, k_cap)
                   and b_loc % (grad_accum * 2) == 0):
                grad_accum *= 2
        meta["grad_accum"] = grad_accum
        fn = build_train_step(cfg, ocfg, mesh=mesh, dp_axes=dp_axes,
                              grad_accum=grad_accum)
        return Cell(
            name=f"{arch}__{shape_name}",
            fn=fn, args=(abstract_state, batch),
            jit_kwargs=dict(in_shardings=(state_shardings, bshard),
                            out_shardings=(state_shardings, None),
                            donate_argnums=(0,)),
            meta=meta)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bshard = shd.batch_shardings(batch, mesh, dp_axes)

        def prefill_fn(params, batch):
            logits, caches, _ = lm_mod.lm_prefill(
                params, cfg, batch["tokens"], max_len=shape.seq_len, ctx=ctx,
                enc_frames=batch.get("enc_frames"),
                vision_embeds=batch.get("vision_embeds"))
            return logits, caches

        abstract_caches = jax.eval_shape(
            lambda: lm_mod.init_lm_cache(cfg, shape.global_batch,
                                         shape.seq_len))
        cshard = shd.cache_shardings(abstract_caches, mesh, dp_axes)
        return Cell(
            name=f"{arch}__{shape_name}",
            fn=prefill_fn, args=(abstract_params, batch),
            jit_kwargs=dict(in_shardings=(pshard, bshard),
                            out_shardings=(None, cshard)),
            meta=meta)

    # decode
    b = shape.global_batch
    abstract_caches = jax.eval_shape(
        lambda: lm_mod.init_lm_cache(cfg, b, shape.seq_len))
    # decode starts from a filled cache: set plausible lengths in meta only
    cshard = shd.cache_shardings(abstract_caches, mesh, dp_axes)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tshard = shd.batch_shardings({"token": token}, mesh, dp_axes)["token"]

    if cfg.family == "audio":
        acfg = lm_mod._attn_cfg(cfg)
        enc_kv = (jax.ShapeDtypeStruct(
                      (b, cfg.enc_len, cfg.n_kv_heads, acfg.hd),
                      cfg.compute_dtype),) * 2
        ekv_shard = jax.tree.map(
            lambda l: NamedSharding(mesh, shd.sanitize_spec(
                P(dp_axes), l.shape, mesh)), enc_kv)

        def decode_fn(params, token, caches, enc_kv):
            return lm_mod.lm_decode_step(params, cfg, token, caches,
                                         ctx=ctx, enc_kv=enc_kv)

        return Cell(
            name=f"{arch}__{shape_name}",
            fn=decode_fn, args=(abstract_params, token, abstract_caches,
                                enc_kv),
            jit_kwargs=dict(
                in_shardings=(pshard, tshard, cshard, ekv_shard),
                out_shardings=(None, cshard), donate_argnums=(2,)),
            meta=meta)

    def decode_fn(params, token, caches):
        return lm_mod.lm_decode_step(params, cfg, token, caches, ctx=ctx)

    return Cell(
        name=f"{arch}__{shape_name}",
        fn=decode_fn, args=(abstract_params, token, abstract_caches),
        jit_kwargs=dict(in_shardings=(pshard, tshard, cshard),
                        out_shardings=(None, cshard), donate_argnums=(2,)),
        meta=meta)


# ---------------------------------------------------------------------------
# Vision cells (the paper's own architecture — extra beyond the 40).
# ---------------------------------------------------------------------------

VISION_SHAPES = {
    "img_train_224": ShapeSpec("img_train_224", "train", 224, 1024),
    "img_infer_1024": ShapeSpec("img_infer_1024", "prefill", 1024, 16),
}


def build_vision_cell(arch: str, shape_name: str, mesh) -> Cell:
    from repro.configs.gspn2_vision import VISION_CONFIGS
    from repro.models import vision as vis_mod
    import dataclasses as dc

    vcfg = dc.replace(VISION_CONFIGS[arch],
                      img_size=VISION_SHAPES[shape_name].seq_len,
                      impl="xla")
    shape = VISION_SHAPES[shape_name]
    dp_axes = dp_axes_for(mesh)
    ctx = lm_mod.Ctx(mesh=mesh, dp_axes=dp_axes)
    b = shape.global_batch

    abstract_params = jax.eval_shape(
        lambda k: vis_mod.init_vision(k, vcfg), jax.random.PRNGKey(0))
    pshard = shd.param_shardings(abstract_params, mesh)
    images = jax.ShapeDtypeStruct((b, vcfg.img_size, vcfg.img_size, 3),
                                  jnp.float32)
    labels = jax.ShapeDtypeStruct((b,), jnp.int32)
    bshard = shd.batch_shardings({"images": images, "labels": labels},
                                 mesh, dp_axes)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "n_params": _count(abstract_params), "family": "vision",
            "seq_len": vcfg.img_size, "global_batch": b}

    if shape.kind == "train":
        ocfg = AdamWConfig()
        abstract_state = jax.eval_shape(
            lambda p: {"params": p, "opt": adamw_init(ocfg, p)},
            abstract_params)
        state_shardings = {"params": pshard,
                           "opt": {"m": pshard, "v": pshard,
                                   "step": NamedSharding(mesh, P())}}

        from repro.optim.adamw import adamw_update

        def train_fn(state, batch):
            def loss_fn(p):
                return vis_mod.vision_loss(p, vcfg, batch, ctx=ctx)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            new_p, new_o, stats = adamw_update(ocfg, grads, state["opt"],
                                               state["params"])
            return {"params": new_p, "opt": new_o}, {"loss": loss, **stats}

        return Cell(
            name=f"{arch}__{shape_name}", fn=train_fn,
            args=(abstract_state, {"images": images, "labels": labels}),
            jit_kwargs=dict(in_shardings=(state_shardings, bshard),
                            out_shardings=(state_shardings, None),
                            donate_argnums=(0,)),
            meta=meta)

    def infer_fn(params, batch):
        return vis_mod.apply_vision(params, batch["images"], vcfg, ctx=ctx)

    return Cell(
        name=f"{arch}__{shape_name}", fn=infer_fn,
        args=(abstract_params, {"images": images, "labels": labels}),
        jit_kwargs=dict(in_shardings=(pshard, bshard), out_shardings=None),
        meta=meta)
