"""Shared launcher flag plumbing (DESIGN.md §15).

The same knobs — kernel impl, mixed precision, at-rest state dtype,
tuning cache, trace/metrics output, and now the serving-tier router —
kept growing copy-pasted ``add_argument`` blocks across
``launch/serve.py``, ``launch/train.py`` and ``benchmarks/run.py``,
which is exactly how flag help text and defaults drift.  Each group is
defined ONCE here as an ``add_*_args(parser)`` helper plus the matching
apply-side function, so a new knob (e.g. ``--replicas``) lands in every
entry point by construction.

The helpers only add flags; the launchers keep their own entry-specific
arguments and call the apply-side functions (``setup_observability`` /
``load_tune_cache`` / ``finish_observability``) at the right points in
their lifecycle.
"""

from __future__ import annotations

from repro import obs
from repro.configs.base import PRECISIONS


# -- flag groups -------------------------------------------------------------

def add_observability_args(ap):
    """``--trace-out`` / ``--metrics-out`` (DESIGN.md §13)."""
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run here "
                         "(open in Perfetto / chrome://tracing; "
                         "DESIGN.md §13)")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics-registry snapshot here "
                         "(.prom => Prometheus text, else JSON; "
                         "DESIGN.md §13)")


def add_tuning_args(ap):
    """``--tune-cache`` (DESIGN.md §11)."""
    ap.add_argument("--tune-cache", default="",
                    help="kernel tuning cache JSON (DESIGN.md §11), "
                         "layered over the checked-in seed cache; every "
                         "GSPN launch then uses measured row tiles "
                         "instead of the VMEM heuristic")


def add_impl_arg(ap):
    """``--impl`` — the GSPN kernel-selection knob."""
    ap.add_argument("--impl", default="",
                    help="override the GSPN kernel impl= knob "
                         "(auto|pallas|multidir|xla|sp)")


def add_precision_args(ap, *, state_dtype: bool = False):
    """``--precision`` (and optionally ``--state-dtype``), DESIGN.md §10."""
    ap.add_argument("--precision", default="",
                    choices=[""] + sorted(PRECISIONS),
                    help="mixed-precision policy "
                         "(params/compute/carries, DESIGN.md §10)")
    if state_dtype:
        ap.add_argument("--state-dtype", default="",
                        choices=["", "f32", "bf16"],
                        help="at-rest dtype of the pooled propagation "
                             "state (bf16 halves pool bytes, "
                             "DESIGN.md §10)")


def add_router_args(ap):
    """Serving-tier knobs: ``--replicas/--router/--prefix-cache/--slo-ttft``
    (DESIGN.md §15)."""
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the router (1 = a bare "
                         "engine, no router tier)")
    ap.add_argument("--router", default="least_loaded",
                    choices=["least_loaded", "ttft"],
                    help="admission policy: fewest in-flight requests, or "
                         "TTFT-predictive (work-ahead x measured per-chunk "
                         "latency, DESIGN.md §15)")
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="capacity (entries) of the shared prefix/state "
                         "cache; 0 disables prefix reuse")
    ap.add_argument("--slo-ttft", type=float, default=0.5,
                    help="TTFT SLO in seconds; admissions predicted to "
                         "miss it count router_slo_at_risk_total")


# -- apply side --------------------------------------------------------------

def setup_observability(args):
    """Enable tracing BEFORE model build so jit-trace-time spans (kernel
    dispatch/launch, autotune plan resolution) are captured."""
    if args.trace_out:
        obs.enable()


def finish_observability(args, tag: str):
    """Write the trace/metrics artifacts named by the flags (no-ops when
    the flags are unset)."""
    if args.trace_out:
        print(f"[{tag}] trace: {obs.save_chrome_trace(args.trace_out)} "
              f"({len(obs.records())} events)")
    if args.metrics_out:
        print(f"[{tag}] metrics: {obs.save_metrics(args.metrics_out)}")


def load_tune_cache(args, tag: str):
    """Layer ``--tune-cache`` over the seed cache (no-op when unset)."""
    if args.tune_cache:
        from repro.kernels.autotune import load_cache
        n = load_cache(args.tune_cache)
        print(f"[{tag}] tuning cache: {n} entries from {args.tune_cache}")
