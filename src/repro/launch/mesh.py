"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the smoke tests to keep seeing one
CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("pod", "data", "model") multi-pod / ("data", "model") single-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for_devices(devices, *, model_parallel: int = 16):
    """Elastic helper: best (data, model) mesh for an arbitrary device set."""
    n = len(devices)
    tp = model_parallel
    while n % tp != 0:
        tp //= 2
    return jax.make_mesh(
        (n // tp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
        devices=devices)


def dp_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


HW = {
    # TPU v5e-class chip constants used for the roofline terms.
    "peak_flops_bf16": 197e12,     # FLOP/s per chip
    "hbm_bw": 819e9,               # B/s per chip
    "ici_bw": 50e9,                # B/s per link
    "hbm_bytes": 16e9,             # HBM capacity per chip
}
