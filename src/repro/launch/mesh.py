"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required for the smoke tests to keep seeing one
CPU device).

Axes (DESIGN.md §5, §8):

* ``pod``   — outer data parallel over DCN (multi-pod only);
* ``data``  — data parallel + FSDP;
* ``seq``   — spatial sequence parallelism: the GSPN scan dimension is
  partitioned over this axis (``parallel/gspn_sp.py``).  Carved out of
  the data-parallel extent so the chip count per pod is unchanged;
* ``model`` — tensor parallel.
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False, seq_parallel: int = 1):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("pod",) ("data", "seq", "model") with the ``seq`` axis carved
    out of the 16-wide data extent (``seq_parallel`` must divide 16);
    ``seq_parallel=1`` keeps the historical ("data", "model") layout.
    """
    data = 16
    assert data % seq_parallel == 0, (data, seq_parallel)
    shape: tuple = (data // seq_parallel, 16)
    axes: tuple = ("data", "model")
    if seq_parallel > 1:
        shape = (data // seq_parallel, seq_parallel, 16)
        axes = ("data", "seq", "model")
    if multi_pod:
        shape = (2,) + shape
        axes = ("pod",) + axes
    return make_mesh(shape, axes)


def make_mesh_for_devices(devices, *, model_parallel: int = 16,
                          seq_parallel: int = 1):
    """Elastic helper: best (data[, seq], model) mesh for a device set."""
    n = len(devices)
    tp = model_parallel
    while n % tp != 0:
        tp //= 2
    dp = n // tp
    sp = seq_parallel
    while dp % sp != 0:
        sp //= 2
    if sp > 1:
        return make_mesh((dp // sp, sp, tp), ("data", "seq", "model"),
                         devices=devices)
    return make_mesh((dp, tp), ("data", "model"), devices=devices)


def make_sp_mesh(n_seq: int | None = None, *, devices=None):
    """Single-axis ``seq`` mesh — the whole device set drives one sharded
    scan (tests, benchmarks, and max-resolution single-image inference)."""
    devices = list(devices if devices is not None else jax.devices())
    n = n_seq or len(devices)
    return make_mesh((n,), ("seq",), devices=devices[:n])


def dp_axes_for(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def seq_axis_size(mesh, axis: str = "seq") -> int:
    """Extent of the sequence-parallel axis (1 when the mesh lacks it)."""
    return mesh.shape[axis] if mesh is not None and axis in mesh.axis_names \
        else 1


HW = {
    # TPU v5e-class chip constants used for the roofline terms.
    "peak_flops_bf16": 197e12,     # FLOP/s per chip
    "hbm_bw": 819e9,               # B/s per chip
    "ici_bw": 50e9,                # B/s per link
    "hbm_bytes": 16e9,             # HBM capacity per chip
}
