"""Serving launcher: load (or init) a model and serve a synthetic request
stream through the continuous-batching engine (DESIGN.md §9).

Engine knobs surfaced here: ``--max-batch`` (decode slots),
``--prefill-chunk`` (0 = one-shot prefill; otherwise prompts are consumed
in chunks interleaved with decode), ``--scheduler fcfs|sjf``, ``--impl``
(GSPN kernel selection threaded into the model config),
``--seq-parallel`` (serve through a `seq`-axis mesh so the GSPN scans
shard across devices, DESIGN.md §8), ``--state-dtype bf16`` (narrow the
pooled propagation state at rest — half the pool bytes, ~2× decode batch
at fixed memory) and ``--precision bf16`` (run the model itself under the
mixed-precision policy, DESIGN.md §10).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 8 --prefill-chunk 128 --scheduler sjf
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import (PRECISIONS, get_arch, resolve_dtype,
                                with_precision)
from repro.models.lm import Ctx, init_lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", "--batch", type=int, default=4,
                    dest="max_batch")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size in tokens (0 = one-shot)")
    ap.add_argument("--scheduler", default="fcfs", choices=["fcfs", "sjf"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--impl", default="",
                    help="override the GSPN kernel impl= knob "
                         "(auto|pallas|multidir|xla|sp)")
    ap.add_argument("--seq-parallel", type=int, default=1,
                    help="carve a seq mesh axis of this size and serve "
                         "the sharded model (impl=sp, DESIGN.md §8)")
    ap.add_argument("--state-dtype", default="",
                    choices=["", "f32", "bf16"],
                    help="at-rest dtype of the pooled propagation state "
                         "(bf16 halves pool bytes, DESIGN.md §10)")
    ap.add_argument("--precision", default="",
                    choices=[""] + sorted(PRECISIONS),
                    help="mixed-precision policy for the served model "
                         "(params/compute/carries, DESIGN.md §10)")
    ap.add_argument("--tune-cache", default="",
                    help="kernel tuning cache JSON (DESIGN.md §11), "
                         "layered over the checked-in seed cache; every "
                         "GSPN launch in the engine then uses measured "
                         "row tiles instead of the VMEM heuristic")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run here "
                         "(open in Perfetto / chrome://tracing; "
                         "DESIGN.md §13)")
    ap.add_argument("--metrics-out", default="",
                    help="write the metrics-registry snapshot here "
                         "(.prom => Prometheus text, else JSON; "
                         "DESIGN.md §13)")
    args = ap.parse_args()

    if args.trace_out:
        # Enable BEFORE model build so jit-trace-time spans (kernel
        # dispatch/launch, autotune plan resolution) are captured.
        obs.enable()

    if args.tune_cache:
        from repro.kernels.autotune import load_cache
        n = load_cache(args.tune_cache)
        print(f"[serve] tuning cache: {n} entries from {args.tune_cache}")

    entry = get_arch(args.arch)
    cfg = entry.reduced() if args.reduced else entry.full()
    if args.precision:
        cfg = with_precision(cfg, args.precision)
    if args.impl:
        cfg = dataclasses.replace(cfg, gspn_impl=args.impl)

    ctx = None
    if args.seq_parallel > 1:
        from repro.launch.mesh import dp_axes_for, make_sp_mesh
        mesh = make_sp_mesh(args.seq_parallel)
        ctx = Ctx(mesh=mesh, dp_axes=dp_axes_for(mesh))
        if not args.impl:
            # the mesh is only consulted by impl="sp"; without this the
            # seq axis would be carved and then silently unused
            cfg = dataclasses.replace(cfg, gspn_impl="sp")
        print(f"[serve] mesh axes {dict(zip(mesh.axis_names, mesh.shape))} "
              f"(gspn impl={cfg.gspn_impl})")

    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore(target={"params": params})
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step}")

    eng = ServeEngine(params, cfg, batch_size=args.max_batch,
                      max_len=args.max_len, temperature=args.temperature,
                      prefill_chunk=args.prefill_chunk,
                      scheduler=args.scheduler, ctx=ctx,
                      state_dtype=(resolve_dtype(args.state_dtype)
                                   if args.state_dtype else None))
    if args.state_dtype:
        print(f"[serve] state pool dtype {args.state_dtype}: "
              f"{eng.pool.nbytes/2**20:.1f} MiB pooled state")
    rng = np.random.default_rng(0)
    # Discrete prompt lengths (each distinct length is a separate jit
    # trace of the prefill); when chunking is on, the long length must
    # actually exceed the (alignment-snapped) chunk so the chunked path
    # runs at this entry point's workload sizes.
    long_len = min(args.max_len - args.max_new,
                   3 * eng.prefill_chunk) if eng.prefill_chunk else 24
    for i in range(args.requests):
        plen = long_len if (eng.prefill_chunk and i % 2) else 12
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, max(plen, 4)),
            max_new_tokens=args.max_new))
    t0 = obs.monotonic()
    results = eng.run()
    dt = obs.monotonic() - t0
    if args.trace_out:
        print(f"[serve] trace: {obs.save_chrome_trace(args.trace_out)} "
              f"({len(obs.records())} events)")
    if args.metrics_out:
        print(f"[serve] metrics: {obs.save_metrics(args.metrics_out)}")
    if not results:
        print(f"[serve] {args.arch}: 0 requests")
        return
    total = sum(len(r.tokens) for r in results.values())
    ttfts = sorted(r.ttft for r in results.values())
    m = eng.metrics
    print(f"[serve] {args.arch}: {len(results)} requests, {total} tokens, "
          f"{total/dt:.1f} tok/s")
    print(f"[serve] ttft p50 {ttfts[len(ttfts)//2]*1e3:.1f} ms, "
          f"max {ttfts[-1]*1e3:.1f} ms; queue depth "
          f"mean {m['queue_depth_mean']:.1f} / "
          f"max {m['queue_depth_max']}; "
          f"{m['prefill_chunks']} prefill chunks / "
          f"{m['decode_steps']} decode steps over {m['ticks']} ticks")


if __name__ == "__main__":
    main()
