"""Serving launcher: load (or init) a model and serve a synthetic request
stream with the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.models.lm import init_lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    entry = get_arch(args.arch)
    cfg = entry.reduced() if args.reduced else entry.full()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore(target={"params": params})
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step}")

    eng = ServeEngine(params, cfg, batch_size=args.batch,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab,
                                       int(rng.integers(4, 32))),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results.values())
    print(f"[serve] {args.arch}: {len(results)} requests, {total} tokens, "
          f"{total/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
