"""Serving launcher: load (or init) a model and serve a synthetic request
stream through the continuous-batching engine (DESIGN.md §9) — or, with
``--replicas N``, through the data-parallel serving tier (DESIGN.md §15):
N engine replicas behind the SLO-aware router.

Engine knobs surfaced here: ``--max-batch`` (decode slots per replica),
``--prefill-chunk`` (0 = one-shot prefill; otherwise prompts are consumed
in chunks interleaved with decode), ``--scheduler fcfs|sjf``, ``--impl``
(GSPN kernel selection threaded into the model config),
``--seq-parallel`` (serve through a `seq`-axis mesh so the GSPN scans
shard across devices, DESIGN.md §8), ``--state-dtype bf16`` (narrow the
pooled propagation state at rest — half the pool bytes, ~2× decode batch
at fixed memory) and ``--precision bf16`` (run the model itself under the
mixed-precision policy, DESIGN.md §10).

Tier knobs (shared definitions in ``launch/args.py``): ``--replicas``,
``--router least_loaded|ttft``, ``--prefix-cache N`` (shared prefix/state
cache entries; prompts sharing a chunk-aligned prefix resume prefill from
cached boundary state), ``--slo-ttft`` (seconds; predicted-miss
admissions are counted, DESIGN.md §15).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 8 --prefill-chunk 128 --scheduler sjf \
        --replicas 2 --router ttft --prefix-cache 8
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch, resolve_dtype, with_precision
from repro.launch import args as largs
from repro.models.lm import Ctx, init_lm
from repro.serve.cache import PrefixStateCache
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", "--batch", type=int, default=4,
                    dest="max_batch")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size in tokens (0 = one-shot)")
    ap.add_argument("--scheduler", default="fcfs", choices=["fcfs", "sjf"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seq-parallel", type=int, default=1,
                    help="carve a seq mesh axis of this size and serve "
                         "the sharded model (impl=sp, DESIGN.md §8)")
    ap.add_argument("--ckpt-dir", default="")
    largs.add_impl_arg(ap)
    largs.add_precision_args(ap, state_dtype=True)
    largs.add_tuning_args(ap)
    largs.add_router_args(ap)
    largs.add_observability_args(ap)
    args = ap.parse_args()

    largs.setup_observability(args)
    largs.load_tune_cache(args, "serve")

    entry = get_arch(args.arch)
    cfg = entry.reduced() if args.reduced else entry.full()
    if args.precision:
        cfg = with_precision(cfg, args.precision)
    if args.impl:
        cfg = dataclasses.replace(cfg, gspn_impl=args.impl)

    ctx = None
    if args.seq_parallel > 1:
        from repro.launch.mesh import dp_axes_for, make_sp_mesh
        mesh = make_sp_mesh(args.seq_parallel)
        ctx = Ctx(mesh=mesh, dp_axes=dp_axes_for(mesh))
        if not args.impl:
            # the mesh is only consulted by impl="sp"; without this the
            # seq axis would be carved and then silently unused
            cfg = dataclasses.replace(cfg, gspn_impl="sp")
        print(f"[serve] mesh axes {dict(zip(mesh.axis_names, mesh.shape))} "
              f"(gspn impl={cfg.gspn_impl})")

    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore(target={"params": params})
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step}")

    prefix_cache = (PrefixStateCache(capacity=args.prefix_cache)
                    if args.prefix_cache > 0 else None)

    def make_engine(seed=0):
        return ServeEngine(
            params, cfg, batch_size=args.max_batch, max_len=args.max_len,
            temperature=args.temperature, prefill_chunk=args.prefill_chunk,
            scheduler=args.scheduler, ctx=ctx, seed=seed,
            prefix_cache=prefix_cache,
            state_dtype=(resolve_dtype(args.state_dtype)
                         if args.state_dtype else None))

    if args.replicas > 1:
        from repro.serve.router import Router
        engines = [make_engine(seed=i) for i in range(args.replicas)]
        tier = Router(engines, policy=args.router, slo_ttft=args.slo_ttft)
        pool = engines[0].pool
        chunk = engines[0].prefill_chunk
        print(f"[serve] router: {args.replicas} replicas, "
              f"policy={args.router}, slo_ttft={args.slo_ttft * 1e3:.0f} ms"
              + (f", prefix cache {args.prefix_cache} entries"
                 if prefix_cache else ""))
    else:
        tier = make_engine()
        pool, chunk = tier.pool, tier.prefill_chunk
    if args.state_dtype:
        print(f"[serve] state pool dtype {args.state_dtype}: "
              f"{args.replicas * pool.nbytes/2**20:.1f} MiB pooled state")

    rng = np.random.default_rng(0)
    # Discrete prompt lengths (each distinct length is a separate jit
    # trace of the prefill); when chunking is on, the long length must
    # actually exceed the (alignment-snapped) chunk so the chunked path
    # runs at this entry point's workload sizes.
    long_len = min(args.max_len - args.max_new, 3 * chunk) if chunk else 24
    handles = []
    for i in range(args.requests):
        plen = long_len if (chunk and i % 2) else 12
        handles.append(tier.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, max(plen, 4)),
            max_new_tokens=args.max_new)))
    t0 = obs.monotonic()
    tier.run()
    dt = obs.monotonic() - t0
    largs.finish_observability(args, "serve")
    results = [h.result() for h in handles]
    if not results:
        print(f"[serve] {args.arch}: 0 requests")
        return
    total = sum(len(r.tokens) for r in results)
    ttfts = sorted(r.ttft for r in results)
    cached = sum(r.cached_tokens for r in results)
    print(f"[serve] {args.arch}: {len(results)} requests, {total} tokens, "
          f"{total/dt:.1f} tok/s"
          + (f", {cached} prompt tokens prefix-cached" if cached else ""))
    if args.replicas > 1:
        placed = [h.replica for h in handles]
        snap = obs.snapshot()
        risk = snap.get("counters", {}).get("router_slo_at_risk_total", 0)
        print(f"[serve] placement: "
              f"{[placed.count(r) for r in range(args.replicas)]} "
              f"requests/replica; {risk} admissions predicted past SLO")
        print(f"[serve] ttft p50 {ttfts[len(ttfts)//2]*1e3:.1f} ms, "
              f"max {ttfts[-1]*1e3:.1f} ms")
    else:
        m = tier.metrics
        print(f"[serve] ttft p50 {ttfts[len(ttfts)//2]*1e3:.1f} ms, "
              f"max {ttfts[-1]*1e3:.1f} ms; queue depth "
              f"mean {m['queue_depth_mean']:.1f} / "
              f"max {m['queue_depth_max']}; "
              f"{m['prefill_chunks']} prefill chunks / "
              f"{m['decode_steps']} decode steps over {m['ticks']} ticks")


if __name__ == "__main__":
    main()
