import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_BASE_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT lower + compile every (architecture × input
shape) cell on the production meshes, and record memory / cost /
collective analyses for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k \
        --mesh single --out results/dryrun
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
    python -m repro.launch.dryrun --list

Driver mode (--all) runs each cell in a subprocess so one failing or
OOM-ing compile cannot take down the sweep, and skips cells whose JSON
already exists (incremental).
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def cell_matrix():
    """All (arch, shape) cells incl. skips, plus vision extras."""
    from repro.configs.all_archs import ASSIGNED, EXTRAS
    from repro.configs.base import get_arch, SHAPES
    from repro.launch.lowering import VISION_SHAPES
    cells = []
    for arch in ASSIGNED + EXTRAS:
        entry = get_arch(arch)
        for shape in SHAPES:
            cells.append(("lm", arch, shape,
                          entry.skip_shapes.get(shape)))
    for vshape in VISION_SHAPES:
        cells.append(("vision", "gspn2-b", vshape, None))
    return cells


def run_cell(kind: str, arch: str, shape: str, mesh_mode: str, out_dir: str,
             remat: str | None = None, tag: str = "",
             grad_accum: int | None = None):
    import jax

    from repro import compat
    from repro.launch.mesh import make_production_mesh, HW
    from repro.roofline import hlo as hlo_mod

    multi_pod = mesh_mode == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_mode,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [int(s) for s in mesh.devices.shape])),
        "n_devices": int(mesh.devices.size),
        "tag": tag,
        "status": "unknown",
    }
    t0 = time.perf_counter()    # monotonic: these are durations
    try:
        with compat.set_mesh(mesh):
            if kind == "vision":
                from repro.launch.lowering import build_vision_cell
                cell = build_vision_cell(arch, shape, mesh)
            else:
                from repro.launch.lowering import build_lm_cell
                cell = build_lm_cell(arch, shape, mesh, remat=remat,
                                     grad_accum=grad_accum)
            result["meta"] = cell.meta
            jitted = jax.jit(cell.fn, **cell.jit_kwargs)
            lowered = jitted.lower(*cell.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            print(mem)                       # proves it fits (or not)
            cost = compiled.cost_analysis()
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
            mem_rec = {}
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes",
                         "peak_memory_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_rec[attr] = int(v)
            hlo_text = compiled.as_text()
            from repro.roofline import hlo_cost
            cost_model = hlo_cost.analyze(hlo_text)
            census = hlo_mod.op_census(hlo_text)

            result.update({
                "status": "ok",
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory": mem_rec,
                # raw XLA numbers (while bodies counted once — see
                # roofline/hlo_cost.py) kept for reference:
                "cost_raw": {k: float(v) for k, v in cost.items()
                             if isinstance(v, (int, float))},
                # trip-corrected per-device cost model:
                "flops": cost_model["flops"],
                "bytes_hbm": cost_model["bytes"],
                # fusion-aware bytes: XLA's own bytes-accessed (respects
                # the compiled fusion structure) scaled by the trip ratio
                # from the text model — the preferred memory-term input.
                "bytes_hbm_calibrated": float(
                    cost.get("bytes accessed", 0.0)
                    * cost_model["trip_ratio"]),
                "trip_ratio": cost_model["trip_ratio"],
                "collectives": cost_model["collectives"],
                "while_trips": cost_model["while_trips"],
                "op_census": census,
                "hlo_lines": hlo_text.count("\n"),
                "hw": HW,
            })
    except Exception as exc:  # noqa: BLE001
        result["status"] = "error"
        result["error"] = f"{type(exc).__name__}: {exc}"
        result["traceback"] = traceback.format_exc()[-4000:]

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape}__{mesh_mode}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] {fname}: {result['status']} "
          f"(lower {result.get('lower_s', '-')}s, "
          f"compile {result.get('compile_s', '-')}s)")
    return result["status"] == "ok"


def write_skip(arch, shape, mesh_mode, reason, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape}__{mesh_mode}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump({"arch": arch, "shape": shape, "mesh": mesh_mode,
                   "status": "skipped", "reason": reason}, f, indent=1)


def drive_all(mesh_modes, out_dir, timeout: int = 1800):
    ok = fail = skip = cached = 0
    for kind, arch, shape, skip_reason in cell_matrix():
        for mm in mesh_modes:
            fname = os.path.join(out_dir, f"{arch}__{shape}__{mm}.json")
            if os.path.exists(fname):
                with open(fname) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    cached += 1
                    continue
            if skip_reason is not None:
                write_skip(arch, shape, mm, skip_reason, out_dir)
                skip += 1
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mm,
                   "--out", out_dir]
            if kind == "vision":
                cmd.append("--vision")
            print(f"[driver] {arch} × {shape} × {mm} ...", flush=True)
            try:
                r = subprocess.run(cmd, timeout=timeout)
                ok += int(r.returncode == 0)
                fail += int(r.returncode != 0)
            except subprocess.TimeoutExpired:
                write_skip(arch, shape, mm, f"compile timeout {timeout}s",
                           out_dir)
                fail += 1
    print(f"[driver] done: ok={ok} fail={fail} skipped={skip} "
          f"cached={cached}")
    return fail == 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--vision", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.list:
        for kind, arch, shape, skip in cell_matrix():
            print(f"{kind:7s} {arch:20s} {shape:15s}"
                  f"{' SKIP: ' + skip if skip else ''}")
        return

    modes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        sys.exit(0 if drive_all(modes, args.out, args.timeout) else 1)

    ok = True
    for mm in modes:
        ok &= run_cell("vision" if args.vision else "lm", args.arch,
                       args.shape, mm, args.out, remat=args.remat,
                       tag=args.tag, grad_accum=args.grad_accum)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
