"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 100 --batch 8 --seq 256 --reduced

On a real multi-host TPU deployment this module is the per-host entry
point: jax.distributed initialisation, production mesh, per-host data
sharding, fault-tolerant trainer with elastic re-mesh.  ``--reduced``
swaps in the reduced config so the same path runs on CPU.
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from repro.configs.base import get_arch, with_precision
from repro.data.pipeline import DataConfig
from repro.launch import args as largs
from repro.launch.mesh import (dp_axes_for, make_mesh_for_devices,
                               make_production_mesh)
from repro.optim.adamw import AdamWConfig
from repro.train.step import LossScaleConfig
from repro.train.trainer import ElasticTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 / 2x16x16 production mesh "
                         "(requires 256/512 devices)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--distributed", action="store_true",
                    help="initialise jax.distributed from env (multi-host)")
    largs.add_precision_args(ap)
    largs.add_tuning_args(ap)
    largs.add_observability_args(ap)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    largs.setup_observability(args)
    largs.load_tune_cache(args, "train")

    if args.distributed:
        jax.distributed.initialize()

    entry = get_arch(args.arch)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_mesh_for_devices(jax.devices(),
                                     model_parallel=min(
                                         16, len(jax.devices())))
    dp_axes = dp_axes_for(mesh)
    tp = mesh.shape["model"]

    cfg = entry.reduced() if args.reduced else entry.full(n_model_shards=tp)
    cfg = dataclasses.replace(cfg, n_model_shards=tp, max_seq=args.seq)
    mp_kwargs = {}
    if args.precision:
        cfg = with_precision(cfg, args.precision)
        if cfg.param_dtype != jax.numpy.float32:
            # low-precision params need the f32 master + loss-scale loop
            mp_kwargs = dict(master_weights=True,
                             loss_scaling=LossScaleConfig())

    n_hosts = jax.process_count()
    trainer = ElasticTrainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, n_hosts=n_hosts,
                   host_id=jax.process_index(),
                   vision_len=args.seq // 2 if cfg.family == "vlm" else 0,
                   enc_len=cfg.enc_len if cfg.family == "audio" else 0,
                   d_model=cfg.d_model),
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        mesh=mesh, dp_axes=dp_axes,
        grad_compression=args.grad_compression,
        mesh_builder=lambda devs: make_mesh_for_devices(
            devs, model_parallel=tp),
        **mp_kwargs)
    trainer.init_or_restore()
    hist = trainer.run(args.steps)
    largs.finish_observability(args, "train")
    print(f"[train] {args.arch}: loss {hist[0]:.4f} -> {hist[-1]:.4f}, "
          f"recoveries={trainer.recoveries}")


if __name__ == "__main__":
    main()
