"""GSPN-2 core algorithm (paper §3.2, §4.2).

Building blocks:

* :func:`normalize_taps` — Stability–Context row-stochastic normalisation of
  the 3-tap propagation logits (masked softmax; boundary taps excluded).
* :func:`directional_scan` — maps the four directional passes (T→B, B→T,
  L→R, R→L) onto the canonical top-to-bottom kernel scan via flips and
  transposes (the TPU analogue of the paper's per-direction CUDA streams:
  directions become batched data parallelism).
* :class:`GSPNAttentionConfig` + ``init/apply_gspn_attention`` — the full
  GSPN-2 attention module with **compact channel propagation**:
  channel-shared affinity taps and a compressive proxy space
  ``C → C_proxy → C`` (paper §4.2, App. D).
* ``init/apply_gspn_seq_mixer`` — the 1D-sequence adaptation used as a
  sub-quadratic causal token mixer for language models (DESIGN.md §4):
  fold L → (H, W), causal T→B 2D scan + causal within-row scan.

All modules are functional: ``init_*(key, cfg) -> params`` (pytree of
jnp arrays) and ``apply_*(params, x, cfg) -> y``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.ops import gspn_scan

DIRECTIONS = ("tb", "bt", "lr", "rl")


# ---------------------------------------------------------------------------
# Tap normalisation (Stability–Context condition).
# ---------------------------------------------------------------------------

def normalize_taps(logits, mode: str = "softmax"):
    """Row-stochastic 3-tap weights from logits.

    logits: (..., W, 3) — per spatial position, taps (left, center, right)
    referring to previous-row neighbours (j-1, j, j+1).  Boundary taps are
    masked (j=0 has no left neighbour; j=W-1 no right), so each row of the
    implied tridiagonal matrix sums to exactly 1 ⇒ non-expansive scan.

    Returns (wl, wc, wr), each (..., W), dtype f32.
    """
    w = logits.shape[-2]
    logits = logits.astype(jnp.float32)
    j = jnp.arange(w)
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.stack([
        jnp.where(j == 0, neg, 0.0),        # left tap invalid at j=0
        jnp.zeros((w,)),                    # center always valid
        jnp.where(j == w - 1, neg, 0.0),    # right tap invalid at j=W-1
    ], axis=-1)                             # (W, 3)
    if mode == "softmax":
        z = jax.nn.softmax(logits + mask, axis=-1)
    elif mode == "abs":
        a = jnp.abs(logits) * (mask == 0.0)
        z = a / (a.sum(axis=-1, keepdims=True) + 1e-6)
    else:
        raise ValueError(mode)
    return z[..., 0], z[..., 1], z[..., 2]


# ---------------------------------------------------------------------------
# Directional dispatch.
# ---------------------------------------------------------------------------

def _to_canonical(a, direction: str):
    """Orient (..., H, W) so the canonical scan (top->bottom over axis -2)
    realises the requested direction."""
    if direction == "tb":
        return a
    if direction == "bt":
        return jnp.flip(a, axis=-2)
    if direction == "lr":
        return jnp.swapaxes(a, -1, -2)
    if direction == "rl":
        return jnp.flip(jnp.swapaxes(a, -1, -2), axis=-2)
    raise ValueError(direction)


def _from_canonical(a, direction: str):
    if direction == "tb":
        return a
    if direction == "bt":
        return jnp.flip(a, axis=-2)
    if direction == "lr":
        return jnp.swapaxes(a, -1, -2)
    if direction == "rl":
        return jnp.swapaxes(jnp.flip(a, axis=-2), -1, -2)
    raise ValueError(direction)


def directional_scan(x, wl, wc, wr, lam, direction: str, **scan_kwargs):
    """Run one directional pass.  x, lam: (G, H, W); w*: (G_w, H, W) in the
    ORIGINAL orientation; tap logits must already be produced for the
    oriented geometry (callers orient positions before generating taps, so
    taps always refer to the scan geometry — see apply_gspn_attention)."""
    h = gspn_scan(
        _to_canonical(x, direction),
        _to_canonical(wl, direction),
        _to_canonical(wc, direction),
        _to_canonical(wr, direction),
        _to_canonical(lam, direction),
        **scan_kwargs,
    )
    return _from_canonical(h, direction)


# ---------------------------------------------------------------------------
# GSPN-2 attention module (vision, channels-last).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSPNAttentionConfig:
    dim: int                       # C
    proxy_dim: int = 8             # C_proxy (paper: 2..32; ImageNet uses 2)
    directions: Sequence[str] = DIRECTIONS
    channel_shared: bool = True    # GSPN-2 compact mode; False = GSPN-1 mode
    chunk: int | None = None       # GSPN-local segment length (rows)
    norm_mode: str = "softmax"
    impl: str = "auto"             # kernel selection, see kernels.ops
    param_dtype: jnp.dtype = jnp.float32


def _dense_init(key, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32,
                               -scale, scale)).astype(dtype)


def init_gspn_attention(key, cfg: GSPNAttentionConfig):
    nd = len(cfg.directions)
    cp = cfg.proxy_dim
    tap_out = 3 * nd if cfg.channel_shared else 3 * nd * cp
    keys = jax.random.split(key, 5)
    return {
        "down": _dense_init(keys[0], cfg.dim, cp, cfg.param_dtype),
        # tap logits biased toward the identity-ish center tap at init
        "w_taps": _dense_init(keys[1], cfg.dim, tap_out, cfg.param_dtype),
        "w_lam": _dense_init(keys[2], cfg.dim, nd * cp, cfg.param_dtype),
        "w_u": _dense_init(keys[3], cfg.dim, nd * cp, cfg.param_dtype),
        "up": _dense_init(keys[4], cp, cfg.dim, cfg.param_dtype),
    }


def apply_gspn_attention(params, x, cfg: GSPNAttentionConfig):
    """x: (B, H, W, C) -> (B, H, W, C)."""
    b, h, w, c = x.shape
    nd = len(cfg.directions)
    cp = cfg.proxy_dim
    xf = x.astype(jnp.float32)

    x_p = xf @ params["down"].astype(jnp.float32)          # (B,H,W,Cp)
    taps = xf @ params["w_taps"].astype(jnp.float32)       # (B,H,W,3*nd[*Cp])
    lam = jax.nn.sigmoid(xf @ params["w_lam"].astype(jnp.float32))
    u = xf @ params["w_u"].astype(jnp.float32)             # (B,H,W,nd*Cp)

    # (B, Cp, H, W) -> (B*Cp, H, W): channel-major grouping so that
    # channels_per_weight = Cp matches the kernel's index_map convention.
    def to_scan(a_bhwc, ch):
        return jnp.moveaxis(a_bhwc, -1, 1).reshape(b * ch, h, w)

    x_scan = to_scan(x_p, cp)
    out = jnp.zeros((b, h, w, cp), jnp.float32)
    for d_idx, direction in enumerate(cfg.directions):
        if cfg.channel_shared:
            tap_d = taps[..., 3 * d_idx:3 * (d_idx + 1)]   # (B,H,W,3)
            # Orient positions first so taps refer to scan-local geometry.
            tap_d = _to_canonical(jnp.moveaxis(tap_d, -1, 1), direction)
            tap_d = jnp.moveaxis(tap_d, 1, -1)             # (B,H',W',3)
            wl, wc_, wr = normalize_taps(tap_d, cfg.norm_mode)
        else:
            sl = taps[..., 3 * cp * d_idx:3 * cp * (d_idx + 1)]
            sl = sl.reshape(b, h, w, cp, 3)
            sl = jnp.moveaxis(sl, 3, 1).reshape(b * cp, h, w, 3)
            sl = _to_canonical(jnp.moveaxis(sl, -1, 1), direction)
            sl = jnp.moveaxis(sl, 1, -1)
            wl, wc_, wr = normalize_taps(sl, cfg.norm_mode)

        lam_d = to_scan(lam[..., cp * d_idx:cp * (d_idx + 1)], cp)
        h_d = gspn_scan(
            _to_canonical(x_scan, direction),
            wl, wc_, wr,
            _to_canonical(lam_d, direction),
            chunk=cfg.chunk, impl=cfg.impl,
        )
        h_d = _from_canonical(h_d, direction)
        h_d = jnp.moveaxis(h_d.reshape(b, cp, h, w), 1, -1)  # (B,H,W,Cp)
        u_d = u[..., cp * d_idx:cp * (d_idx + 1)]
        out = out + u_d * h_d

    y = out @ params["up"].astype(jnp.float32)
    return y.astype(x.dtype)


def gspn_attention_param_count(cfg: GSPNAttentionConfig) -> int:
    nd = len(cfg.directions)
    cp = cfg.proxy_dim
    tap_out = 3 * nd if cfg.channel_shared else 3 * nd * cp
    return (cfg.dim * cp + cfg.dim * tap_out + 2 * cfg.dim * nd * cp
            + cp * cfg.dim)


# ---------------------------------------------------------------------------
# 1D-sequence causal mixer (LM adaptation, DESIGN.md §4).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSPNSeqConfig:
    dim: int
    proxy_dim: int = 8
    row_width: int = 0             # 0 => ceil(sqrt(L)) at call time
    channel_shared: bool = True
    norm_mode: str = "softmax"
    impl: str = "auto"
    param_dtype: jnp.dtype = jnp.float32


def init_gspn_seq_mixer(key, cfg: GSPNSeqConfig):
    cp = cfg.proxy_dim
    keys = jax.random.split(key, 6)
    return {
        "down": _dense_init(keys[0], cfg.dim, cp, cfg.param_dtype),
        "w_taps": _dense_init(keys[1], cfg.dim, 3, cfg.param_dtype),
        "w_row": _dense_init(keys[2], cfg.dim, 1, cfg.param_dtype),
        "w_lam": _dense_init(keys[3], cfg.dim, 2 * cp, cfg.param_dtype),
        "w_u": _dense_init(keys[4], cfg.dim, 2 * cp, cfg.param_dtype),
        "up": _dense_init(keys[5], cp, cfg.dim, cfg.param_dtype),
    }


def _fold_len(l: int, row_width: int) -> tuple[int, int]:
    w = row_width or 1 << max(1, math.ceil(math.log2(max(l, 4)) / 2))
    h = -(-l // w)
    return h, w


def apply_gspn_seq_mixer(params, x, cfg: GSPNSeqConfig,
                         return_cache: bool = False):
    """Causal sub-quadratic token mixer.  x: (B, L, D) -> (B, L, D).

    Fold the sequence row-major into (H, W); causality holds because:
    * the T→B pass only reads row i-1, all of whose tokens precede row i;
    * the within-row pass is a strictly left-to-right recurrence.

    ``return_cache=True`` additionally returns the O(W) decode cache
    (previous grid row + within-row state) for streaming generation.
    """
    b, l, d = x.shape
    cp = cfg.proxy_dim
    h, w = _fold_len(l, cfg.row_width)
    pad = h * w - l
    xf = x.astype(jnp.float32)

    x_p = xf @ params["down"].astype(jnp.float32)            # (B,L,Cp)
    taps = xf @ params["w_taps"].astype(jnp.float32)         # (B,L,3)
    row_g = jax.nn.sigmoid(xf @ params["w_row"].astype(jnp.float32))
    lam = jax.nn.sigmoid(xf @ params["w_lam"].astype(jnp.float32))
    u = xf @ params["w_u"].astype(jnp.float32)

    def fold(a):  # (B, L, K) -> (B*K, H, W)
        k = a.shape[-1]
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        a = a.reshape(b, h, w, k)
        return jnp.moveaxis(a, -1, 1).reshape(b * k, h, w)

    def unfold(a, k):  # (B*K, H, W) -> (B, L, K)
        a = jnp.moveaxis(a.reshape(b, k, h, w), 1, -1)
        return a.reshape(b, h * w, k)[:, :l]

    # Pass 1: causal T->B 2D scan in proxy space, channel-shared taps.
    wl, wc_, wr = normalize_taps(fold(taps).reshape(b * 3, h, w)
                                 .reshape(b, 3, h, w).transpose(0, 2, 3, 1),
                                 cfg.norm_mode)
    h_tb = gspn_scan(fold(x_p), wl, wc_, wr,
                     fold(lam[..., :cp]), impl=cfg.impl)

    # Pass 2: causal within-row scan — center-tap-only recurrence along W,
    # realised as an 'lr'-oriented scan with chunk=1 row coupling removed
    # (wl=wr=0 ⇒ h[j] = g·h[j-1] + lam·x[j] independently per row).
    x_lr = _to_canonical(fold(x_p), "lr")
    gate = _to_canonical(fold(jnp.broadcast_to(row_g, (b, l, 1))), "lr")
    zeros = jnp.zeros_like(gate)
    h_row = gspn_scan(x_lr, zeros, gate, zeros,
                      _to_canonical(fold(lam[..., cp:]), "lr"),
                      impl=cfg.impl)
    h_row = _from_canonical(h_row, "lr")

    y = (unfold(h_tb, cp) * u[..., :cp] + unfold(h_row, cp) * u[..., cp:])
    y = y @ params["up"].astype(jnp.float32)
    y = y.astype(x.dtype)
    if not return_cache:
        return y

    # Build the streaming cache for position l (static shapes).
    grid_tb = h_tb.reshape(b, cp, h, w)
    grid_row = h_row.reshape(b, cp, h, w)
    i_last, j_last = (l - 1) // w, (l - 1) % w
    row_i = grid_tb[:, :, i_last, :]
    if j_last == w - 1:
        prev_row = row_i
        cur_row = row_i
    else:
        prev_row = (grid_tb[:, :, i_last - 1, :] if i_last > 0
                    else jnp.zeros_like(row_i))
        col_mask = (jnp.arange(w) <= j_last).astype(jnp.float32)
        cur_row = row_i * col_mask
    cache = {
        "prev_row": prev_row.astype(jnp.float32),
        "cur_row": cur_row.astype(jnp.float32),
        "row_state": grid_row[:, :, i_last, j_last].astype(jnp.float32),
        "pos": jnp.full((b,), l, jnp.int32),
    }
    return y, cache
