"""GSPN-2 core algorithm (paper §3.2, §4.2).

Building blocks:

* :func:`normalize_taps` — Stability–Context row-stochastic normalisation of
  the 3-tap propagation logits (masked softmax; boundary taps excluded).
* :func:`directional_scan` — the multi-direction dispatch (DESIGN.md §2).
  Opposite directions (T→B/B→T, L→R/R→L) are FUSED into one
  ``gspn_scan_pair`` launch each — the reverse member of a pair is index
  arithmetic inside the kernel, and the horizontal pair costs a single
  transpose of ``x`` at the dispatch boundary — so a full four-direction
  pass issues two fused calls instead of four per-direction scans over
  flipped/transposed copies (the TPU analogue of the paper's §4.3
  stream-based concurrency).  A single direction string is still accepted
  and maps onto the canonical top-to-bottom scan.
* :class:`GSPNAttentionConfig` + ``init/apply_gspn_attention`` — the full
  GSPN-2 attention module with **compact channel propagation**:
  channel-shared affinity taps and a compressive proxy space
  ``C → C_proxy → C`` (paper §4.2, App. D), routed through the fused
  multi-direction dispatch.
* ``init/apply_gspn_seq_mixer`` — the 1D-sequence adaptation used as a
  sub-quadratic causal token mixer for language models (DESIGN.md §4):
  fold L → (H, W), causal T→B 2D scan + causal within-row scan.

All modules are functional: ``init_*(key, cfg) -> params`` (pytree of
jnp arrays) and ``apply_*(params, x, cfg) -> y``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.ops import gspn_scan, gspn_scan_pair
from repro.kernels.spec import ScanSpec

DIRECTIONS = ("tb", "bt", "lr", "rl")

# Opposite-direction pairs fused into one kernel launch each: the first
# member is the canonical (forward) traversal, the second its mirror.
OPPOSITE_PAIRS = (("tb", "bt"), ("lr", "rl"))


# ---------------------------------------------------------------------------
# Tap normalisation (Stability–Context condition).
# ---------------------------------------------------------------------------

def normalize_taps(logits, mode: str = "softmax"):
    """Row-stochastic 3-tap weights from logits.

    logits: (..., W, 3) — per spatial position, taps (left, center, right)
    referring to previous-row neighbours (j-1, j, j+1).  Boundary taps are
    masked (j=0 has no left neighbour; j=W-1 no right), so each row of the
    implied tridiagonal matrix sums to exactly 1 ⇒ non-expansive scan.

    Returns (wl, wc, wr), each (..., W), dtype f32.
    """
    w = logits.shape[-2]
    logits = logits.astype(jnp.float32)
    j = jnp.arange(w)
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.stack([
        jnp.where(j == 0, neg, 0.0),        # left tap invalid at j=0
        jnp.zeros((w,)),                    # center always valid
        jnp.where(j == w - 1, neg, 0.0),    # right tap invalid at j=W-1
    ], axis=-1)                             # (W, 3)
    if mode == "softmax":
        z = jax.nn.softmax(logits + mask, axis=-1)
    elif mode == "abs":
        a = jnp.abs(logits) * (mask == 0.0)
        z = a / (a.sum(axis=-1, keepdims=True) + 1e-6)
    else:
        raise ValueError(mode)
    return z[..., 0], z[..., 1], z[..., 2]


# ---------------------------------------------------------------------------
# Directional dispatch.
# ---------------------------------------------------------------------------

def _to_canonical(a, direction: str):
    """Orient (..., H, W) so the canonical scan (top->bottom over axis -2)
    realises the requested direction."""
    if direction == "tb":
        return a
    if direction == "bt":
        return jnp.flip(a, axis=-2)
    if direction == "lr":
        return jnp.swapaxes(a, -1, -2)
    if direction == "rl":
        return jnp.flip(jnp.swapaxes(a, -1, -2), axis=-2)
    raise ValueError(direction)


def _from_canonical(a, direction: str):
    if direction == "tb":
        return a
    if direction == "bt":
        return jnp.flip(a, axis=-2)
    if direction == "lr":
        return jnp.swapaxes(a, -1, -2)
    if direction == "rl":
        return jnp.swapaxes(jnp.flip(a, axis=-2), -1, -2)
    raise ValueError(direction)


def directional_scan(x, wl, wc, wr, lam, direction, **scan_kwargs):
    """Run one or several directional passes through the fused dispatch.

    Single direction (``direction`` a string): x, lam: (G, H, W); w*:
    (G_w, H, W) in the ORIGINAL orientation; returns (G, H, W).

    Multi-direction (``direction`` a sequence of distinct direction names):
    w*: (D, G_w, H, W) and lam: (D, G, H, W) stacked per direction, again
    in the ORIGINAL orientation; ``x`` is shared by every direction.
    Returns (D, G, H, W).  Opposite pairs present in the sequence are
    fused into ONE ``gspn_scan_pair`` launch each (the L→R/R→L pair via a
    single transpose of the operands at this boundary — no per-direction
    flipped copies), so a full four-direction pass issues two fused
    kernel calls.  Unpaired directions fall back to single scans.

    In both forms, tap logits must already be produced for the oriented
    geometry (callers orient positions before generating taps, so taps
    always refer to the scan geometry — see apply_gspn_attention).
    """
    if not isinstance(direction, str):
        return _multi_directional_scan(x, wl, wc, wr, lam,
                                       tuple(direction), **scan_kwargs)
    h = gspn_scan(
        _to_canonical(x, direction),
        _to_canonical(wl, direction),
        _to_canonical(wc, direction),
        _to_canonical(wr, direction),
        _to_canonical(lam, direction),
        **scan_kwargs,
    )
    return _from_canonical(h, direction)


def _multi_directional_scan(x, wl, wc, wr, lam, directions, **scan_kwargs):
    idx = {d: i for i, d in enumerate(directions)}
    assert len(idx) == len(directions), f"duplicate directions {directions}"
    # per_step is the GSPN-1 emulation — by construction one dispatch per
    # line per direction, so pair fusion is intentionally skipped.  The
    # spatially-sharded path ("sp") DOES fuse: the opposite members share
    # one boundary collective over the seq mesh axis (stacked compact
    # (T, b) states, gspn_scan_sp_pair — DESIGN.md §8), so splitting the
    # pair would double the exchange count.  The impl leg lives in the
    # ScanSpec when one is passed.
    sk_spec = scan_kwargs.get("spec")
    impl = (sk_spec.impl if sk_spec is not None
            else scan_kwargs.get("impl", "auto"))
    fuse = impl != "per_step"

    out = [None] * len(directions)
    fused = set()
    if fuse:
        for fwd_d, rev_d in OPPOSITE_PAIRS:
            if fwd_d not in idx or rev_d not in idx:
                continue
            i, j = idx[fwd_d], idx[rev_d]
            if fwd_d == "lr":      # horizontal: ONE transpose at dispatch
                ori = lambda a: jnp.swapaxes(a, -1, -2)
            else:                  # vertical: already canonical
                ori = lambda a: a
            h2 = gspn_scan_pair(
                ori(x),
                jnp.stack([ori(wl[i]), ori(wl[j])]),
                jnp.stack([ori(wc[i]), ori(wc[j])]),
                jnp.stack([ori(wr[i]), ori(wr[j])]),
                jnp.stack([ori(lam[i]), ori(lam[j])]),
                **scan_kwargs,
            )
            out[i], out[j] = ori(h2[0]), ori(h2[1])
            fused.update((fwd_d, rev_d))
    for d, i in idx.items():
        if d not in fused:
            out[i] = directional_scan(x, wl[i], wc[i], wr[i], lam[i], d,
                                      **scan_kwargs)
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# GSPN-2 attention module (vision, channels-last).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSPNAttentionConfig:
    dim: int                       # C
    proxy_dim: int = 8             # C_proxy (paper: 2..32; ImageNet uses 2)
    directions: Sequence[str] = DIRECTIONS
    channel_shared: bool = True    # GSPN-2 compact mode; False = GSPN-1 mode
    chunk: int | None = None       # GSPN-local segment length (rows)
    norm_mode: str = "softmax"
    impl: str = "auto"             # kernel selection, see kernels.ops
    seq_axis: str = "seq"          # mesh axis for impl="sp" (DESIGN.md §8)
    sp_strategy: str = "auto"      # boundary-exchange strategy for impl="sp"
    param_dtype: jnp.dtype = jnp.float32
    # Mixed-precision policy (DESIGN.md §10): projections and streamed
    # scan operands run in compute_dtype; tap softmax, scan carries and
    # the decode cache stay f32.  boundary_dtype is the sp exchange
    # payload (None → compute_dtype); composition is always f32.
    compute_dtype: jnp.dtype = jnp.float32
    carry_dtype: jnp.dtype = jnp.float32
    boundary_dtype: jnp.dtype | None = None


def _dense_init(key, d_in, d_out, dtype):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32,
                               -scale, scale)).astype(dtype)


def init_gspn_attention(key, cfg: GSPNAttentionConfig):
    nd = len(cfg.directions)
    cp = cfg.proxy_dim
    tap_out = 3 * nd if cfg.channel_shared else 3 * nd * cp
    keys = jax.random.split(key, 5)
    return {
        "down": _dense_init(keys[0], cfg.dim, cp, cfg.param_dtype),
        # tap logits biased toward the identity-ish center tap at init
        "w_taps": _dense_init(keys[1], cfg.dim, tap_out, cfg.param_dtype),
        "w_lam": _dense_init(keys[2], cfg.dim, nd * cp, cfg.param_dtype),
        "w_u": _dense_init(keys[3], cfg.dim, nd * cp, cfg.param_dtype),
        "up": _dense_init(keys[4], cp, cfg.dim, cfg.param_dtype),
    }


def _normalize_taps_oriented(logits, direction: str, mode: str):
    """Row-stochastic taps for ``direction`` from logits (..., H, W, 3),
    returned in the ORIGINAL (H, W) orientation.

    Boundary masking must refer to the scan geometry, so horizontal
    directions normalise in transposed space; the flip component of
    'bt'/'rl' acts along the scan axis, commutes with the (scan-axis
    independent) masking and needs no data movement.
    """
    if direction in ("lr", "rl"):
        wl, wc, wr = normalize_taps(jnp.swapaxes(logits, -3, -2), mode)
        return tuple(jnp.swapaxes(a, -1, -2) for a in (wl, wc, wr))
    return normalize_taps(logits, mode)


def _scan_spec_kwargs(cfg, mesh, *, boundary: str = "one_shot"):
    """The ``scan_kwargs`` shared by the attention module, the sequence
    mixer and chunked prefill: ONE :class:`ScanSpec` carrying the whole
    launch policy (impl, dtype legs, boundary behaviour — DESIGN.md §10,
    §14), plus the sp ROUTING legs (mesh/axis/strategy/wire dtype) that
    describe where the scan runs rather than what it computes."""
    cd = jnp.dtype(cfg.compute_dtype)
    bd = cfg.boundary_dtype if cfg.boundary_dtype is not None else cd
    spec = ScanSpec(impl=cfg.impl, stream_dtype=str(cd),
                    carry_dtype=str(jnp.dtype(cfg.carry_dtype)),
                    boundary=boundary)
    return dict(spec=spec, mesh=mesh, seq_axis=cfg.seq_axis,
                sp_strategy=cfg.sp_strategy,
                sp_boundary_dtype=jnp.dtype(bd))


def apply_gspn_attention(params, x, cfg: GSPNAttentionConfig, *, mesh=None):
    """x: (B, H, W, C) -> (B, H, W, C).

    All directional passes run through ONE batched ``directional_scan``
    call: opposite pairs are fused per kernel launch, so the default
    four-direction pass dispatches two fused scans (DESIGN.md §2).
    ``mesh`` is only consulted by ``impl="sp"``, which shards each
    direction's scan dimension over ``cfg.seq_axis`` (DESIGN.md §8).
    Projections and scan streams run in ``cfg.compute_dtype``; the tap
    softmax and the output accumulation stay f32 (DESIGN.md §10).
    """
    b, h, w, c = x.shape
    cp = cfg.proxy_dim
    cd = jnp.dtype(cfg.compute_dtype)
    xf = x.astype(cd)

    x_p = xf @ params["down"].astype(cd)                   # (B,H,W,Cp)
    taps = xf @ params["w_taps"].astype(cd)                # (B,H,W,3*nd[*Cp])
    lam = jax.nn.sigmoid(xf @ params["w_lam"].astype(cd))
    u = xf @ params["w_u"].astype(cd)                      # (B,H,W,nd*Cp)

    # (B, Cp, H, W) -> (B*Cp, H, W): channel-major grouping so that
    # channels_per_weight = Cp matches the kernel's index_map convention.
    def to_scan(a_bhwc, ch):
        return jnp.moveaxis(a_bhwc, -1, 1).reshape(b * ch, h, w)

    x_scan = to_scan(x_p, cp)
    wls, wcs, wrs, lams = [], [], [], []
    for d_idx, direction in enumerate(cfg.directions):
        if cfg.channel_shared:
            tap_d = taps[..., 3 * d_idx:3 * (d_idx + 1)]   # (B,H,W,3)
        else:
            tap_d = taps[..., 3 * cp * d_idx:3 * cp * (d_idx + 1)]
            tap_d = tap_d.reshape(b, h, w, cp, 3)
            tap_d = jnp.moveaxis(tap_d, 3, 1).reshape(b * cp, h, w, 3)
        wl, wc_, wr = _normalize_taps_oriented(tap_d, direction,
                                               cfg.norm_mode)
        # Tap softmax runs in f32; the normalised taps are then streamed
        # to the kernels in compute_dtype (row sums survive the rounding
        # to within one ulp — the scan stays non-expansive in practice).
        wls.append(wl.astype(cd))
        wcs.append(wc_.astype(cd))
        wrs.append(wr.astype(cd))
        lams.append(to_scan(lam[..., cp * d_idx:cp * (d_idx + 1)], cp))

    h_all = directional_scan(
        x_scan, jnp.stack(wls), jnp.stack(wcs), jnp.stack(wrs),
        jnp.stack(lams), cfg.directions,
        chunk=cfg.chunk, **_scan_spec_kwargs(cfg, mesh),
    )                                                      # (D, B*Cp, H, W)

    # Directional merge accumulates in f32 whatever the stream dtype.
    out = jnp.zeros((b, h, w, cp), jnp.float32)
    for d_idx in range(len(cfg.directions)):
        h_d = jnp.moveaxis(h_all[d_idx].reshape(b, cp, h, w), 1, -1)
        out = out + (u[..., cp * d_idx:cp * (d_idx + 1)]
                     * h_d).astype(jnp.float32)

    y = out.astype(cd) @ params["up"].astype(cd)
    return y.astype(x.dtype)


def gspn_attention_param_count(cfg: GSPNAttentionConfig) -> int:
    nd = len(cfg.directions)
    cp = cfg.proxy_dim
    tap_out = 3 * nd if cfg.channel_shared else 3 * nd * cp
    return (cfg.dim * cp + cfg.dim * tap_out + 2 * cfg.dim * nd * cp
            + cp * cfg.dim)


# ---------------------------------------------------------------------------
# 1D-sequence causal mixer (LM adaptation, DESIGN.md §4).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSPNSeqConfig:
    dim: int
    proxy_dim: int = 8
    row_width: int = 0             # 0 => ceil(sqrt(L)) at call time
    channel_shared: bool = True
    norm_mode: str = "softmax"
    impl: str = "auto"
    seq_axis: str = "seq"          # mesh axis for impl="sp" (DESIGN.md §8)
    sp_strategy: str = "auto"
    param_dtype: jnp.dtype = jnp.float32
    # Mixed-precision policy (DESIGN.md §10) — same legs as the attention
    # module: compute_dtype streams, f32 tap softmax / carries / cache.
    compute_dtype: jnp.dtype = jnp.float32
    carry_dtype: jnp.dtype = jnp.float32
    boundary_dtype: jnp.dtype | None = None


def init_gspn_seq_mixer(key, cfg: GSPNSeqConfig):
    cp = cfg.proxy_dim
    keys = jax.random.split(key, 6)
    return {
        "down": _dense_init(keys[0], cfg.dim, cp, cfg.param_dtype),
        "w_taps": _dense_init(keys[1], cfg.dim, 3, cfg.param_dtype),
        "w_row": _dense_init(keys[2], cfg.dim, 1, cfg.param_dtype),
        "w_lam": _dense_init(keys[3], cfg.dim, 2 * cp, cfg.param_dtype),
        "w_u": _dense_init(keys[4], cfg.dim, 2 * cp, cfg.param_dtype),
        "up": _dense_init(keys[5], cp, cfg.dim, cfg.param_dtype),
    }


def _fold_len(l: int, row_width: int) -> tuple[int, int]:
    w = row_width or 1 << max(1, math.ceil(math.log2(max(l, 4)) / 2))
    h = -(-l // w)
    return h, w


def _seq_mixer_projections(params, xf):
    """Per-token projections shared by the one-shot and chunked paths.
    xf: (B, L, D) in the policy's compute dtype (f32 by default).
    Returns (x_p, taps, row_g, lam, u), all in xf.dtype."""
    cd = xf.dtype
    x_p = xf @ params["down"].astype(cd)                     # (B,L,Cp)
    taps = xf @ params["w_taps"].astype(cd)                  # (B,L,3)
    row_g = jax.nn.sigmoid(xf @ params["w_row"].astype(cd))
    lam = jax.nn.sigmoid(xf @ params["w_lam"].astype(cd))
    u = xf @ params["w_u"].astype(cd)
    return x_p, taps, row_g, lam, u


def _fold_ops(b, h, w, l):
    """The row-major (B, L, K) <-> (B*K, H, W) fold/unfold pair for a
    sequence of l tokens on an (h, w) grid (zero-padded tail).  One
    definition serves the one-shot and chunked paths — the chunked≡
    one-shot equivalence depends on an identical layout."""
    pad = h * w - l

    def fold(a):
        k = a.shape[-1]
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        a = a.reshape(b, h, w, k)
        return jnp.moveaxis(a, -1, 1).reshape(b * k, h, w)

    def unfold(a, k):
        a = jnp.moveaxis(a.reshape(b, k, h, w), 1, -1)
        return a.reshape(b, h * w, k)[:, :l]

    return fold, unfold


def _tb_taps(taps, fold, b, h, w, mode, dtype=jnp.float32):
    """Row-stochastic T→B tap weights from per-token logits (B, L, 3):
    fold to the grid, regroup the 3 taps innermost, and normalise.
    Shared by the one-shot and chunked paths.  The softmax itself runs in
    f32 (normalize_taps); ``dtype`` is the streamed output dtype."""
    wl, wc, wr = normalize_taps(
        fold(taps).reshape(b, 3, h, w).transpose(0, 2, 3, 1), mode)
    return wl.astype(dtype), wc.astype(dtype), wr.astype(dtype)


def _within_row_pass(x_p, row_g, lam_hi, b, l, fold, scan_kwargs):
    """Pass 2 of the sequence mixer: causal within-row recurrence —
    centre-tap-only 'lr'-oriented scan (wl=wr=0 ⇒ h[j] = g·h[j-1] + λ·x[j]
    independently per grid row).  Shared by the one-shot and chunked
    paths; rows reset their carry at column 0, so the pass is local to
    whatever fold it is given."""
    x_lr = _to_canonical(fold(x_p), "lr")
    gate = _to_canonical(fold(jnp.broadcast_to(row_g, (b, l, 1))), "lr")
    zeros = jnp.zeros_like(gate)
    h_row = gspn_scan(x_lr, zeros, gate, zeros,
                      _to_canonical(fold(lam_hi), "lr"), **scan_kwargs)
    return _from_canonical(h_row, "lr")


def _slice_boundary_cache(grid_tb, grid_row, l, w, prev_fallback):
    """Slice the outgoing O(W) decode-cache state at (static) position l
    from the scanned grids (B, Cp, H, W): previous/current grid rows of
    the T→B pass plus the within-row state.  ``prev_fallback`` stands in
    for the row above when the final partial row is the grid's FIRST row
    — zeros at sequence start, the incoming boundary when chunking.  One
    definition serves both paths so the streaming-cache convention cannot
    drift (the 1e-5 chunked≡one-shot invariant depends on it)."""
    i_last, j_last = (l - 1) // w, (l - 1) % w
    row_i = grid_tb[:, :, i_last, :]
    if j_last == w - 1:
        prev_row = row_i
        cur_row = row_i
    else:
        prev_row = (grid_tb[:, :, i_last - 1, :] if i_last > 0
                    else prev_fallback)
        col_mask = (jnp.arange(w) <= j_last).astype(jnp.float32)
        cur_row = row_i * col_mask
    return {
        "prev_row": prev_row.astype(jnp.float32),
        "cur_row": cur_row.astype(jnp.float32),
        "row_state": grid_row[:, :, i_last, j_last].astype(jnp.float32),
    }


def apply_gspn_seq_mixer(params, x, cfg: GSPNSeqConfig,
                         return_cache: bool = False, *, mesh=None):
    """Causal sub-quadratic token mixer.  x: (B, L, D) -> (B, L, D).

    Fold the sequence row-major into (H, W); causality holds because:
    * the T→B pass only reads row i-1, all of whose tokens precede row i;
    * the within-row pass is a strictly left-to-right recurrence.

    ``return_cache=True`` additionally returns the O(W) decode cache
    (previous grid row + within-row state) for streaming generation.
    With ``impl="sp"`` and a mesh carrying ``cfg.seq_axis``, both folded
    passes shard their scan dimension across devices (DESIGN.md §8) —
    grid rows for the T→B pass, grid columns for the within-row pass —
    which is what lets folded sequences outgrow one chip's memory.
    """
    b, l, d = x.shape
    cp = cfg.proxy_dim
    h, w = _fold_len(l, cfg.row_width)
    cd = jnp.dtype(cfg.compute_dtype)
    xf = x.astype(cd)

    x_p, taps, row_g, lam, u = _seq_mixer_projections(params, xf)
    fold, unfold = _fold_ops(b, h, w, l)

    scan_kwargs = _scan_spec_kwargs(cfg, mesh)

    # Pass 1: causal T->B 2D scan in proxy space, channel-shared taps.
    wl, wc_, wr = _tb_taps(taps, fold, b, h, w, cfg.norm_mode, dtype=cd)
    h_tb = gspn_scan(fold(x_p), wl, wc_, wr,
                     fold(lam[..., :cp]), **scan_kwargs)

    # Pass 2: causal within-row scan.
    h_row = _within_row_pass(x_p, row_g, lam[..., cp:], b, l, fold,
                             scan_kwargs)

    y = (unfold(h_tb, cp) * u[..., :cp] + unfold(h_row, cp) * u[..., cp:])
    y = y @ params["up"].astype(cd)
    y = y.astype(x.dtype)
    if not return_cache:
        return y

    # Build the streaming cache for position l (static shapes).
    grid_tb = h_tb.reshape(b, cp, h, w)
    grid_row = h_row.reshape(b, cp, h, w)
    cache = _slice_boundary_cache(grid_tb, grid_row, l, w,
                                  jnp.zeros_like(grid_tb[:, :, 0, :]))
    cache["pos"] = jnp.full((b,), l, jnp.int32)
    return y, cache


def gspn_seq_prefill_chunk(params, x, cfg: GSPNSeqConfig, cache, *,
                           mesh=None):
    """Resume the folded causal scans from a streaming cache (DESIGN.md §9).

    x: (B, T, D) — the next T prompt tokens; ``cache`` is the O(W) decode
    cache from a previous call to this function (or a fresh all-zero
    cache at pos 0).  Returns (y (B, T, D), new_cache) such that chaining
    chunks is numerically equivalent to one one-shot prefill over the
    concatenated tokens.  A cache advanced mid-row by ``gspn_decode_step``
    is NOT a valid input — this path resumes from ``prev_row`` only and
    would drop the partial ``cur_row``/``row_state`` (see the alignment
    contract below).

    State slicing: the recurrence only reads grid row i−1, so a chunk that
    STARTS at a grid-row boundary needs exactly one boundary row of state.
    The incoming ``prev_row`` is injected as a synthetic row 0 of the
    chunk's folded grid with λ=1 and zero taps (the scan's zero initial
    carry then reproduces it exactly), and the within-row pass is
    chunk-local because every grid row resets its carry at column 0.

    Contract (enforced by the serve engine, not checkable on traced
    values): ``cache['pos'] % cfg.row_width == 0`` — i.e. all chunks but
    the last must cover a whole number of grid rows.  Requires a fixed
    ``cfg.row_width`` (the fold geometry must not depend on total length).
    """
    b, t, d = x.shape
    cp = cfg.proxy_dim
    w = cfg.row_width
    if w <= 0:
        raise ValueError(
            "chunked GSPN prefill needs a fixed row_width (row_width=0 "
            "derives the fold from the total length, which a chunked "
            "caller does not know)")
    hc = -(-t // w)
    cd = jnp.dtype(cfg.compute_dtype)
    xf = x.astype(cd)

    x_p, taps, row_g, lam, u = _seq_mixer_projections(params, xf)
    fold, unfold = _fold_ops(b, hc, w, t)

    # The resumed-carry chunk gets the chunk_resume boundary label: same
    # numerics as one_shot (the resumed row is a synthetic row 0 of the
    # launch), but the autotuner keys the ragged chunk-grid launches
    # separately from full-length prefill (DESIGN.md §14).
    scan_kwargs = _scan_spec_kwargs(cfg, mesh, boundary="chunk_resume")

    # Pass 1: T->B scan seeded with the incoming boundary row.  Row 0 of
    # the seeded grid carries prev_row (λ=1, taps=0 ⇒ h[0] = prev_row);
    # the chunk's real rows then see the correct cross-chunk neighbour.
    # The f32 cached boundary is rounded to the stream dtype here — the
    # one bounded cross-chunk rounding the §10 error budget accounts for.
    wl, wc_, wr = _tb_taps(taps, fold, b, hc, w, cfg.norm_mode, dtype=cd)
    ztap = jnp.zeros((b, 1, w), cd)
    x_tb = jnp.concatenate(
        [cache["prev_row"].astype(cd).reshape(b * cp, 1, w), fold(x_p)],
        axis=1)
    lam_tb = jnp.concatenate(
        [jnp.ones((b * cp, 1, w), cd), fold(lam[..., :cp])], axis=1)
    h_tb = gspn_scan(x_tb,
                     jnp.concatenate([ztap, wl], axis=1),
                     jnp.concatenate([ztap, wc_], axis=1),
                     jnp.concatenate([ztap, wr], axis=1),
                     lam_tb, **scan_kwargs)[:, 1:]

    # Pass 2: within-row scan — every grid row resets at column 0 and
    # chunks start at row boundaries, so this pass is chunk-local.
    h_row = _within_row_pass(x_p, row_g, lam[..., cp:], b, t, fold,
                             scan_kwargs)

    y = (unfold(h_tb, cp) * u[..., :cp] + unfold(h_row, cp) * u[..., cp:])
    y = (y @ params["up"].astype(cd)).astype(x.dtype)

    # Slice the outgoing boundary state — same construction as the
    # one-shot cache, with the incoming prev_row standing in when the
    # chunk is a single partial row.  All indices are static in T, so
    # this traces once per chunk length.
    grid_tb = h_tb.reshape(b, cp, hc, w)
    grid_row = h_row.reshape(b, cp, hc, w)
    new_cache = _slice_boundary_cache(
        grid_tb, grid_row, t, w, cache["prev_row"].astype(jnp.float32))
    new_cache["pos"] = cache["pos"] + t
    return y, new_cache
