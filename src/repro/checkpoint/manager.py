"""Checkpoint manager: atomic, async, retention-limited, elastic-restorable.

Layout (one directory per step)::

    <dir>/step_000123/
        meta.json            — step, flat-key manifest, shapes/dtypes, config
        host_000.npz         — this host's param/opt shards (flat keys)
        COMMIT               — written last; a checkpoint without COMMIT is
                               ignored on restore (atomicity)

* **Async**: ``save`` snapshots arrays to host memory synchronously (cheap)
  and writes to disk on a background thread, so the train loop continues.
* **Retention**: keeps the newest ``keep`` committed checkpoints.
* **Elastic restore**: restore maps flat keys back into an arbitrary target
  pytree/sharding — a job restarted on a different mesh re-shards on load
  (``jax.device_put`` with the new sharding), which is how the elastic
  trainer survives topology changes.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def key_str(path):
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    return {key_str(p): l for p, l in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def committed_steps(self) -> list:
        steps = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                    steps.append(int(name.split("_")[1]))
        return steps

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    # -- save -------------------------------------------------------------
    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Any, extra_meta: dict | None = None):
        """Snapshot now, write in background (if async)."""
        self.wait()
        flat = _flatten(state)
        # Synchronous device->host snapshot; cheap relative to a train step.
        host = {k: np.asarray(v) for k, v in flat.items()}
        meta = {
            "step": step,
            "n_hosts": self.n_hosts,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
            "extra": extra_meta or {},
            "time": time.time(),
        }

        def write():
            sdir = self._step_dir(step)
            os.makedirs(sdir, exist_ok=True)
            np.savez(os.path.join(sdir, f"host_{self.host_id:03d}.npz"),
                     **host)
            if self.host_id == 0:
                with open(os.path.join(sdir, "meta.json"), "w") as f:
                    json.dump(meta, f)
                with open(os.path.join(sdir, "COMMIT"), "w") as f:
                    f.write(str(step))
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def restore(self, step: Optional[int] = None, target: Any = None,
                shardings: Any = None):
        """Restore into the structure of ``target`` (required).  If
        ``shardings`` (same structure) is given, leaves are device_put with
        the new sharding — this is the elastic re-shard path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        sdir = self._step_dir(step)
        data = dict(np.load(os.path.join(
            sdir, f"host_{self.host_id:03d}.npz")))

        flat_target = _flatten(target)
        missing = set(flat_target) - set(data)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}")
        flat_shard = _flatten(shardings) if shardings is not None else None

        leaves_by_key = {}
        for k, tgt in flat_target.items():
            arr = data[k]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"{k}: ckpt {arr.shape} != target {tgt.shape}")
            arr = arr.astype(tgt.dtype)
            if flat_shard is not None and flat_shard.get(k) is not None:
                arr = jax.device_put(arr, flat_shard[k])
            else:
                arr = jnp.asarray(arr)
            leaves_by_key[k] = arr

        # Rebuild in target's structure.
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)

        def key_str(path):
            parts = []
            for k in path:
                parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
            return "/".join(parts)

        new_leaves = [leaves_by_key[key_str(p)] for p, _ in paths]
        return jax.tree_util.tree_unflatten(treedef, new_leaves), step
