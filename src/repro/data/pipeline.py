"""Deterministic synthetic data pipeline with per-host sharding.

At 1000+-node scale each host must read only its slice of the global batch
and the stream must be bitwise-reproducible under restart/elastic re-mesh.
This pipeline derives every batch purely from ``(seed, step, host_slice)``
— no filesystem state — so a restarted or re-sharded job regenerates the
identical token stream for any step (tested in tests/test_data.py).

Token streams are Zipf-distributed with a Markov skeleton so models have
learnable structure (losses fall during the examples' training runs).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: str = "markov"      # markov|uniform
    vision_len: int = 0            # Qwen2-VL stub prefix length
    d_model: int = 0               # for vision/audio embedding stubs
    enc_len: int = 0               # whisper stub frames

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # Stable across restarts AND across re-sharding: seed folds in the step
    # only; host slicing is positional below.
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))


def synth_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """Global batch of tokens (global_batch, seq_len+1) — callers slice
    inputs=[:-1], labels=[1:]."""
    rng = _batch_rng(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len + 1, cfg.vocab
    if cfg.structure == "uniform":
        return rng.integers(0, v, (b, s), dtype=np.int32)
    # Markov skeleton: next token = (prev * a + noise) mod small_band, then
    # mapped through a Zipf-ish permutation for a realistic marginal.
    band = min(v, 4096)
    a = 31
    x = np.empty((b, s), np.int64)
    x[:, 0] = rng.integers(0, band, b)
    noise = rng.integers(0, 7, (b, s))
    for t in range(1, s):
        x[:, t] = (x[:, t - 1] * a + noise[:, t]) % band
    # Zipf-ify: token id -> floor(band * u^2) spreads mass toward low ids.
    u = x.astype(np.float64) / band
    out = (np.floor((u ** 1.5) * min(v, band * 8)) % v).astype(np.int32)
    return out


def host_batch(cfg: DataConfig, step: int) -> dict:
    """This host's slice of the global batch, as numpy."""
    toks = synth_tokens(cfg, step)
    lo = cfg.host_id * cfg.host_batch
    hi = lo + cfg.host_batch
    sl = toks[lo:hi]
    batch = {"tokens": sl[:, :-1], "labels": sl[:, 1:]}
    rng = _batch_rng(cfg, step)
    if cfg.vision_len:
        ve = rng.standard_normal(
            (cfg.global_batch, cfg.vision_len, cfg.d_model)).astype(np.float32)
        batch["vision_embeds"] = ve[lo:hi]
    if cfg.enc_len:
        fr = rng.standard_normal(
            (cfg.global_batch, cfg.enc_len, cfg.d_model)).astype(np.float32)
        batch["enc_frames"] = fr[lo:hi]
    return batch


class Prefetcher:
    """Single-step lookahead prefetch onto device (thread-based)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, sharding=None):
        import queue
        import threading
        self.cfg = cfg
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = host_batch(cfg, step)
                if sharding is not None:
                    batch = jax.tree.map(
                        lambda a: jax.device_put(a, sharding), batch)
                self._q.put((step, batch))
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except Exception:
            pass


def synth_images(cfg: DataConfig, step: int, img_size: int,
                 n_classes: int) -> dict:
    """Synthetic image classification batch: class-conditional blobs so a
    model can actually learn (examples/train_vision.py)."""
    rng = _batch_rng(cfg, step)
    b = cfg.host_batch
    labels = rng.integers(0, n_classes, b).astype(np.int32)
    xs = rng.standard_normal((b, img_size, img_size, 3)).astype(np.float32)
    # inject a class-dependent low-frequency pattern
    yy, xx = np.meshgrid(np.linspace(0, 1, img_size),
                         np.linspace(0, 1, img_size), indexing="ij")
    for i, c in enumerate(labels):
        freq = 1 + (c % 5)
        phase = (c // 5) * 0.7
        xs[i, :, :, c % 3] += 2.0 * np.sin(
            freq * 2 * np.pi * (yy + xx) + phase)
    return {"images": xs, "labels": labels}
