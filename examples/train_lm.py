"""End-to-end LM training driver.

    PYTHONPATH=src python examples/train_lm.py --preset small --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Runs the full production path: config → sharded train step (single device
here; the identical code path drives the 512-chip meshes via
repro.launch.train) → fault-tolerant trainer with checkpointing → loss
curve.  ``--mixer gspn`` swaps attention for the paper's GSPN-2 sequence
mixer (beyond-paper LM adaptation, DESIGN.md §4).
"""

import argparse
import logging

import jax

from repro.data.pipeline import DataConfig
from repro.models.lm import LMConfig, count_params, init_lm
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~15M params: fast on CPU
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=1024, vocab=8192),
    # ~100M params: the "train a ~100M model for a few hundred steps"
    # deliverable configuration (several hours on this CPU container;
    # minutes on one accelerator host)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mixer", default="attn", choices=["attn", "gspn"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(message)s")

    p = PRESETS[args.preset]
    cfg = LMConfig(
        name=f"{args.preset}-{args.mixer}", family="dense",
        unit=((args.mixer, p["n_layers"]),), n_units=1,
        gspn_proxy_dim=8, gspn_row_width=32, remat="none", **p)
    n = count_params(init_lm(jax.random.PRNGKey(0), cfg))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
          f"mixer={args.mixer}  device={jax.devices()[0].platform}")

    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10),
        mesh=mesh)
    trainer.init_or_restore()
    hist = trainer.run(args.steps)
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps "
          f"({trainer.recoveries} recoveries, {trainer.stragglers} "
          f"straggler events)")


if __name__ == "__main__":
    main()
