"""Beyond-paper showcase: GSPN-2 as an O(√L)-state long-context decoder.

    PYTHONPATH=src python examples/long_context_gspn.py --ctx 4096

The GSPN sequence mixer folds the token stream into a √L×√L grid; decode
keeps only the previous grid row + the within-row state (DESIGN.md §4).
This script prefils a prompt, then streams tokens while printing the cache
footprint — constant in context length per row — and verifies streaming
outputs equal the full forward pass.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (LMConfig, apply_lm, init_lm, lm_decode_step,
                             lm_prefill)


def cache_bytes(tree):
    return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ctx", type=int, default=4096)
    ap.add_argument("--stream", type=int, default=32)
    args = ap.parse_args()

    row_w = 1 << max(2, (args.ctx.bit_length() // 2))
    cfg = LMConfig(name="gspn-long", family="dense", n_layers=2,
                   d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                   vocab=512, gspn_proxy_dim=4, gspn_row_width=row_w,
                   unit=(("gspn", 2),), n_units=1, remat="none")
    params = init_lm(jax.random.PRNGKey(0), cfg)

    total = args.ctx + args.stream
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, total), 0, 512)
    logits_full, _ = apply_lm(params, cfg, toks)

    _, caches, _ = lm_prefill(params, cfg, toks[:, :args.ctx],
                              max_len=total)
    print(f"context {args.ctx} tokens folded into rows of {row_w}; "
          f"decode cache = {cache_bytes(caches)/1e3:.1f} KB "
          f"(vs {args.ctx * cfg.n_layers * 2 * cfg.n_kv_heads * 16 * 2/1e3:.1f} KB "
          f"for an equivalent KV cache)")

    outs = []
    for t in range(args.ctx, total):
        lg, caches = lm_decode_step(params, cfg, toks[:, t:t + 1], caches)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(logits_full[:, args.ctx:], np.float32),
        rtol=5e-2, atol=5e-2)
    print(f"streamed {args.stream} tokens at position {args.ctx}: "
          f"outputs match full forward ✓")


if __name__ == "__main__":
    main()
