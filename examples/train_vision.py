"""Train a (reduced) GSPN-2 vision classifier — the paper's own model —
on synthetic class-conditional images; accuracy climbs well above chance.

    PYTHONPATH=src python examples/train_vision.py --steps 60
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.gspn2_vision import reduced_vision
from repro.data.pipeline import DataConfig, synth_images
from repro.models.lm import count_params
from repro.models.vision import apply_vision, init_vision, vision_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = reduced_vision()
    params = init_vision(jax.random.PRNGKey(0), cfg)
    print(f"GSPN-2 classifier ({cfg.name}): "
          f"{count_params(params)/1e3:.0f}K params, "
          f"C_proxy={cfg.proxy_dim}, img {cfg.img_size}²")

    ocfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps,
                       weight_decay=0.01)
    opt = adamw_init(ocfg, params)
    dcfg = DataConfig(vocab=1, seq_len=1, global_batch=args.batch)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: vision_loss(p, cfg, batch), has_aux=True)(params)
        params, opt, _ = adamw_update(ocfg, g, opt, params)
        return params, opt, loss

    @jax.jit
    def accuracy(params, batch):
        logits = apply_vision(params, batch["images"], cfg)
        return jnp.mean(jnp.argmax(logits, -1) == batch["labels"])

    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 synth_images(dcfg, s, cfg.img_size, cfg.n_classes).items()}
        params, opt, loss = step(params, opt, batch)
        if s % 10 == 0 or s == args.steps - 1:
            test = {k: jnp.asarray(v) for k, v in
                    synth_images(dcfg, 10_000 + s, cfg.img_size,
                                 cfg.n_classes).items()}
            acc = float(accuracy(params, test))
            print(f"step {s:4d}  loss {float(loss):.3f}  "
                  f"held-out acc {acc:.2f} (chance {1/cfg.n_classes:.2f})")
    assert acc > 2.0 / cfg.n_classes, "no learning"
    print("vision training OK")


if __name__ == "__main__":
    main()
