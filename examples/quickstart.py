"""Quickstart: the GSPN-2 propagation layer in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows (1) the raw 4-directional line scan, (2) that it equals the dense
Eq.-4 affinity-matrix form, (3) the full GSPN-2 attention module with
compact channel propagation, and (4) gradients flowing through the fused
custom-VJP scan.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gspn as G
from repro.kernels import ref as R
from repro.kernels.ops import gspn_scan


def main():
    key = jax.random.PRNGKey(0)
    b, c, h, w = 2, 4, 16, 16

    # --- 1. raw scan ------------------------------------------------------
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b * c, h, w))
    lam = jax.nn.sigmoid(jax.random.normal(ks[1], (b * c, h, w)))
    # channel-shared taps (GSPN-2 compact mode): one tap set per image
    wl, wc, wr = G.normalize_taps(jax.random.normal(ks[2], (b, h, w, 3)))
    hidden = gspn_scan(x, wl, wc, wr, lam)
    print(f"line scan: x{x.shape} -> h{hidden.shape}")
    print(f"  row-stochastic taps: wl+wc+wr = "
          f"{float((wl + wc + wr).mean()):.6f} (exactly 1)")

    # --- 2. equals the dense attention-like form (paper Eq. 4) ------------
    dense = R.gspn_dense_oracle(x, wl, wc, wr, lam)
    print(f"  max |scan - dense Eq.4| = "
          f"{float(jnp.abs(hidden - dense).max()):.2e}")

    # --- 3. four-directional GSPN-2 attention module -----------------------
    cfg = G.GSPNAttentionConfig(dim=32, proxy_dim=8)
    params = G.init_gspn_attention(jax.random.PRNGKey(1), cfg)
    img = jax.random.normal(jax.random.PRNGKey(2), (b, h, w, 32))
    y = G.apply_gspn_attention(params, img, cfg)
    print(f"GSPN-2 attention: {img.shape} -> {y.shape} "
          f"(proxy C {cfg.dim}->{cfg.proxy_dim}, "
          f"directions={list(cfg.directions)})")

    # --- 4. gradients through the fused scan --------------------------------
    def loss(p):
        return jnp.sum(G.apply_gspn_attention(p, img, cfg) ** 2)

    grads = jax.grad(loss)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    print(f"grad norm through custom-VJP scan: {float(gnorm):.3f}")
    assert np.isfinite(float(gnorm))
    print("quickstart OK")


if __name__ == "__main__":
    main()
