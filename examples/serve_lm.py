"""Request-generator driver for the continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --rate 8 \
        --prefill-chunk 32 --scheduler sjf --mixer gspn

Builds a small model, then plays an arrival process against the engine:
requests arrive at ``--rate`` req/s (exponential inter-arrivals) with a
short/long prompt mix, and the driver interleaves ``submit`` with engine
``tick()``s — exactly how a deployment front-end would drive it.  Long
prompts are consumed in ``--prefill-chunk``-token chunks between decode
steps, so they never stall the decode batch (DESIGN.md §9).

Printed metrics per request: TTFT (submit -> first token), queue delay
(submit -> admission), mean inter-token latency, prefill chunk count and
finish reason; aggregate: tok/s, p50/max TTFT, max queue depth.
``--stream`` prints tokens as they are produced.
"""

import argparse

import jax
import numpy as np

from repro.models.lm import LMConfig, init_lm
from repro.serve.engine import Request, ServeEngine, drive


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="offered load, requests/s (0 = all at once)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--scheduler", default="fcfs", choices=["fcfs", "sjf"])
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mixer", default="attn",
                    choices=["attn", "gspn", "mlstm", "mamba"])
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated")
    args = ap.parse_args()

    cfg = LMConfig(
        name=f"serve-{args.mixer}", family="dense", n_layers=4,
        d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192,
        unit=((args.mixer, 4),), n_units=1,
        gspn_proxy_dim=8, gspn_row_width=32, ssm_head_dim=32, remat="none")
    params = init_lm(jax.random.PRNGKey(0), cfg)

    stream = (lambda uid, tok: print(f"    [stream] req {uid} -> {tok}")) \
        if args.stream else None
    eng = ServeEngine(params, cfg, batch_size=args.batch, max_len=512,
                      temperature=args.temperature, top_k=50,
                      prefill_chunk=args.prefill_chunk,
                      scheduler=args.scheduler, stream=stream)

    # Request generator: discrete short/long prompt lengths (bounds jit
    # variants), exponential inter-arrival times at the offered rate.
    rng = np.random.default_rng(0)
    plens = rng.choice([16, 96], size=args.requests, p=[0.7, 0.3])
    gaps = (rng.exponential(1.0 / args.rate, args.requests)
            if args.rate > 0 else np.zeros(args.requests))
    arrivals = np.cumsum(gaps)
    reqs = [Request(uid=i, prompt=rng.integers(0, 8192, int(plens[i])),
                    max_new_tokens=int(rng.integers(
                        min(8, args.max_new), args.max_new + 1)))
            for i in range(args.requests)]

    dt, handles = drive(eng, reqs, arrivals, idle_sleep=0.005)

    results = {h.uid: h.result() for h in handles if h.done}
    if not results:
        print("served 0 requests")
        return
    total = sum(len(r.tokens) for r in results.values())
    ttfts = sorted(r.ttft for r in results.values())
    print(f"served {len(results)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, mixer={args.mixer}, "
          f"slots={args.batch}, chunk={eng.prefill_chunk}, "
          f"sched={args.scheduler})")
    m = eng.metrics
    print(f"ttft p50 {ttfts[len(ttfts)//2]*1e3:.1f} ms / "
          f"max {ttfts[-1]*1e3:.1f} ms; queue depth "
          f"mean {m['queue_depth_mean']:.1f} / "
          f"max {m['queue_depth_max']}")
    for uid in sorted(results)[:6]:
        r = results[uid]
        itl = 1e3 * (sum(r.itl) / len(r.itl)) if r.itl else 0.0
        print(f"  req {uid}: {len(r.tokens)} toks, "
              f"ttft {r.ttft*1e3:.1f} ms, queue {r.queue_delay*1e3:.1f} ms, "
              f"itl {itl:.1f} ms, chunks {r.prefill_chunks}, "
              f"{r.finish_reason}: {r.tokens[:8]}...")


if __name__ == "__main__":
    main()
