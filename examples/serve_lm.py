"""Batched serving with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --batch 4

Builds a small model, submits a stream of mixed-length requests, and runs
the engine: prefill fills each slot's cache (KV / SSM state / GSPN row
cache depending on --mixer), the batched decode step serves all slots,
finished slots are refilled from the queue.
"""

import argparse
import time

import jax
import numpy as np

from repro.models.lm import LMConfig, init_lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--mixer", default="attn",
                    choices=["attn", "gspn", "mlstm", "mamba"])
    args = ap.parse_args()

    cfg = LMConfig(
        name=f"serve-{args.mixer}", family="dense", n_layers=4,
        d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192,
        unit=((args.mixer, 4),), n_units=1,
        gspn_proxy_dim=8, gspn_row_width=32, ssm_head_dim=32, remat="none")
    params = init_lm(jax.random.PRNGKey(0), cfg)

    eng = ServeEngine(params, cfg, batch_size=args.batch, max_len=512,
                      temperature=args.temperature, top_k=50)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 64))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, 8192, plen),
                           max_new_tokens=int(rng.integers(8,
                                                           args.max_new))))
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.tokens) for r in results.values())
    print(f"served {len(results)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, mixer={args.mixer}, "
          f"slots={args.batch})")
    for uid in sorted(results)[:4]:
        print(f"  req {uid}: {results[uid].tokens[:10]}...")


if __name__ == "__main__":
    main()
